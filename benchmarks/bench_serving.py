"""Continuous batching vs static cohorts under streaming arrivals.

The paper evaluates per-iteration goodput on a fixed batch; a serving
deployment sees a *stream* — requests arrive over time, finish at
different times, and capacity idles unless freed rows are re-filled
immediately.  This section measures end-to-end goodput (accepted tokens
per sim-second, idle gaps included) of the continuous-batching scheduler
against the seed-style static-cohort baseline on identical Poisson
arrival traces, plus a KV-pressure record showing budget-driven
preemption at work.

Uses the untrained reduced zoo (scheduling behaviour, not acceptance
quality, is under test) so the section runs in seconds on CPU.
"""

from __future__ import annotations

import time

from repro.core.selector import LBSS, SelectorConfig
from repro.data.workloads import make_workload
from repro.launch.serve import build_zoo
from repro.serving.engine import EngineConfig, SpinEngine

VOCAB = 128
N_REQ = 12
CAPACITY = 4
GAMMA = 3
RATES = (100.0, 300.0)     # requests/sec on the sim clock


def _run(llm, ssms, policy, rate, *, kv_budget=None, capacity=CAPACITY,
         seed=17):
    reqs = make_workload("mix", N_REQ, VOCAB, seed=seed, scale=0.25,
                         arrival_rate=rate)
    sel = LBSS(SelectorConfig(n_ssms=len(ssms),
                              batch_limits=[capacity] * len(ssms),
                              alpha=4, beta=2, seed=seed),
               group_of={r.rid: r.dataset for r in reqs})
    ecfg = EngineConfig(gamma=GAMMA, max_len=128, capacity=capacity,
                        packed_bucket=128, straggler_mitigation=False,
                        scheduler_policy=policy, kv_budget=kv_budget)
    eng = SpinEngine(llm, ssms, sel, ecfg)
    eng.add_requests(reqs)
    stats = eng.run(max_slots=1000)
    stats["unfinished"] = sum(1 for r in eng.requests.values() if not r.done)
    return stats


def main(emit):
    llm, ssms = build_zoo(VOCAB, seed=0, n_ssms=2)
    for rate in RATES:
        res = {}
        for policy in ("static", "continuous"):
            t0 = time.perf_counter()
            st = _run(llm, ssms, policy, rate)
            us = (time.perf_counter() - t0) * 1e6
            res[policy] = st
            sch = st["scheduler"]
            emit(f"serving[{policy},rate={rate:.0f}]", us,
                 f"goodput={st['goodput_sim']:.1f}tok/s "
                 f"mean_lat={st['mean_latency'] * 1e3:.1f}ms "
                 f"p95_lat={st['p95_latency'] * 1e3:.1f}ms "
                 f"queue_wait={sch['queue_wait'] * 1e3:.1f}ms "
                 f"finished={sch['finished']} "
                 f"unfinished={st['unfinished']}")
        speedup = (res["continuous"]["goodput_sim"]
                   / max(res["static"]["goodput_sim"], 1e-9))
        emit(f"serving_speedup[rate={rate:.0f}]", 0.0,
             f"continuous_vs_static={speedup:.2f}x")

    # KV pressure: a budget far below capacity*max_len forces preemption;
    # the run must still drain (re-prefill on re-admission, losslessly).
    # 64 cells = 4 blocks of 16 under the paged layout: requests fit at
    # admission (1 block each) and outgrow the budget mid-flight.
    t0 = time.perf_counter()
    st = _run(llm, ssms, "continuous", 500.0, kv_budget=64, capacity=3)
    us = (time.perf_counter() - t0) * 1e6
    sch = st["scheduler"]
    emit("serving_kv_pressure[budget=64]", us,
         f"goodput={st['goodput_sim']:.1f}tok/s "
         f"preemptions={sch['preemptions']} "
         f"finished={sch['finished']} unfinished={st['unfinished']}")


if __name__ == "__main__":
    main(lambda n, u, d: print(f"{n},{u:.1f},{d}"))
