"""Paper Fig. 12: fast batch verification — padded vs request-decomposed
verification cost vs batch size.

Three views per batch size:
  * KV cells: padded grid (B x max_len) vs decomposed-packed grid (the
    paper's memory saving; the batch-32 padded blowup = their OOM);
  * Pallas tile work: (q_block x kv_block) tiles the verify_attention
    kernel COMPUTES after segment/causality block-skipping vs the padded
    kernel's tiles — the TPU compute saving of §V-A;
  * CPU wall-clock of the jitted XLA fallback path (reference only: the
    XLA path cannot skip masked blocks, so packed looks slower HERE; the
    kernel tile counts are the hardware-relevant number)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import VOCAB, build_zoo
from repro.core import decompose as D
from repro.models import transformer as T

GAMMA = 4


def kernel_tiles(q_seg, q_pos, kv_seg, kv_pos, bq=64, bk=64):
    """Mirror of verify_attention's block-skip predicate (numpy)."""
    import numpy as np
    nq = (len(q_seg) + bq - 1) // bq
    nk = (len(kv_seg) + bk - 1) // bk
    computed = 0
    for i in range(nq):
        qs = q_seg[i * bq:(i + 1) * bq]
        qp = q_pos[i * bq:(i + 1) * bq]
        for j in range(nk):
            ks = kv_seg[j * bk:(j + 1) * bk]
            kp = kv_pos[j * bk:(j + 1) * bk]
            valid = ks >= 0
            if not valid.any():
                continue
            lo, hi = ks[valid].min(), ks.max()
            if hi < qs.min() or lo > qs.max():
                continue                       # segment ranges disjoint
            if kp[valid].min() > qp.max():
                continue                       # entirely in the future
            computed += 1
    return computed, nq * nk


def main(emit):
    llm, _ = build_zoo()
    cfg, params = llm.cfg, llm.params
    rng = np.random.default_rng(5)
    for B in (4, 8, 16, 32):
        # ragged contexts with spec-decoding-style skew (paper: acceptance
        # variance drives length variance)
        lens = rng.integers(16, 160, B).tolist()
        S_max = max(lens) + GAMMA + 2
        toks = jnp.asarray(rng.integers(1, VOCAB, (B, S_max)), jnp.int32)
        lengths = jnp.asarray(lens, jnp.int32)
        _, cache = T.prefill(params, cfg, tokens=toks, lengths=lengths,
                             max_len=S_max)
        new_toks = jnp.asarray(rng.integers(1, VOCAB, (B, GAMMA + 1)),
                               jnp.int32)

        # padded verification
        pad_fn = jax.jit(lambda c, t, l: T.decode_step(
            params, cfg, c, tokens=t, lengths=l))
        pad_fn(cache, new_toks, lengths)                  # compile
        t0 = time.perf_counter()
        for _ in range(3):
            out_p = pad_fn(cache, new_toks, lengths)
        jax.block_until_ready(out_p[0])
        t_pad = (time.perf_counter() - t0) / 3

        # packed verification
        plan = D.plan_decomposition(lens, align=32)
        q_rows, q_pos, q_seg = D.build_query_layout(lens, GAMMA)
        override = D.make_attn_override(plan.gather_b, plan.gather_s,
                                        plan.valid, q_rows)
        pk_fn = jax.jit(lambda c, t: T.verify_step_packed(
            params, cfg, c, tokens=t, positions=jnp.asarray(q_pos),
            segments=jnp.asarray(q_seg), attn_override=override))
        flat = new_toks.reshape(1, -1)
        pk_fn(cache, flat)                                # compile
        t0 = time.perf_counter()
        for _ in range(3):
            out_k = pk_fn(cache, flat)
        jax.block_until_ready(out_k[0])
        t_packed = (time.perf_counter() - t0) / 3

        # Pallas-kernel tile work (block-skipping) for both layouts
        kv_seg_l, kv_pos_l = [], []
        for i, l in enumerate(lens):
            pad = (32 - l % 32) % 32
            kv_seg_l += [i] * l + [-1] * pad
            kv_pos_l += list(range(l)) + [-1] * pad
        tiles_packed, _ = kernel_tiles(
            np.asarray(q_seg[0]), np.asarray(q_pos[0]),
            np.asarray(kv_seg_l, np.int64), np.asarray(kv_pos_l, np.int64))
        # padded layout: every request padded to max_len; kernel still skips
        # nothing within a row (all same segment)
        S_pad = max(lens)
        tiles_padded = B * ((GAMMA + 1 + 63) // 64) * ((S_pad + 63) // 64)
        emit(f"fig12_verify[B={B}]", t_pad * 1e6,
             f"padded_cells={plan.baseline_cells} "
             f"packed_cells={plan.total} mem_saving={plan.saving:.0%} "
             f"kernel_tiles_padded={tiles_padded} "
             f"kernel_tiles_packed={tiles_packed} "
             f"tile_saving={1 - tiles_packed / max(tiles_padded, 1):.0%} "
             f"xla_cpu: padded={t_pad * 1e3:.1f}ms "
             f"packed={t_packed * 1e3:.1f}ms")


if __name__ == "__main__":
    main(lambda n, u, d: print(f"{n},{u:.1f},{d}"))
