"""§Perf hillclimb driver.

Runs one dry-run cell under a sequence of named variants (sharding rule
table x remat policy x attention accounting) and logs
hypothesis -> change -> before/after roofline terms to
results/perf_<arch>_<shape>.json.  The narrative lives in EXPERIMENTS.md.

Must own the process (512-device XLA flag) — run as:
    PYTHONPATH=src python benchmarks/perf_hillclimb.py --arch ... --shape ...
        --variants baseline,seqparallel,...
"""

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json


from repro.launch import dryrun
from repro.launch.specs import SHAPES
from repro.configs import registry
from repro.models import transformer as T


def flash_equiv_cost(cfg, shape: str):
    """Analytic per-device cost of attention under the Pallas flash kernel
    (kernels/flash_attention.py — validated vs oracle in tests):
      flops = QK^T + PV matmuls (fwd; x3.5 for train bwd+recompute)
      bytes = q+k+v+o streamed once (fwd; x3.5 train)
    Window-bounded for SWA.  Used to replace the measured XLA-attention
    subgraph cost in the kernel-adjusted §Perf variants."""
    info = SHAPES[shape]
    B, S = info["batch"], info["seq"]
    kind = info["kind"]
    n_attn = len(cfg.attn_positions)
    hd = cfg.hd
    if kind == "decode":
        sq, skv = 1, min(S, cfg.sliding_window or S)
    elif kind == "prefill":
        sq = S + cfg.num_prefix_embeds
        skv = min(sq, cfg.sliding_window or sq)
    else:
        sq = S + cfg.num_prefix_embeds
        skv = min(sq, cfg.sliding_window or sq)
    # causal: ~half the S^2 work (full for decode)
    pair_frac = 1.0 if kind == "decode" else 0.5
    flops = 2 * 2 * B * cfg.n_heads * sq * skv * hd * pair_frac * n_attn
    bytes_ = (2 * B * sq * cfg.n_heads * hd            # q, o
              + 2 * B * skv * cfg.n_kv_heads * hd) * 2 * n_attn   # k, v bf16
    if kind == "train":
        flops *= 3.5
        bytes_ *= 3.5
    n_chips = 256
    return {"flops": flops / n_chips, "bytes": bytes_ / n_chips}


VARIANTS = {
    # name: (rules_kind, opts_overrides, kernel_adjusted)
    "baseline": ("auto", {}, False),
    "remat_dots": ("auto", {"remat": "dots"}, False),
    "remat_none": ("auto", {"remat": "none"}, False),
    "seqparallel": ("train_seqparallel", {}, False),
    "zero1": ("train_zero1", {}, False),
    "serve_seqshard": ("serve_seqshard", {}, False),
    "serve_batch_model": ("serve_batch_model", {}, False),
    "serve_zero1": ("serve_zero1", {}, False),
    "serve_seq_data": ("serve_seq_data", {}, False),
    "serve_attn_repl": ("serve_attn_repl", {}, False),
    "flash_kernel+serve_attn_repl": ("serve_attn_repl", {}, True),
    "flash_kernel+serve_zero1": ("serve_zero1", {}, True),
    "qblock_256": ("auto", {"q_block": 256}, False),
    "qblock_1024": ("auto", {"q_block": 1024}, False),
    "flash_kernel": ("auto", {}, True),
    "flash_kernel+seqparallel": ("train_seqparallel", {}, True),
    "flash_kernel+remat_dots": ("auto", {"remat": "dots"}, True),
}


def run_variant(arch, shape, name, multi_pod=False):
    rules_kind, opt_over, kernel_adj = VARIANTS[name]
    opts = T.Opts(**opt_over)
    cfg = registry.get(arch)
    if not kernel_adj:
        rec = dryrun.run_cell(arch, shape, multi_pod=multi_pod,
                              roofline=True, rules_kind=rules_kind,
                              opts=opts)
    else:
        # measure attention subgraph exactly: std - stub, replace with the
        # flash-kernel analytic cost
        rec = dryrun.run_cell(arch, shape, multi_pod=multi_pod,
                              roofline=True, rules_kind=rules_kind,
                              opts=opts)
        stub = dryrun.run_cell(arch, shape, multi_pod=multi_pod,
                               roofline=True, rules_kind=rules_kind,
                               opts=dataclasses.replace(opts,
                                                        attn_stub=True))
        if rec.get("status") == "ok" and stub.get("status") == "ok":
            fl = flash_equiv_cost(cfg, shape)
            adj = {}
            for key in ("flops", "bytes"):
                attn_part = (rec["roofline_raw"][key]
                             - stub["roofline_raw"][key])
                adj[key] = (stub["roofline_raw"][key] + fl[key])
                rec.setdefault("attn_subgraph", {})[key] = attn_part
            adj["collective_bytes"] = rec["roofline_raw"]["collective_bytes"]
            rec["roofline_raw_xla"] = rec["roofline_raw"]
            rec["roofline_raw"] = adj
            rec["roofline"] = dryrun.roofline_terms(adj, rec["n_chips"])
    rec["variant"] = name
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", required=True,
                    help="comma-separated variant names")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    out_path = args.out or os.path.join(
        "results", f"perf_{args.arch}_{args.shape}.json".replace("/", "_"))
    results = []
    for name in args.variants.split(","):
        print(f"=== variant {name} ===", flush=True)
        rec = run_variant(args.arch, args.shape, name)
        show = {k: rec.get(k) for k in
                ("variant", "status", "roofline", "useful_flops_frac",
                 "error")}
        print(json.dumps(show, indent=1, default=str), flush=True)
        results.append(rec)
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1, default=str)
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
