"""Goodput-under-SLO: deadline-aware serving vs the deadline-blind stack.

The headline serving metric of this PR is **goodput-under-SLO**: accepted
tokens that also met their per-token deadline, per sim-second.  A token j
of a request with contract ``SLO(ttft_deadline, tpot_target)`` counts
only if it was emitted by ``arrival + ttft_deadline + j*tpot_target``
(serving/stats.py ``slo_summary``); raw goodput is blind to *when* each
token landed, which is exactly what an operator with latency contracts
cannot be.

Both arms run the *same* SLO-stamped mixed strict/lax stream
(``--slo-profile interactive``: chat-class requests carry lax contracts,
completion-class ones strict) on identical engines at equal aggregate KV
— the only difference is ``slo_aware``: the aware arm ranks admission
deadline-closest-first, boosts prefill chunks against TTFT slack, picks
preemption victims farthest-from-deadline-first and caps speculative
depth to deadline headroom; the blind arm is the pre-SLO stack (FIFO by
(priority, arrival), bit-identical to PR 8) that merely *measures*
attainment.  Under queueing pressure the blind arm makes strict requests
wait behind lax ones and busts their TTFT/TPOT budgets.

Acceptance (ISSUE 9): the SLO-aware arm must reach >= 1.25x the blind
arm's goodput-under-SLO on this workload.  A second record shows the
router's ``slo`` dispatch policy (cluster-level headroom) against ``lot``
on the same stamped stream across 2 replicas.

Uses the untrained reduced zoo (scheduling, not acceptance quality, is
under test) so the section runs in seconds on CPU.
"""

from __future__ import annotations

import time

from repro.core.selector import LBSS, SelectorConfig
from repro.data.workloads import make_workload
from repro.launch.serve import build_zoo, split_evenly
from repro.serving.engine import EngineConfig, SpinEngine
from repro.serving.router import Router, RouterConfig

VOCAB = 128
N_REQ = 28
CAPACITY = 4  # queueing pressure: ~7x oversubscribed at arrival
KV_BUDGET = 512
GAMMA = 3
RATE = 400.0  # req/s on the sim clock — saturating burst
SEED = 23
PROFILE = "interactive"
# 2x the profile deadlines: tight enough that the blind arm busts strict
# TTFT chains under queueing (attainment ~0.76), loose enough that the
# aware arm can actually meet them (~0.98) — the regime where ordering,
# not raw speed, decides attainment
SLO_SCALE = 2.0


def _workload():
    """Fresh stamped stream (requests are mutated by a run, so each arm
    rebuilds from the same seed — identical tokens, arrivals, SLOs)."""
    return make_workload(
        "mix",
        N_REQ,
        VOCAB,
        seed=SEED,
        scale=0.25,
        arrival_rate=RATE,
        slo_profile=PROFILE,
        slo_scale=SLO_SCALE,
    )


def _engine(llm, ssms, *, slo_aware, capacity=CAPACITY, kv_budget=KV_BUDGET, seed=SEED):
    sel = LBSS(
        SelectorConfig(
            n_ssms=len(ssms),
            batch_limits=[capacity] * len(ssms),
            alpha=4,
            beta=2,
            seed=seed,
        )
    )
    ecfg = EngineConfig(
        gamma=GAMMA,
        max_len=128,
        capacity=capacity,
        packed_bucket=128,
        straggler_mitigation=False,
        kv_budget=kv_budget,
        gamma_policy="adaptive",
        gamma_max=4,
        prefill_chunk=8,
        token_budget=30,
        slo_aware=slo_aware,
    )
    return SpinEngine(llm, ssms, sel, ecfg)


def _run(llm, ssms, *, slo_aware):
    eng = _engine(llm, ssms, slo_aware=slo_aware)
    eng.add_requests(_workload())
    st = eng.run(max_slots=2000)
    sch = st["scheduler"]
    assert sch["finished"] == N_REQ, (
        f"stream must drain: {sch['finished']}/{N_REQ} finished"
    )
    return st


def main(emit):
    llm, ssms = build_zoo(VOCAB, seed=0, n_ssms=2)

    # -- deadline-aware vs deadline-blind at equal aggregate KV ----------
    res = {}
    for arm, aware in (("aware", True), ("blind", False)):
        t0 = time.perf_counter()
        st = _run(llm, ssms, slo_aware=aware)
        us = (time.perf_counter() - t0) * 1e6
        res[arm] = st
        slo = st["slo"]
        sch = st["scheduler"]
        emit(
            f"slo[{arm}]",
            us,
            f"goodput_under_slo={slo['goodput_under_slo']:.1f}tok/s "
            f"attainment={slo['attainment']:.3f} "
            f"met_ttft={slo['ttft_met']}/{slo['slo_requests']} "
            f"goodput={st['goodput_sim']:.1f}tok/s "
            f"chunk_boosts={sch['slo_chunk_boosts']} "
            f"gamma_capped={st['gamma']['slo_capped']}",
        )
    aware_gus = res["aware"]["slo"]["goodput_under_slo"]
    blind_gus = res["blind"]["slo"]["goodput_under_slo"]
    gain = aware_gus / max(blind_gus, 1e-9)
    emit(
        "slo_gain[aware_vs_blind]",
        0.0,
        f"speedup={gain:.2f}x aware={aware_gus:.1f}tok/s blind={blind_gus:.1f}tok/s",
    )
    if gain < 1.25:
        raise AssertionError(
            "SLO-aware serving must reach >= 1.25x the deadline-blind "
            "goodput-under-SLO at equal aggregate KV: got "
            f"{aware_gus:.1f} vs {blind_gus:.1f} tok/s ({gain:.2f}x)"
        )

    # -- router dispatch by cluster-level SLO headroom -------------------
    # Same stamped stream over 2 replicas at the same aggregate budget;
    # ``slo`` keeps strict traffic away from replicas near a deadline
    # bust, ``lot`` balances token backlog only.
    caps = split_evenly(2 * CAPACITY, 2)
    kvs = split_evenly(2 * KV_BUDGET, 2)
    for policy in ("lot", "slo"):
        t0 = time.perf_counter()
        engines = []
        for i in range(2):
            engines.append(
                _engine(
                    llm,
                    ssms,
                    slo_aware=True,
                    capacity=caps[i],
                    kv_budget=kvs[i],
                    seed=SEED + i,
                )
            )
        router = Router(engines, RouterConfig(policy=policy, seed=SEED))
        router.submit(_workload())
        st = router.run(max_slots=2000)
        us = (time.perf_counter() - t0) * 1e6
        assert st["finished"] == N_REQ, (
            f"stream must drain: {st['finished']}/{N_REQ} finished"
        )
        slo = st["slo"]
        emit(
            f"slo_router[{policy}]",
            us,
            f"goodput_under_slo={slo['goodput_under_slo']:.1f}tok/s "
            f"attainment={slo['attainment']:.3f} "
            f"dispatch={'/'.join(map(str, st['dispatched']))} "
            f"goodput={st['aggregate_goodput_sim']:.1f}tok/s",
        )


if __name__ == "__main__":
    main(lambda n, u, d: print(f"{n},{u:.1f},{d}"))
