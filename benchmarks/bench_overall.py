"""Paper Fig. 10: overall goodput — Vanilla (homogeneous, per SSM) vs SPIN
ablations: w/o batching&pipeline, w/o pipeline, full SPIN."""

from __future__ import annotations

import time


from benchmarks.common import SSM_NAMES, VOCAB, build_zoo
from repro.core.pipeline import profile_cost_model
from repro.core.selector import LBSS, SelectorConfig
from repro.data.workloads import make_workload
from repro.serving.engine import EngineConfig, SpinEngine

N_REQ = 8
GAMMA = 4


def run_engine(llm, ssms, selector, cost, *, packed, pipeline, dataset,
               slots=40):
    ecfg = EngineConfig(gamma=GAMMA, max_len=192, capacity=N_REQ,
                        use_packed_verify=packed, use_pipeline=pipeline,
                        straggler_mitigation=False)
    eng = SpinEngine(llm, ssms, selector, ecfg, cost_model=cost)
    reqs = make_workload(dataset, N_REQ, VOCAB, seed=31, scale=0.35)
    eng.add_requests(reqs)
    stats = eng.run(max_slots=slots)
    return stats


def vanilla(llm, ssm_single, cost_j, dataset, j):
    """Homogeneous spec decoding with one SSM type (the common baseline)."""
    sel = LBSS(SelectorConfig(n_ssms=1, batch_limits=[N_REQ], alpha=1,
                              beta=1))
    from repro.core.pipeline import CostModel
    cost = CostModel(ssm_time_per_token=[cost_j.ssm_time_per_token[j]],
                     ssm_fixed=[cost_j.ssm_fixed[j]],
                     llm_fixed=cost_j.llm_fixed,
                     llm_time_per_token=cost_j.llm_time_per_token,
                     gamma=GAMMA)
    return run_engine(llm, [ssm_single], sel, cost, packed=False,
                      pipeline=False, dataset=dataset)


def main(emit):
    llm, ssms = build_zoo()
    cost = profile_cost_model(ssms, llm, GAMMA)

    for dataset in ("alpaca", "cp", "mix"):
        t0 = time.perf_counter()
        results = {}
        for j, name in enumerate(SSM_NAMES):
            s = vanilla(llm, ssms[j], cost, dataset, j)
            results[f"vanilla[{name}]"] = s["goodput_sim"]

        def spin(packed, pipeline):
            reqs = make_workload(dataset, N_REQ, VOCAB, seed=31, scale=0.35)
            sel = LBSS(SelectorConfig(
                n_ssms=len(ssms), batch_limits=[N_REQ] * len(ssms),
                alpha=6, beta=2, seed=5),
                group_of={r.rid: r.dataset for r in reqs})
            return run_engine(llm, ssms, sel, cost, packed=packed,
                              pipeline=pipeline, dataset=dataset)

        results["spin_wo_bat_pipe"] = spin(False, False)["goodput_sim"]
        results["spin_wo_pipe"] = spin(True, False)["goodput_sim"]
        results["spin_full"] = spin(True, True)["goodput_sim"]
        us = (time.perf_counter() - t0) * 1e6
        best_v = max(v for k, v in results.items() if k.startswith("van"))
        emit(f"fig10_goodput[{dataset}]", us,
             " ".join(f"{k}={v:.0f}" for k, v in results.items())
             + f" | spin_vs_best_vanilla={results['spin_full'] / best_v:.2f}x")


if __name__ == "__main__":
    main(lambda n, u, d: print(f"{n},{u:.1f},{d}"))
