"""Quantized paged KV: the ISSUE-8 acceptance benchmarks.

The tentpole's economics in two records, reduced CPU zoo (trends, not
absolute numbers — the byte accounting is backend-independent; on CPU the
"bf16" baseline stores the float32 compute dtype, so the int8 ratio here
is an upper bound on the TPU bf16 ratio of ~2x):

* **concurrent residents at a fixed physical KV byte budget** — each
  ``--kv-dtype`` gets exactly the same HBM bytes (``bytes_per_block`` x a
  fixed bf16 block count); int8/fp8 pools mint proportionally more blocks
  from the budget and therefore admit proportionally more concurrent
  requests.  Acceptance: int8 admits >= 1.8x the bf16 residents.
* **acceptance-rate delta on the mixed easy/hard workload** — quantized
  KV perturbs both the SSM drafts and the LLM verify states, so
  accept/reject outcomes may flip; greedy verification stays lossless
  (every committed token is re-derived through the LLM), so the only
  thing allowed to move is the *rate*.  Acceptance: per-token acceptance
  within 2% of bf16 for int8 (within 10% for the 3-mantissa-bit fp8).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_gamma import _zoo
from repro.core.selector import LBSS, SelectorConfig
from repro.data.workloads import make_workload
from repro.serving.engine import EngineConfig, SpinEngine, _bucket
from repro.serving.pool import PagedCachePool

VOCAB = 128
MAX_LEN = 256
BLOCK = 16
PROMPT = 40
DTYPES = ("bf16", "int8", "fp8")
BUDGET_BLOCKS_BF16 = 64          # the fixed physical budget, in bf16 blocks


def _prefill(llm, L, plen):
    row = np.zeros((1, _bucket(L)), np.int32)
    row[0, :L] = np.arange(L) % VOCAB
    return llm.prefill(jnp.asarray(row), jnp.asarray([L], jnp.int32), plen)


def bytes_per_block(cfg, kv_dtype):
    """Physical bytes of one KV block (all layers, pos/seg, and scale
    sidecars when quantized) — measured on a 2-block probe pool."""
    probe = PagedCachePool(cfg, 1, MAX_LEN, BLOCK, num_blocks=2,
                           kv_dtype=kv_dtype)
    return probe.bytes_per_block()


def bench_residents(emit, llm):
    """Concurrent PROMPT-token residents per dtype at one byte budget."""
    bpb = {d: bytes_per_block(llm.cfg, d) for d in DTYPES}
    budget = BUDGET_BLOCKS_BF16 * bpb["bf16"]
    residents = {}
    for d in DTYPES:
        nblocks = budget // bpb[d]
        pool = PagedCachePool(llm.cfg, 512, MAX_LEN, BLOCK,
                              num_blocks=nblocks, kv_dtype=d)
        _, cp = _prefill(llm, PROMPT, pool.prefill_len(_bucket(PROMPT)))
        n = 0
        while pool.can_admit(PROMPT):
            pool.insert(n, cp, PROMPT, 1)
            n += 1
        residents[d] = n
        ratio = n / max(residents["bf16"], 1)
        emit(f"quant_concurrency[kv={d},budget={budget // 1024}KiB]", 0.0,
             f"concurrency={n} blocks={nblocks} "
             f"bytes_per_block={bpb[d]} "
             f"bytes_per_token={bpb[d] // BLOCK} "
             f"ratio={ratio:.2f}x")
    return residents


def _accept_run(llm, ssms, kv_dtype):
    """One engine pass of the bench_gamma mixed stream; returns stats and
    the per-request committed tokens."""
    half = 4
    sel = LBSS(SelectorConfig(n_ssms=2, batch_limits=[half, half],
                              alpha=4, beta=2, seed=2))
    ecfg = EngineConfig(gamma=4, max_len=128, capacity=8,
                        packed_bucket=128, straggler_mitigation=False,
                        block_size=BLOCK, kv_dtype=kv_dtype)
    eng = SpinEngine(llm, ssms, sel, ecfg)
    reqs = make_workload("mix", 10, VOCAB, seed=13, scale=0.3,
                         arrival_rate=400.0)
    eng.add_requests(reqs)
    st = eng.run(max_slots=400)
    assert all(r.done for r in eng.requests.values()), "stream must drain"
    toks = {r.rid: list(r.emitted[:r.max_new])
            for r in eng.requests.values()}
    return st, toks


def bench_acceptance(emit, llm, ssms):
    """Per-token acceptance rate per dtype on the easy/hard mix."""
    rates, toks = {}, {}
    for d in DTYPES:
        t0 = time.perf_counter()
        st, toks[d] = _accept_run(llm, ssms, d)
        us = (time.perf_counter() - t0) * 1e6
        rates[d] = st["accepted_tokens"] / max(st["drafted"], 1)
        delta = abs(rates[d] - rates["bf16"]) / max(rates["bf16"], 1e-9)
        emit(f"quant_acceptance[kv={d}]", us,
             f"accepted={st['accepted_tokens']} drafted={st['drafted']} "
             f"accept_rate={rates[d]:.4f} delta_vs_bf16={delta * 100:.2f}pct "
             f"goodput={st['goodput_sim']:.1f}tok/s")
    # the committed-token contract: every dtype emits max_new tokens per
    # request (lossless greedy verification), even when the tokens differ
    for d in DTYPES:
        for rid in toks["bf16"]:
            assert len(toks[d][rid]) == len(toks["bf16"][rid]), (d, rid)
    return rates


def main(emit):
    llm, ssms = _zoo()
    residents = bench_residents(emit, llm)
    rates = bench_acceptance(emit, llm, ssms)
    ratio = residents["int8"] / max(residents["bf16"], 1)
    if ratio < 1.8:
        raise AssertionError(
            f"int8 resident ratio {ratio:.2f}x below the 1.8x bar")
    # int8 (8-bit mantissa + per-row scale) must track bf16 within 2%;
    # fp8 e4m3 keeps only 3 mantissa bits, so it gets a looser 10% bar
    for d, bar in (("int8", 0.02), ("fp8", 0.10)):
        delta = abs(rates[d] - rates["bf16"]) / max(rates["bf16"], 1e-9)
        if delta > bar:
            raise AssertionError(
                f"{d} acceptance {rates[d]:.4f} drifted "
                f"{delta * 100:.1f}% from bf16 {rates['bf16']:.4f} "
                f"(> {bar * 100:.0f}% bar)")


if __name__ == "__main__":
    main(lambda n, u, d: print(f"{n},{u:.1f},{d}"))
