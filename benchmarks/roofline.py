"""§Roofline report: reads results/dryrun_baseline.json (written by
launch/dryrun.py --all --roofline) and renders the per-(arch x shape)
three-term roofline table used in EXPERIMENTS.md."""

from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun_baseline.json")


def load(path=RESULTS):
    with open(path) as f:
        return json.load(f)


def table(records, markdown=True):
    rows = []
    for r in records:
        if r.get("multi_pod") or r.get("status") != "ok" \
                or "roofline" not in r:
            continue
        rf = r["roofline"]
        terms = {"compute": rf["t_compute_s"], "memory": rf["t_memory_s"],
                 "collective": rf["t_collective_s"]}
        dom = rf["dominant"]
        bound = max(terms.values())
        # roofline fraction: useful model flops time / bound time
        t_model = r["model_flops"] / (r["n_chips"] * 197e12)
        frac = t_model / bound if bound > 0 else 0.0
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "t_comp": terms["compute"], "t_mem": terms["memory"],
            "t_coll": terms["collective"], "dominant": dom,
            "useful": r.get("useful_flops_frac", 0.0),
            "roofline_frac": frac,
            "peak_gb": (r["memory"]["peak_bytes"] or 0) / 2**30,
        })
    return rows


def render(rows):
    hdr = ("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | "
           "bottleneck | 6ND/HLO | roofline frac | peak GB/dev |")
    sep = "|" + "---|" * 9
    out = [hdr, sep]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_comp']:.3g} | "
            f"{r['t_mem']:.3g} | {r['t_coll']:.3g} | {r['dominant']} | "
            f"{r['useful']:.2f} | {r['roofline_frac']:.3f} | "
            f"{r['peak_gb']:.2f} |")
    return "\n".join(out)


def main(emit=None):
    if not os.path.exists(RESULTS):
        print(f"(roofline: {RESULTS} not found — run "
              "python -m repro.launch.dryrun --all --roofline --json "
              "results/dryrun_baseline.json)")
        return
    rows = table(load())
    if emit:
        for r in rows:
            emit(f"roofline[{r['arch']}/{r['shape']}]", 0.0,
                 f"dom={r['dominant']} frac={r['roofline_frac']:.3f} "
                 f"t=({r['t_comp']:.3g},{r['t_mem']:.3g},"
                 f"{r['t_coll']:.3g})s peak={r['peak_gb']:.2f}GB")
    else:
        print(render(rows))


if __name__ == "__main__":
    main()
