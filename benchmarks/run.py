"""Benchmark runner — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per record.  Wall-clock numbers are
CPU (reduced models, trends); "goodput" numbers use the calibrated event
simulator (see DESIGN.md §8); full-scale numbers live in the roofline
section (compiled dry-run artifacts)."""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_batching, bench_heterogeneity,
                            bench_overall, bench_pipeline, bench_selector,
                            bench_serving, bench_verification, roofline)

    records = []

    def emit(name, us, derived):
        line = f"{name},{us:.1f},{derived}"
        records.append(line)
        print(line, flush=True)

    sections = [
        ("fig2/3 heterogeneity", bench_heterogeneity.main),
        ("fig4 batching", bench_batching.main),
        ("fig10 overall", bench_overall.main),
        ("fig11 selector", bench_selector.main),
        ("fig12 verification", bench_verification.main),
        ("fig13 pipeline", bench_pipeline.main),
        ("serving scheduler", bench_serving.main),
        ("roofline", roofline.main),
    ]
    failures = 0
    for name, fn in sections:
        print(f"# === {name} ===", flush=True)
        try:
            fn(emit)
        except Exception:                                  # noqa: BLE001
            failures += 1
            print(f"# SECTION FAILED: {name}", flush=True)
            traceback.print_exc()
    print(f"# {len(records)} records, {failures} failed sections")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
