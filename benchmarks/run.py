"""Benchmark runner — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per record and writes the same
records as machine-readable JSON to ``results/BENCH_serving.json`` (one
object per record: name / us / derived / section) so CI can track the
perf trajectory per PR.  Wall-clock numbers are CPU (reduced models,
trends); "goodput" numbers use the calibrated event simulator (see
DESIGN.md §8); full-scale numbers live in the roofline section (compiled
dry-run artifacts).

``--sections`` selects a comma-separated subset by substring (e.g.
``--sections serving,paged`` is the CI smoke set).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")
JSON_PATH = os.path.join(RESULTS_DIR, "BENCH_serving.json")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sections", default=None,
                    help="comma-separated substrings selecting sections "
                         "(default: all)")
    ap.add_argument("--json-path", default=JSON_PATH,
                    help="where to write the JSON record file")
    args = ap.parse_args(argv)

    from benchmarks import (bench_batching, bench_chunked, bench_elastic,
                            bench_gamma, bench_heterogeneity, bench_kernels,
                            bench_overall, bench_paged, bench_pipeline,
                            bench_quant, bench_router, bench_selector,
                            bench_serving, bench_slo, bench_tree,
                            bench_verification, roofline)

    records = []
    section_name = [""]

    def emit(name, us, derived):
        line = f"{name},{us:.1f},{derived}"
        records.append({"name": name, "us": round(float(us), 1),
                        "derived": str(derived),
                        "section": section_name[0]})
        print(line, flush=True)

    sections = [
        ("fig2/3 heterogeneity", bench_heterogeneity.main),
        ("fig4 batching", bench_batching.main),
        ("fig10 overall", bench_overall.main),
        ("fig11 selector", bench_selector.main),
        ("fig12 verification", bench_verification.main),
        ("fig13 pipeline", bench_pipeline.main),
        ("serving scheduler", bench_serving.main),
        ("paged kv", bench_paged.main),
        ("fused kernels", bench_kernels.main),
        ("chunked prefill", bench_chunked.main),
        ("gamma depth", bench_gamma.main),
        ("tree speculation", bench_tree.main),
        ("quant kv", bench_quant.main),
        ("router replicas", bench_router.main),
        ("elastic fleet", bench_elastic.main),
        ("slo goodput", bench_slo.main),
        ("roofline", roofline.main),
    ]
    if args.sections:
        keys = [k.strip() for k in args.sections.split(",") if k.strip()]
        sections = [(n, fn) for n, fn in sections
                    if any(k in n for k in keys)]
        if not sections:
            print(f"# no section matches {args.sections!r}")
            sys.exit(2)

    failures = 0
    for name, fn in sections:
        print(f"# === {name} ===", flush=True)
        section_name[0] = name
        try:
            fn(emit)
        except Exception:                                  # noqa: BLE001
            failures += 1
            print(f"# SECTION FAILED: {name}", flush=True)
            traceback.print_exc()
    print(f"# {len(records)} records, {failures} failed sections")

    out_dir = os.path.dirname(args.json_path)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    with open(args.json_path, "w") as f:
        json.dump({"records": records, "failed_sections": failures,
                   "sections_run": [n for n, _ in sections]}, f, indent=2)
    print(f"# wrote {args.json_path}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
