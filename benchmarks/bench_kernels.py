"""Fused speculative-step kernels: the ISSUE-7 acceptance benchmarks.

Four record groups on the reduced zoo's LLM attention geometry, all at a
decode-heavy paged config (long committed context, short speculation
window) with the SAME pool arrays (equal KV budget) for both paths:

* **autotune coverage** — run the config search for the LLM's tune keys,
  persist winners to ``results/TUNE_cache.json``, then prove dispatch
  consults it (hits, and the cold-miss default fallback).
* **verify step** — the unfused path materializes the ``(M * bs,)``
  gathered KV copy and re-reads it inside the attention launch, so one
  step moves ~3x the live-KV bytes in 2 dispatches; the fused kernel
  streams the pool blocks once in 1 launch.  The gated ``speedup`` is
  the bandwidth-model step-time ratio (bytes / HBM BW + launch
  overhead) — this host has no TPU, so CPU interpret-mode wall-clock
  (reported as ``us``, never gated) cannot show the memory-system win;
  the byte/launch counts it is computed from are measured, not assumed.
* **decode step** — same comparison for the ``(B, nb_max * bs)`` decode
  gather vs ``kernels/fused_decode``.
* **launch counts** — ``gather``/``pallas_call`` primitives counted in
  the actual jaxprs of both read paths (the launch-``reduction`` metric
  gates the dispatch-count claim, independent of the byte model).

Both kernels are additionally asserted against the ``kernels/ref.py``
oracles here — a bench run that drifts from the oracle fails loudly.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import autotune, ops
from repro.kernels import ref as R
from repro.launch.serve import build_zoo
from repro.models.layers import attention

VOCAB = 128
BLOCK = 16
NB = 12                          # live blocks per row -> ctx ~ 190
CTX = NB * BLOCK - 2             # committed context (straddles last block)
B = 8                            # decode rows
W = 4                            # speculation window (gamma)

# bandwidth-model constants (TPUv4-flavoured; only the RATIO is gated, and
# it is insensitive to the exact values while KV bytes dominate)
HBM_BW = 800e9                   # bytes/s
LAUNCH_US = 2.0                  # per-dispatch overhead


def _median_us(fn, iters=8, warmup=2):
    ts = []
    for _ in range(iters + warmup):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts[warmup:]))


def count_primitives(fn, *args):
    """Occurrences of each primitive in ``fn``'s jaxpr, recursing into
    call/closed sub-jaxprs (pjit, custom_vjp, ...)."""
    counts: dict = {}

    def sub_jaxprs(val):
        if hasattr(val, "eqns"):                  # Jaxpr
            yield val
        elif hasattr(val, "jaxpr"):               # ClosedJaxpr
            yield val.jaxpr
        elif isinstance(val, (list, tuple)):
            for v in val:
                yield from sub_jaxprs(v)

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            counts[eqn.primitive.name] = counts.get(eqn.primitive.name, 0) + 1
            if eqn.primitive.name == "pallas_call":
                continue          # the kernel body is ONE dispatch
            for val in eqn.params.values():
                for sub in sub_jaxprs(val):
                    walk(sub)

    walk(jax.make_jaxpr(fn)(*args).jaxpr)
    return counts


def _pool_state(H, Kh, D, seed=0):
    """Decode-heavy paged state: B rows x NB full-ish blocks, verified
    cohort = every row, window W."""
    rng = np.random.default_rng(seed)
    N = B * NB + 4                                 # + free blocks
    k_pool = jnp.asarray(rng.standard_normal((N, BLOCK, Kh, D)) * 0.3,
                         jnp.float32)
    v_pool = jnp.asarray(rng.standard_normal((N, BLOCK, Kh, D)) * 0.3,
                         jnp.float32)
    bt = np.full((B, NB), -1, np.int32)
    seg = np.full((N, BLOCK), -1, np.int32)
    pos = np.zeros((N, BLOCK), np.int32)
    ids, owner = [], []
    for b in range(B):
        for lb in range(NB):
            blk = b * NB + lb
            bt[b, lb] = blk
            ids.append(blk)
            owner.append(b)
            n = int(np.clip(CTX + W + 1 - lb * BLOCK, 0, BLOCK))
            seg[blk, :n] = 0
            pos[blk] = lb * BLOCK + np.arange(BLOCK)
    m = 1 << (len(ids) - 1).bit_length()
    ids += [0] * (m - len(ids))
    owner += [-1] * (m - len(owner))
    Tq = B * (W + 1)
    q = jnp.asarray(rng.standard_normal((Tq, H, D)) * 0.3, jnp.float32)
    q_seg = jnp.repeat(jnp.arange(B, dtype=jnp.int32), W + 1)
    q_pos = jnp.asarray(np.concatenate(
        [CTX + np.arange(W + 1) for _ in range(B)]).astype(np.int32))
    qd = jnp.asarray(rng.standard_normal((B, W + 1, H, D)) * 0.3,
                     jnp.float32)
    qd_seg = jnp.zeros((B, W + 1), jnp.int32)
    qd_pos = jnp.asarray(CTX + np.arange(W + 1)[None]
                         + np.zeros((B, 1), np.int32), jnp.int32)
    return dict(
        k_pool=k_pool, v_pool=v_pool, pool_seg=jnp.asarray(seg),
        pool_pos=jnp.asarray(pos), bt=jnp.asarray(bt),
        ids=jnp.asarray(np.asarray(ids, np.int32)),
        owner=jnp.asarray(np.asarray(owner, np.int32)),
        q=q, q_seg=q_seg, q_pos=q_pos,
        qd=qd, qd_seg=qd_seg, qd_pos=qd_pos, M=len(ids))


def _unfused_verify(st):
    """The ``--fused-kernels off`` read side (serving/paged.py gather +
    packed attention), as one jittable function."""
    bs = BLOCK

    @jax.jit
    def run(q, k_pool, v_pool, pool_seg, pool_pos, q_seg, q_pos, ids, owner):
        idsc = jnp.maximum(ids, 0)
        M = ids.shape[0]
        slot = ((idsc * bs)[:, None] + jnp.arange(bs)).reshape(M * bs)
        kf = k_pool.reshape(-1, *k_pool.shape[2:])
        vf = v_pool.reshape(-1, *v_pool.shape[2:])
        kg, vg = kf[slot][None], vf[slot][None]
        posg = pool_pos.reshape(-1)[slot][None]
        slot_seg = pool_seg.reshape(-1)[slot]
        segg = jnp.where((slot_seg >= 0) & (jnp.repeat(owner, bs) >= 0),
                         jnp.repeat(owner, bs), -1)[None]
        return attention(q[None], kg, vg, q_positions=q_pos[None],
                         kv_positions=posg, q_segments=q_seg[None],
                         kv_segments=segg)[0]

    return lambda: run(st["q"], st["k_pool"], st["v_pool"], st["pool_seg"],
                       st["pool_pos"], st["q_seg"], st["q_pos"], st["ids"],
                       st["owner"]), run


def _unfused_decode(st):
    """The ``--fused-kernels off`` decode read side: per-row
    ``(B, nb_max * bs)`` gather + masked attention."""
    bs = BLOCK

    @jax.jit
    def run(q, k_pool, v_pool, pool_seg, pool_pos, q_seg, q_pos, bt):
        Bn, nb = bt.shape
        slot = ((jnp.maximum(bt, 0) * bs)[:, :, None]
                + jnp.arange(bs)).reshape(Bn, nb * bs)
        kf = k_pool.reshape(-1, *k_pool.shape[2:])
        vf = v_pool.reshape(-1, *v_pool.shape[2:])
        kg, vg = kf[slot], vf[slot]
        posg = pool_pos.reshape(-1)[slot]
        segg = pool_seg.reshape(-1)[slot]
        live = jnp.repeat(bt >= 0, bs, axis=1)
        segg = jnp.where(live, segg, -1)
        return attention(q, kg, vg, q_positions=q_pos, kv_positions=posg,
                         q_segments=q_seg, kv_segments=segg)

    return lambda: run(st["qd"], st["k_pool"], st["v_pool"], st["pool_seg"],
                       st["pool_pos"], st["qd_seg"], st["qd_pos"],
                       st["bt"]), run


def _modeled_us(kv_bytes, qo_bytes, copies, launches):
    """Bandwidth-model step time: the KV stream is read ``copies`` times
    (gather read + copy write + kernel re-read = 3 for the unfused path,
    1 for the fused stream) plus per-dispatch overhead."""
    return (copies * kv_bytes + qo_bytes) / HBM_BW * 1e6 \
        + launches * LAUNCH_US


def bench_verify(emit, H, Kh, D, st, cfg):
    run_unfused, _ = _unfused_verify(st)
    fused = jax.jit(lambda: ops.fused_paged_verify(
        st["q"], st["k_pool"], st["v_pool"], st["pool_seg"], st["pool_pos"],
        st["q_seg"], st["q_pos"], st["ids"], st["owner"], config=cfg))
    oracle = R.paged_verify_ref(
        st["q"], st["k_pool"], st["v_pool"], st["pool_seg"], st["pool_pos"],
        st["q_seg"], st["q_pos"], st["ids"], st["owner"])
    err = float(jnp.max(jnp.abs(fused() - oracle)))
    if err > 2e-3:
        raise AssertionError(f"fused verify drifted from oracle: {err}")
    uu, fu = _median_us(run_unfused), _median_us(fused)
    kv_bytes = st["M"] * BLOCK * Kh * D * 4 * 2          # k + v, f32
    qo_bytes = 2 * st["q"].size * 4
    mu_u = _modeled_us(kv_bytes, qo_bytes, copies=3, launches=2)
    mu_f = _modeled_us(kv_bytes, qo_bytes, copies=1, launches=1)
    sp = mu_u / mu_f
    emit(f"kernel_verify[Tq={int(st['q'].shape[0])},M={st['M']},"
         f"bs={BLOCK}]", fu,
         f"speedup={sp:.2f}x modeled_unfused={mu_u:.1f} "
         f"modeled_fused={mu_f:.1f} wall_unfused={uu:.0f}us "
         f"wall_fused={fu:.0f}us oracle_err={err:.1e} "
         f"cfg=({cfg.bq},{cfg.bk},{cfg.depth})")
    return sp


def bench_decode(emit, H, Kh, D, st, cfg):
    run_unfused, _ = _unfused_decode(st)
    fused = jax.jit(lambda: ops.fused_paged_decode(
        st["qd"], st["k_pool"], st["v_pool"], st["pool_seg"],
        st["pool_pos"], st["qd_seg"], st["qd_pos"], st["bt"], config=cfg))
    oracle = R.paged_seq_decode_ref(
        st["qd"], st["k_pool"], st["v_pool"], st["pool_seg"],
        st["pool_pos"], st["qd_seg"], st["qd_pos"], st["bt"])
    err = float(jnp.max(jnp.abs(fused() - oracle)))
    if err > 2e-3:
        raise AssertionError(f"fused decode drifted from oracle: {err}")
    uu, fu = _median_us(run_unfused), _median_us(fused)
    kv_bytes = B * NB * BLOCK * Kh * D * 4 * 2
    qo_bytes = 2 * st["qd"].size * 4
    mu_u = _modeled_us(kv_bytes, qo_bytes, copies=3, launches=2)
    mu_f = _modeled_us(kv_bytes, qo_bytes, copies=1, launches=1)
    sp = mu_u / mu_f
    emit(f"kernel_decode[B={B},nb={NB},bs={BLOCK}]", fu,
         f"speedup={sp:.2f}x modeled_unfused={mu_u:.1f} "
         f"modeled_fused={mu_f:.1f} wall_unfused={uu:.0f}us "
         f"wall_fused={fu:.0f}us oracle_err={err:.1e} "
         f"cfg=({cfg.bq},{cfg.bk},{cfg.depth})")
    return sp


def bench_launch_counts(emit, st, vcfg, dcfg):
    """Dispatch-shape evidence measured from the jaxprs themselves."""
    _, unf_v = _unfused_verify(st)
    cv = count_primitives(
        unf_v, st["q"], st["k_pool"], st["v_pool"], st["pool_seg"],
        st["pool_pos"], st["q_seg"], st["q_pos"], st["ids"], st["owner"])
    fv = count_primitives(
        lambda q: ops.fused_paged_verify(
            q, st["k_pool"], st["v_pool"], st["pool_seg"], st["pool_pos"],
            st["q_seg"], st["q_pos"], st["ids"], st["owner"], config=vcfg),
        st["q"])
    unf = cv.get("gather", 0) + cv.get("dot_general", 0) \
        + cv.get("pallas_call", 0)
    fus = fv.get("gather", 0) + fv.get("dot_general", 0) \
        + fv.get("pallas_call", 0)
    emit("kernel_verify_dispatches", 0.0,
         f"reduction={unf / max(fus, 1):.2f}x unfused={unf} fused={fus} "
         f"(unfused: gather={cv.get('gather', 0)} "
         f"dot={cv.get('dot_general', 0)}; fused: "
         f"pallas={fv.get('pallas_call', 0)} "
         f"gather={fv.get('gather', 0)})")
    if fv.get("pallas_call", 0) != 1:
        raise AssertionError("fused verify is not a single launch")
    if fv.get("gather", 0) != 0:
        raise AssertionError("fused verify still gathers a KV copy")
    return unf / max(fus, 1)


def bench_autotune(emit, H, Kh, D):
    """Populate the cache for the zoo LLM's keys, then prove dispatch
    consults it (and that a cold key falls back to the default)."""
    autotune.CACHE_STATS.update(hits=0, misses=0)
    t0 = time.perf_counter()
    for kind, shape in (("verify", "linear"), ("verify", "tree"),
                        ("decode", "linear")):
        autotune.autotune(kind, H=H, Kh=Kh, D=D, gamma_max=2 * W,
                          block_size=BLOCK, shape=shape)
    tune_s = time.perf_counter() - t0
    vcfg = autotune.get_config("verify", H=H, Kh=Kh, D=D, gamma_max=2 * W,
                               block_size=BLOCK, shape="linear")
    dcfg = autotune.get_config("decode", H=H, Kh=Kh, D=D, gamma_max=2 * W,
                               block_size=BLOCK, shape="linear")
    hits = autotune.CACHE_STATS["hits"]
    cold = autotune.get_config("verify", H=H + 1, Kh=Kh, D=D,
                               gamma_max=2 * W, block_size=BLOCK)
    if cold != autotune.DEFAULT_CONFIG:
        raise AssertionError("cold-miss lookup did not fall back to default")
    misses = autotune.CACHE_STATS["misses"]
    n_keys = len(autotune.load_cache())
    emit("kernel_autotune", tune_s * 1e6,
         f"tuned_keys={n_keys} consult_hits={hits} cold_misses={misses} "
         f"verify_cfg=({vcfg.bq},{vcfg.bk},{vcfg.depth}) "
         f"decode_cfg=({dcfg.bq},{dcfg.bk},{dcfg.depth})")
    if hits < 2 or misses < 1:
        raise AssertionError("autotune cache was not consulted as expected")
    return vcfg, dcfg


def main(emit):
    llm, _ = build_zoo(VOCAB, seed=0, n_ssms=2)
    H, Kh, D = llm.cfg.n_heads, llm.cfg.n_kv_heads, llm.cfg.hd
    vcfg, dcfg = bench_autotune(emit, H, Kh, D)
    st = _pool_state(H, Kh, D)
    sp_v = bench_verify(emit, H, Kh, D, st, vcfg)
    bench_decode(emit, H, Kh, D, st, dcfg)
    bench_launch_counts(emit, st, vcfg, dcfg)
    if sp_v < 1.15:
        raise AssertionError(
            f"verify-step speedup {sp_v:.2f}x below the 1.15x bar")


if __name__ == "__main__":
    main(lambda n, u, d: print(f"{n},{u:.1f},{d}"))
