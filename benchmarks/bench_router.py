"""Multi-replica scaling and routing-policy comparison.

The router (serving/router.py) splits a saturating Poisson stream across
N independent engine replicas at a **fixed aggregate budget**: total pool
rows and total KV cells are constant, so the sweep isolates what
replication itself buys — N verification queues draining in parallel
instead of one.  Aggregate goodput is total accepted tokens over the
*makespan* (the slowest replica's sim clock), the honest cluster-level
figure: a replica finishing early stops contributing.

Acceptance (ISSUE 5): 2 replicas must reach >= 1.7x the single-engine
aggregate goodput on this workload.  The second half compares the three
dispatch policies (least-outstanding-tokens, power-of-two-choices on
free KV blocks, most-SLO-headroom) on the same stream stamped with the
mixed strict/lax ``interactive`` SLO profile, reporting
**goodput-under-SLO** and deadline attainment — the headline serving
metric since ISSUE 9 — alongside dispatch balance (the scaling sweep
stays unstamped, so its gate is unchanged from ISSUE 5).

Uses the untrained reduced zoo (scheduling, not acceptance quality, is
under test); model weights and jit caches are shared across replicas, so
the sweep adds no compilation cost per replica.
"""

from __future__ import annotations

import time

from repro.core.selector import LBSS, SelectorConfig
from repro.data.workloads import make_workload
from repro.launch.serve import build_zoo, split_evenly
from repro.serving.engine import EngineConfig, SpinEngine
from repro.serving.router import Router, RouterConfig

VOCAB = 128
N_REQ = 32
AGG_CAPACITY = 8  # total pool rows, split across replicas
AGG_KV = 1024  # total KV cells, split across replicas
GAMMA = 3
# ~14x the single-engine service rate (~35 req/s): saturating, but spread
# over enough sim time that least-outstanding-tokens tracks real drain
# progress instead of statically splitting an instantaneous burst
RATE = 500.0
SEED = 19


def _engines(llm, ssms, n_replicas):
    caps = split_evenly(AGG_CAPACITY, n_replicas)
    kvs = split_evenly(AGG_KV, n_replicas)
    engines = []
    for i in range(n_replicas):
        sel = LBSS(
            SelectorConfig(
                n_ssms=len(ssms),
                batch_limits=[caps[i]] * len(ssms),
                alpha=4,
                beta=2,
                seed=SEED + i,
            )
        )
        ecfg = EngineConfig(
            gamma=GAMMA,
            max_len=128,
            capacity=caps[i],
            packed_bucket=128,
            straggler_mitigation=False,
            kv_budget=kvs[i],
        )
        engines.append(SpinEngine(llm, ssms, sel, ecfg))
    return engines


def _run(llm, ssms, n_replicas, policy, slo_profile="off"):
    reqs = make_workload(
        "mix",
        N_REQ,
        VOCAB,
        seed=SEED,
        scale=0.25,
        arrival_rate=RATE,
        slo_profile=slo_profile,
        slo_scale=2.0,
    )
    router = Router(
        _engines(llm, ssms, n_replicas), RouterConfig(policy=policy, seed=SEED)
    )
    router.submit(reqs)
    st = router.run(max_slots=1500)
    assert st["finished"] == N_REQ, (
        f"stream must drain: {st['finished']}/{N_REQ} finished "
        f"(dispatch {st['dispatched']})"
    )
    return st


def main(emit):
    llm, ssms = build_zoo(VOCAB, seed=0, n_ssms=2)

    # -- replica scaling at fixed aggregate (rows, KV cells) budget ------
    goodput = {}
    for n in (1, 2, 4):
        t0 = time.perf_counter()
        st = _run(llm, ssms, n, "lot")
        us = (time.perf_counter() - t0) * 1e6
        goodput[n] = st["aggregate_goodput_sim"]
        emit(
            f"router[replicas={n}]",
            us,
            f"goodput={st['aggregate_goodput_sim']:.1f}tok/s "
            f"makespan={st['makespan_sim'] * 1e3:.1f}ms "
            f"p95_lat={st['p95_latency'] * 1e3:.1f}ms "
            f"finished={st['finished']} "
            f"dispatch={'/'.join(map(str, st['dispatched']))}",
        )
    for n in (2, 4):
        emit(
            f"router_scaling[{n}x]",
            0.0,
            f"speedup={goodput[n] / max(goodput[1], 1e-9):.2f}x "
            f"goodput={goodput[n]:.1f}tok/s base={goodput[1]:.1f}tok/s",
        )
    if goodput[2] < 1.7 * goodput[1]:
        raise AssertionError(
            "2-replica aggregate goodput must scale >= 1.7x at fixed "
            f"aggregate KV budget: got {goodput[2]:.1f} vs "
            f"{goodput[1]:.1f} tok/s ({goodput[2] / goodput[1]:.2f}x)"
        )

    # -- dispatch-policy comparison on the SLO-stamped stream ------------
    # Same arrivals/tokens as the sweep, now carrying mixed strict/lax
    # contracts (``interactive`` profile): the headline per policy is
    # goodput-under-SLO, not raw goodput.
    for policy in ("lot", "p2c", "slo"):
        t0 = time.perf_counter()
        st = _run(llm, ssms, 2, policy, slo_profile="interactive")
        us = (time.perf_counter() - t0) * 1e6
        counts = st["dispatched"]
        imbalance = max(counts) - min(counts)
        occ = [f"{x:.2f}" for x in st["peak_kv_occupancy"]]
        emit(
            f"router_policy[{policy}]",
            us,
            f"goodput_under_slo={st['slo']['goodput_under_slo']:.1f}tok/s "
            f"attainment={st['slo']['attainment']:.3f} "
            f"goodput={st['aggregate_goodput_sim']:.1f}tok/s "
            f"dispatch={'/'.join(map(str, counts))} "
            f"imbalance={imbalance} "
            f"peak_queue={max(st['peak_queue_depth'])} "
            f"peak_kv_occupancy={'/'.join(occ)}",
        )


if __name__ == "__main__":
    main(lambda n, u, d: print(f"{n},{u:.1f},{d}"))
