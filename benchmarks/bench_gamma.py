"""Adaptive vs fixed speculation depth on a mixed easy/hard workload.

The goodput lever (SpecServe, PAPERS.md): speculation depth should track
per-request acceptance.  This section builds the sharpest possible mixed
workload from real model forwards — an SSM zoo whose first member shares
the LLM's parameters (its drafts are always accepted: "easy" requests)
next to a small random-weight SSM (drafts almost never accepted: "hard"
requests), with batch caps forcing the cohort to split across both.  The
same request stream then runs through the engine twice:

* ``fixed``    — every request drafts ``GAMMA`` tokens per slot (seed
  behaviour): easy requests under-speculate, hard requests burn
  ``GAMMA + 1`` verification query tokens per ~1 committed token;
* ``adaptive`` — the gamma controller grants each request
  ``k in [1, GAMMA_MAX]`` by expected-goodput argmax over the LBSS
  acceptance estimates.

Acceptance (ISSUE 4): adaptive goodput must be >= fixed goodput on this
workload, with bit-identical emitted tokens (greedy speculative decoding
is lossless at any depth).
"""

from __future__ import annotations

import time

import jax

from repro.configs import registry
from repro.core import spec_decode as sd
from repro.core.selector import LBSS, SelectorConfig
from repro.data.workloads import make_workload
from repro.models import transformer as T
from repro.serving.engine import EngineConfig, SpinEngine

VOCAB = 128
CAPACITY = 8
GAMMA = 4
GAMMA_MAX = 8
N_REQUESTS = 10


def _zoo():
    key = jax.random.PRNGKey(0)
    cfg_llm = registry.reduced_for(
        "llama-7b", d_model=64, n_heads=4, n_kv_heads=4,
        vocab_size=VOCAB, n_layers=2,
    )
    llm = sd.Bundle(cfg_llm, T.init_params(cfg_llm, key))
    cfg_hard = registry.reduced_for(
        "llama-68m", d_model=32, n_heads=4, n_kv_heads=4,
        vocab_size=VOCAB, n_layers=1,
    )
    ssms = [
        # easy lane: shares the LLM's parameters -> acceptance ~1.0
        sd.Bundle(cfg_llm, llm.params),
        # hard lane: tiny random weights -> acceptance ~0.0
        sd.Bundle(cfg_hard, T.init_params(cfg_hard, jax.random.PRNGKey(7))),
    ]
    return llm, ssms


def _run(llm, ssms, policy):
    # batch caps force a genuine easy/hard split: only half the cohort
    # fits the perfect-draft SSM, the rest must draft on the weak one
    half = CAPACITY // 2
    sel = LBSS(
        SelectorConfig(n_ssms=2, batch_limits=[half, half], alpha=4, beta=2, seed=2)
    )
    ecfg = EngineConfig(
        gamma=GAMMA,
        gamma_policy=policy,
        gamma_max=GAMMA_MAX,
        max_len=128,
        capacity=CAPACITY,
        packed_bucket=128,
        straggler_mitigation=False,
    )
    eng = SpinEngine(llm, ssms, sel, ecfg)
    reqs = make_workload(
        "mix", N_REQUESTS, VOCAB, seed=13, scale=0.3, arrival_rate=400.0
    )
    eng.add_requests(reqs)
    st = eng.run(max_slots=400)
    assert all(r.done for r in eng.requests.values()), "stream must drain"
    # compare the committed output contract (emitted[:max_new]); the
    # overshoot tail beyond max_new varies with the final slot's depth
    emitted = {}
    for r in eng.requests.values():
        n = r.max_new
        emitted[r.rid] = list(r.emitted[:n])
    return st, emitted


def main(emit):
    llm, ssms = _zoo()
    res, toks = {}, {}
    for policy in ("fixed", "adaptive"):
        t0 = time.perf_counter()
        st, emitted = _run(llm, ssms, policy)
        us = (time.perf_counter() - t0) * 1e6
        res[policy], toks[policy] = st, emitted
        g = st["gamma"]
        emit(
            f"gamma_policy[{policy}]",
            us,
            f"goodput={st['goodput_sim']:.1f}tok/s "
            f"drafted={st['drafted']} "
            f"accepted={st['accepted_tokens']} "
            f"mean_depth={g['mean_depth']:.2f} "
            f"mean_accept={st['mean_accept']:.2f} "
            f"p95_latency={st['p95_latency'] * 1e3:.1f}ms",
        )
    if toks["adaptive"] != toks["fixed"]:
        raise AssertionError(
            "adaptive depth changed emitted tokens — speculative decoding "
            "must be lossless at any depth"
        )
    ratio = res["adaptive"]["goodput_sim"] / max(res["fixed"]["goodput_sim"], 1e-9)
    hist = res["adaptive"]["gamma"]["depth_hist"]
    emit(
        "gamma_adaptive_speedup[mixed easy/hard]",
        0.0,
        f"adaptive={res['adaptive']['goodput_sim']:.1f}tok/s "
        f"fixed={res['fixed']['goodput_sim']:.1f}tok/s "
        f"speedup={ratio:.2f}x depth_hist={hist}",
    )
    if res["adaptive"]["goodput_sim"] < res["fixed"]["goodput_sim"]:
        raise AssertionError(
            "adaptive gamma lost goodput on the mixed workload: "
            f"{res['adaptive']['goodput_sim']:.1f} vs "
            f"{res['fixed']['goodput_sim']:.1f} tok/s fixed"
        )


if __name__ == "__main__":
    main(lambda n, u, d: print(f"{n},{u:.1f},{d}"))
