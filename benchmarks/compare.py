"""Bench-regression gate: compare a benchmark run against the committed
baseline.

``benchmarks/run.py`` writes one JSON record per emitted line
(name / us / derived / section).  The ``derived`` strings carry the
actual metrics as ``key=value`` pairs (``goodput=276.0tok/s``,
``ttft_p95=12.3ms``, ``speedup=1.42x``, ...), produced by the calibrated
event simulator — deterministic for a given seed, so they are comparable
across machines.  Wall-clock ``us`` readings are machine-dependent and
are reported but never gated.

For every record name present in both files, each shared numeric metric
is classified by key:

* lower-is-better (latency-flavoured: ``ttft*``, ``stall*``, ``*latency``,
  ``*_lat``, ``*wait``, ``us``) — regression = current > baseline * (1 +
  tolerance);
* higher-is-better (throughput-flavoured: ``goodput*``, ``speedup``,
  ``reduction``, ``saving*``, ``accepted``, ``concurrency``, ...) —
  regression = current < baseline * (1 - tolerance);
* anything else is informational only.

The gate also fails when a record that exists in the baseline is missing
from the current run *for a section the current run claims to have run*
— silently dropping a benchmark must not pass CI.  Exit status: 0 clean,
1 regression(s), 2 usage/IO error.

Refreshing the baseline after an intentional perf change::

    PYTHONPATH=src python -m benchmarks.run \
        --sections serving,paged,kernels,chunked,gamma,tree,router,quant,slo,elastic \
        --json-path results/BENCH_baseline.json
"""

from __future__ import annotations

import argparse
import json
import re
import sys

DEFAULT_BASELINE = "results/BENCH_baseline.json"
DEFAULT_CURRENT = "results/BENCH_serving.json"

_NUM = re.compile(r"([A-Za-z_][\w.]*)=(-?\d+(?:\.\d+)?(?:e-?\d+)?)")

LOWER_BETTER = ("ttft", "stall", "latency", "lat", "wait", "us", "preempt")
HIGHER_BETTER = (
    "goodput",
    "attainment",
    "speedup",
    "reduction",
    "saving",
    "accepted",
    "concurrency",
    "tokens_per",
    "finished",
    # elastic fleet headline: accepted tokens per replica-second
    # provisioned (also covers cost_normalized_speedup, the
    # elastic-vs-static gate ratio)
    "cost_normalized",
)
# Explicitly directionless, checked before the pattern tables: fleet
# churn/ledger counters describe how much the elastic control plane
# acted, not a quality axis — more steals is neither a win nor a
# regression (and replica_seconds only means something relative to the
# tokens it bought, which cost_normalized_goodput already gates).
INFORMATIONAL = ("steals", "scale_ups", "scale_downs", "replica_seconds")


def parse_metrics(derived: str) -> dict:
    """Numeric key=value pairs from a derived string; trailing unit text
    after the number (``tok/s``, ``ms``, ``x``) is ignored by the regex."""
    return {k: float(v) for k, v in _NUM.findall(derived)}


def direction(key: str) -> int:
    """-1 lower-is-better, +1 higher-is-better, 0 informational."""
    k = key.lower()
    if any(k.startswith(p) for p in INFORMATIONAL):
        return 0
    if any(k.startswith(p) or k.endswith(p) for p in LOWER_BETTER):
        return -1
    if any(k.startswith(p) for p in HIGHER_BETTER):
        return +1
    return 0


def load_records(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    return data


def compare(baseline: dict, current: dict, tolerance: float):
    """Yields (section, name, key, base, cur, delta_frac, status) rows.
    status: 'ok' | 'regressed' | 'missing' | 'info'."""
    base_by_name = {r["name"]: r for r in baseline.get("records", [])}
    cur_by_name = {r["name"]: r for r in current.get("records", [])}
    sections_run = set(current.get("sections_run", []))
    rows = []
    for name, base_rec in sorted(base_by_name.items()):
        section = base_rec.get("section", "")
        if sections_run and section not in sections_run:
            continue  # section not selected this run: nothing to gate
        cur_rec = cur_by_name.get(name)
        if cur_rec is None:
            rows.append((section, name, "-", 0.0, 0.0, 0.0, "missing"))
            continue
        base_m = parse_metrics(base_rec.get("derived", ""))
        cur_m = parse_metrics(cur_rec.get("derived", ""))
        base_m["us"] = float(base_rec.get("us", 0.0))
        cur_m["us"] = float(cur_rec.get("us", 0.0))
        for key in sorted(base_m):
            if key not in cur_m:
                # a gated metric that vanished from the derived string is
                # a silent drop, not a pass — same class as a missing
                # record, one level down
                if key != "us" and direction(key) != 0:
                    rows.append(
                        (section, name, key, base_m[key], 0.0, 0.0, "missing")
                    )
                continue
            b, c = base_m[key], cur_m[key]
            delta = (c - b) / abs(b) if b else 0.0
            d = direction(key)
            if key == "us" or d == 0:
                status = "info"
            elif d < 0 and c > b * (1.0 + tolerance) and c - b > 1e-9:
                status = "regressed"
            elif d > 0 and c < b * (1.0 - tolerance) and b - c > 1e-9:
                status = "regressed"
            else:
                status = "ok"
            rows.append((section, name, key, b, c, delta, status))
    return rows


def print_table(rows, tolerance: float) -> None:
    header = (
        f"{'section':<18} {'record':<44} {'metric':<14} "
        f"{'baseline':>12} {'current':>12} {'delta':>8}  status"
    )
    print(header)
    print("-" * len(header))
    for section, name, key, b, c, delta, status in rows:
        mark = {"regressed": "FAIL", "missing": "MISS", "info": "", "ok": ""}[status]
        print(
            f"{section[:18]:<18} {name[:44]:<44} {key[:14]:<14} "
            f"{b:>12.3f} {c:>12.3f} {delta * 100:>+7.1f}%  {mark}"
        )
    print(
        f"(tolerance: ±{tolerance * 100:.0f}% on gated metrics; "
        "'us' and unclassified keys are informational)"
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "current",
        nargs="?",
        default=DEFAULT_CURRENT,
        help="bench JSON produced by benchmarks.run",
    )
    ap.add_argument(
        "--baseline",
        default=DEFAULT_BASELINE,
        help="committed baseline JSON",
    )
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.30,
        help="allowed relative slack on gated metrics "
        "(default 0.30: sim metrics are deterministic "
        "per seed but drift slightly across jax builds)",
    )
    args = ap.parse_args(argv)
    if args.tolerance < 0:
        ap.error("--tolerance must be >= 0")
    try:
        baseline = load_records(args.baseline)
        current = load_records(args.current)
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    rows = compare(baseline, current, args.tolerance)
    print_table(rows, args.tolerance)
    bad = [r for r in rows if r[6] in ("regressed", "missing")]
    if bad:
        print(f"\n{len(bad)} regression(s) vs {args.baseline}:")
        for section, name, key, b, c, delta, status in bad:
            if status == "missing":
                print(f"  {name}: record missing from current run")
            else:
                print(f"  {name}: {key} {b:.3f} -> {c:.3f} ({delta * 100:+.1f}%)")
        return 1
    gated = sum(1 for r in rows if r[6] == "ok")
    print(f"\nno regressions ({gated} gated comparisons clean)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
