"""Chunked prefill vs monolithic admission: TTFT and decode-stall.

A long prompt admitted monolithically occupies the LLM for its whole
prefill inside one slot, so every running request sees one giant
inter-token gap — exactly the batching overhead SPIN §V targets and the
reason Sarathi-style servers bound per-iteration token work.  This
section replays one fixed scenario — a cohort of short decode requests
joined mid-stream by one long prompt — through both admission modes at
the same per-slot token budget and records:

* **TTFT p50/p95** (first token committed − arrival, sim clock), and
* **decode stall**: p95 / max inter-token gap of the *short* requests,
  i.e. how badly the long prompt's admission starves everyone else.

Acceptance (ISSUE 3): chunked prefill must reduce the p95 inter-token
gap vs the monolithic path.  Uses the untrained reduced zoo (scheduling
behaviour, not acceptance quality, is under test).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.selector import LBSS, SelectorConfig
from repro.data.pipeline import _backbone, synthetic_sequence
from repro.data.workloads import Request, make_workload
from repro.launch.serve import build_zoo
from repro.serving.engine import EngineConfig, SpinEngine

VOCAB = 128
CAPACITY = 8
GAMMA = 3
N_SHORT = 6
LONG_PROMPT = 144
LONG_ARRIVAL = 0.03            # lands while the shorts are mid-decode
CHUNK = 32
TOKEN_BUDGET = CHUNK + CAPACITY * (GAMMA + 1)   # equal for both modes


def _workload(seed: int = 5):
    reqs = make_workload("cp", N_SHORT, VOCAB, seed=seed, scale=0.4)
    rng = np.random.default_rng(seed ^ 0xC0DE)
    table = _backbone(np.random.default_rng(seed ^ 0x5EED), VOCAB)
    prompt = synthetic_sequence(rng, LONG_PROMPT, VOCAB, table, 0.5)
    reqs.append(Request(rid=len(reqs), dataset="long", difficulty=0.5,
                        prompt=prompt.astype(np.int32), max_new=12,
                        arrival=LONG_ARRIVAL, emitted=[]))
    return reqs


def _run(llm, ssms, prefill_chunk: int):
    reqs = _workload()
    long_rid = reqs[-1].rid
    sel = LBSS(SelectorConfig(n_ssms=len(ssms),
                              batch_limits=[CAPACITY] * len(ssms),
                              alpha=4, beta=2, seed=2),
               group_of={r.rid: r.dataset for r in reqs})
    ecfg = EngineConfig(gamma=GAMMA, max_len=256, capacity=CAPACITY,
                        packed_bucket=128, straggler_mitigation=False,
                        prefill_chunk=prefill_chunk,
                        token_budget=TOKEN_BUDGET)
    eng = SpinEngine(llm, ssms, sel, ecfg)
    eng.add_requests(reqs)
    # drive the loop by hand to log per-request token-commit times
    commits = {r.rid: [] for r in reqs}
    emitted = {r.rid: 0 for r in reqs}
    for _ in range(600):
        rec = eng.step()
        if rec.get("done") and not eng.scheduler.outstanding:
            break
        for rid, r in eng.requests.items():
            n = len(r.emitted or [])
            if n > emitted[rid]:
                emitted[rid] = n
                commits[rid].append(eng.sim_time)
    assert all(r.done for r in eng.requests.values()), "stream must drain"
    gaps = []
    for rid, times in commits.items():
        if rid == long_rid:
            continue
        gaps.extend(np.diff(times))
    st = eng.stats()
    return {
        "ttft_p50": st["ttft_p50"],
        "ttft_p95": st["ttft_p95"],
        "stall_p95": float(np.percentile(gaps, 95)) if gaps else 0.0,
        "stall_max": float(np.max(gaps)) if gaps else 0.0,
        "goodput": st["goodput_sim"],
        "grants": st["scheduler"]["prefill_grants"],
        "long_ttft": (eng.requests[long_rid].first_token_time
                      - eng.requests[long_rid].arrival),
    }


def main(emit):
    llm, ssms = build_zoo(VOCAB, seed=0, n_ssms=2)
    res = {}
    for mode, chunk in (("monolithic", 0), ("chunked", CHUNK)):
        t0 = time.perf_counter()
        r = _run(llm, ssms, chunk)
        us = (time.perf_counter() - t0) * 1e6
        res[mode] = r
        emit(f"chunked_prefill[{mode},budget={TOKEN_BUDGET}]", us,
             f"ttft_p50={r['ttft_p50'] * 1e3:.1f}ms "
             f"ttft_p95={r['ttft_p95'] * 1e3:.1f}ms "
             f"stall_p95={r['stall_p95'] * 1e3:.1f}ms "
             f"stall_max={r['stall_max'] * 1e3:.1f}ms "
             f"long_ttft={r['long_ttft'] * 1e3:.1f}ms "
             f"goodput={r['goodput']:.1f}tok/s grants={r['grants']}")
    ratio = (res["monolithic"]["stall_p95"]
             / max(res["chunked"]["stall_p95"], 1e-9))
    emit("chunked_stall_reduction[p95 gap]", 0.0,
         f"monolithic={res['monolithic']['stall_p95'] * 1e3:.1f}ms "
         f"chunked={res['chunked']['stall_p95'] * 1e3:.1f}ms "
         f"reduction={ratio:.2f}x")
    if res["chunked"]["stall_p95"] >= res["monolithic"]["stall_p95"]:
        raise AssertionError(
            "chunked prefill did not reduce the p95 decode stall: "
            f"{res['chunked']['stall_p95']:.4f}s vs "
            f"{res['monolithic']['stall_p95']:.4f}s monolithic")


if __name__ == "__main__":
    main(lambda n, u, d: print(f"{n},{u:.1f},{d}"))
