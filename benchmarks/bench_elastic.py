"""Elastic fleet vs static fleet under a diurnal arrival curve.

The headline is **cost-normalized goodput** — accepted tokens per
replica-second *provisioned* (FleetStats in serving/stats.py) — the
number an autoscaling operator optimizes: raw goodput at half the fleet
cost doubles it, over-provisioning dilutes it.

Both fleets get the same pre-carved maximum (REPLICAS_MAX engines at the
same aggregate capacity/KV split) and the identical diurnal request
stream (data/workloads.py ``diurnal_arrivals``: sinusoidal rate between
trough and peak).  The **static** fleet keeps every replica active for
the whole run — it pays ``REPLICAS_MAX x makespan`` replica-seconds, the
fixed-pool baseline SPIN §V assumes.  The **elastic** fleet starts at
one active replica and lets the target-occupancy autoscaler follow the
curve (scale up into the peak, drain-before-retire through the trough),
with work stealing rebalancing queued requests; it pays only the
provisioned segments on the fleet ledger.

Acceptance (ISSUE 10): at equal peak replica count the elastic fleet's
cost-normalized goodput must be >= 1.3x the static fleet's on the
diurnal trace, both fleets must drain the stream completely, and a
drained replica must never retire with in-flight work (asserted against
the router's event log).  A third section exercises the heterogeneous
``prefill:1,decode:N-1`` class split on the same stream.
"""

from __future__ import annotations

import time

from repro.core.selector import LBSS, SelectorConfig
from repro.data.workloads import diurnal_arrivals, make_workload
from repro.launch.serve import build_zoo, split_evenly
from repro.serving.engine import EngineConfig, SpinEngine
from repro.serving.router import (Router, RouterConfig, class_engine_config,
                                  parse_replica_classes)

VOCAB = 128
N_REQ = 36
REPLICAS_MAX = 3
AGG_CAPACITY = 9  # total pool rows, split across the pre-carved fleet
AGG_KV = 1536  # total KV cells, split across the pre-carved fleet
GAMMA = 3
# diurnal curve: trough at a sixth of the peak.  The peak needs the
# whole fleet, the trough well under one replica, and the arrival span
# is on the order of the service makespan — load-FOLLOWING, not a
# saturated backlog (a backlogged fleet needs every replica throughout,
# and elastic == static by construction).
RATE_PEAK = 120.0
RATE_BASE = 20.0
# faster control ticks than the serving default: the whole diurnal cycle
# spans well under a second of sim time at this scale
COOLDOWN = 0.02
SEED = 23


def _arrivals():
    n = N_REQ
    period = 2.0 * n / RATE_PEAK
    return diurnal_arrivals(n, rate_base=RATE_BASE, rate_peak=RATE_PEAK,
                            period=period, seed=SEED)


def _workload():
    reqs = make_workload("mix", N_REQ, VOCAB, seed=SEED, scale=0.25)
    trace = _arrivals()
    for r, t in zip(reqs, trace):
        r.arrival = float(t)
    return reqs


def _engines(llm, ssms, classes=None):
    caps = split_evenly(AGG_CAPACITY, REPLICAS_MAX)
    kvs = split_evenly(AGG_KV, REPLICAS_MAX)
    classes = classes or ["general"] * REPLICAS_MAX
    engines = []
    for i in range(REPLICAS_MAX):
        sel = LBSS(SelectorConfig(
            n_ssms=len(ssms), batch_limits=[caps[i]] * len(ssms),
            alpha=4, beta=2, seed=SEED + i))
        base = EngineConfig(gamma=GAMMA, max_len=128, capacity=caps[i],
                            packed_bucket=128, straggler_mitigation=False,
                            kv_budget=kvs[i])
        ecfg = class_engine_config(base, classes[i])
        engines.append(SpinEngine(llm, ssms, sel, ecfg))
    return engines


def _run(llm, ssms, rcfg, classes=None):
    router = Router(_engines(llm, ssms, classes), rcfg)
    router.submit(_workload())
    st = router.run(max_slots=2000)
    assert st["finished"] == N_REQ, (
        f"stream must drain: {st['finished']}/{N_REQ} finished "
        f"(dispatch {st['dispatched']}, undispatched "
        f"{st['undispatched']})")
    return router, st


def main(emit):
    llm, ssms = build_zoo(VOCAB, seed=0, n_ssms=2)

    # -- static fleet: every replica provisioned for the whole run -------
    t0 = time.perf_counter()
    _, st_static = _run(llm, ssms, RouterConfig(policy="lot", seed=SEED))
    us = (time.perf_counter() - t0) * 1e6
    emit("elastic[static-fleet]", us,
         f"cost_normalized_goodput={st_static['cost_normalized_goodput']:.1f}"
         f"tok/s/replica goodput={st_static['aggregate_goodput_sim']:.1f}"
         f"tok/s replica_seconds={st_static['replica_seconds'] * 1e3:.1f}ms "
         f"makespan={st_static['makespan_sim'] * 1e3:.1f}ms "
         f"finished={st_static['finished']}")

    # -- elastic fleet: autoscale 1..REPLICAS_MAX on the same stream -----
    t0 = time.perf_counter()
    router, st_el = _run(llm, ssms, RouterConfig(
        policy="lot", seed=SEED, autoscale="target-occupancy",
        replicas_min=1, replicas_max=REPLICAS_MAX, cooldown=COOLDOWN))
    us = (time.perf_counter() - t0) * 1e6
    emit("elastic[autoscaled]", us,
         f"cost_normalized_goodput={st_el['cost_normalized_goodput']:.1f}"
         f"tok/s/replica goodput={st_el['aggregate_goodput_sim']:.1f}tok/s "
         f"replica_seconds={st_el['replica_seconds'] * 1e3:.1f}ms "
         f"makespan={st_el['makespan_sim'] * 1e3:.1f}ms "
         f"scale_ups={st_el['scale_ups']} "
         f"scale_downs={st_el['scale_downs']} steals={st_el['steals']}")

    # drain-before-retire: every retire event happened on a replica whose
    # scheduler reported nothing outstanding at that instant (the router
    # only flips draining->standby then); a retired replica accepting no
    # further dispatches is implied by _eligible excluding non-active
    retires = [e for e in router.events if e["event"] == "retire"]
    drains = {e["replica"] for e in router.events if e["event"] == "drain"}
    for e in retires:
        assert e["replica"] in drains, (
            f"replica {e['replica']} retired without a drain phase")

    ratio = (st_el["cost_normalized_goodput"]
             / max(st_static["cost_normalized_goodput"], 1e-9))
    emit("elastic_vs_static", 0.0,
         f"cost_normalized_speedup={ratio:.2f}x "
         f"elastic={st_el['cost_normalized_goodput']:.1f} "
         f"static={st_static['cost_normalized_goodput']:.1f}"
         f"tok/s/replica")
    if ratio < 1.3:
        raise AssertionError(
            "elastic fleet must reach >= 1.3x the static fleet's "
            f"cost-normalized goodput on the diurnal trace: got "
            f"{st_el['cost_normalized_goodput']:.1f} vs "
            f"{st_static['cost_normalized_goodput']:.1f} tok/s/replica "
            f"({ratio:.2f}x)")

    # -- heterogeneous classes: prefill:1,decode:2 on the same stream ----
    classes = parse_replica_classes("prefill:1,decode:2")
    t0 = time.perf_counter()
    _, st_cls = _run(llm, ssms, RouterConfig(
        policy="lot", seed=SEED, classes="prefill:1,decode:2"),
        classes=classes)
    us = (time.perf_counter() - t0) * 1e6
    emit("elastic[classes=prefill:1,decode:2]", us,
         f"goodput={st_cls['aggregate_goodput_sim']:.1f}tok/s "
         f"cost_normalized_goodput={st_cls['cost_normalized_goodput']:.1f}"
         f"tok/s/replica "
         f"dispatch={'/'.join(map(str, st_cls['dispatched']))} "
         f"finished={st_cls['finished']}")


if __name__ == "__main__":
    main(lambda n, u, d: print(f"{n},{u:.1f},{d}"))
