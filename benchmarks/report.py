"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the dryrun
JSONs (merges the baseline sweep and any remainder/fix-up files)."""

from __future__ import annotations

import glob
import json
import os
import sys


def load_all(paths):
    by_key = {}
    for p in paths:
        if not os.path.exists(p):
            continue
        with open(p) as f:
            for r in json.load(f):
                by_key[(r["arch"], r["shape"], r["multi_pod"])] = r
    return by_key


def dryrun_table(by_key):
    out = ["| arch | shape | 16x16 | 2x16x16 | peak GB/dev (1pod) | "
           "compile s |", "|---|---|---|---|---|---|"]
    archs, shapes = [], []
    for (a, s, mp) in by_key:
        if a not in archs:
            archs.append(a)
        if s not in shapes:
            shapes.append(s)
    for a in archs:
        for s in shapes:
            r1 = by_key.get((a, s, False))
            r2 = by_key.get((a, s, True))
            if r1 is None and r2 is None:
                continue
            def st(r):
                if r is None:
                    return "—"
                if r["status"] == "skipped":
                    return "skip"
                return "OK" if r["status"] == "ok" else "**FAIL**"
            peak = ""
            comp = ""
            if r1 and r1["status"] == "ok":
                peak = f"{r1['memory']['peak_bytes'] / 2**30:.2f}"
                comp = f"{r1.get('compile_s', 0):.0f}"
            out.append(f"| {a} | {s} | {st(r1)} | {st(r2)} | {peak} | "
                       f"{comp} |")
    return "\n".join(out)


def roofline_table(by_key):
    sys.path.insert(0, os.path.dirname(__file__))
    from roofline import render, table
    records = [r for (a, s, mp), r in by_key.items() if not mp]
    rows = table(records)
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    return render(rows)


def main():
    paths = sorted(glob.glob("results/dryrun_*.json"))
    by_key = load_all(paths)
    n_ok = sum(1 for r in by_key.values() if r["status"] == "ok")
    n_skip = sum(1 for r in by_key.values() if r["status"] == "skipped")
    n_fail = sum(1 for r in by_key.values() if r["status"] == "FAILED")
    print(f"<!-- {len(by_key)} cells: {n_ok} ok / {n_skip} skipped / "
          f"{n_fail} failed -->\n")
    print("### Dry-run matrix\n")
    print(dryrun_table(by_key))
    print("\n### Roofline (single-pod, per §Roofline terms)\n")
    print(roofline_table(by_key))


if __name__ == "__main__":
    main()
