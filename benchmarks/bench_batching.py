"""Paper Fig. 4: batching vs speculative decoding.

Throughput (LLM tokens/s, simulated-TPU cost model calibrated on the real
jitted models) vs batch size for (a) plain autoregressive batched decoding
and (b) padded-batch speculative decoding.  Reproduces the paper's
observation: vanilla spec decoding's advantage decays with batch size as
padding (ragged acceptance) grows, while plain batching keeps scaling."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import VOCAB, build_zoo
from repro.core import spec_decode as sd
from repro.data.workloads import make_workload

GAMMA = 4
ITERS = 5


def main(emit):
    llm, ssms = build_zoo()
    ssm = ssms[2]
    # simulated per-token costs from parameter counts (v5e-ish: 200 GFLOP/s
    # per small-model token at CPU scale keeps ratios right)
    c_llm = llm.cfg.params_count() / 2e9
    c_ssm = ssm.cfg.params_count() / 2e9
    rng = jax.random.PRNGKey(1)

    for B in (1, 2, 4, 8, 16):
        reqs = make_workload("mix", B, VOCAB, seed=23, scale=0.4)
        P = max(r.prompt_len for r in reqs)
        prompts = np.zeros((B, P), np.int32)
        lens = []
        for i, r in enumerate(reqs):
            prompts[i, :r.prompt_len] = r.prompt
            lens.append(r.prompt_len)
        lengths = jnp.asarray(lens, jnp.int32)
        max_len = P + ITERS * (GAMMA + 2) + 4
        toks = jnp.asarray(prompts)

        # (a) plain autoregressive batched decoding: 1 token per LLM pass
        t_plain = ITERS * (GAMMA + 1) * c_llm        # same #tokens emitted
        tok_plain = B * ITERS * (GAMMA + 1)
        thr_plain = tok_plain / t_plain

        # (b) padded-batch spec decoding (functional run for accept rates)
        t0 = time.perf_counter()
        lg, lc = llm.prefill(toks, lengths, max_len)
        _, sc = ssm.prefill(toks, lengths, max_len)
        cur = lengths
        last = jnp.take_along_axis(
            jnp.argmax(lg[..., :VOCAB], -1), (cur - 1)[:, None],
            axis=1).astype(jnp.int32)
        tokens_out = 0
        pad_cells = 0
        for it in range(ITERS):
            rng, k = jax.random.split(rng)
            out, ol, na, lc, sc, cur, last = sd.spec_iteration(
                llm, ssm, lc, sc, last, cur, GAMMA, k)
            tokens_out += int(jnp.sum(ol))
            # padding: ragged contexts aligned to the max row
            pad_cells += int(jnp.sum(jnp.max(cur) - cur))
        wall = time.perf_counter() - t0
        # verification cost scales with the PADDED batch width
        pad_factor = 1.0 + pad_cells / max(1, int(jnp.sum(cur)) * ITERS)
        t_spec = ITERS * (GAMMA * c_ssm + c_llm * pad_factor)
        thr_spec = tokens_out / t_spec
        emit(f"fig4_batch[{B}]", wall * 1e6 / max(ITERS, 1),
             f"plain={thr_plain:.0f}tok/s spec={thr_spec:.0f}tok/s "
             f"speedup={thr_spec / thr_plain:.2f}x pad_cells={pad_cells}")


if __name__ == "__main__":
    main(lambda n, u, d: print(f"{n},{u:.1f},{d}"))
