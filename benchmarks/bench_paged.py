"""Paged vs dense KV layout: the ISSUE-2 acceptance benchmarks.

Four records, all on the reduced CPU zoo (trends, not absolute numbers —
the layout asymptotics are backend-independent):

* **admission latency vs pool capacity** — dense ``insert`` functionally
  rewrites the whole ``capacity x max_len`` tree (scales with capacity);
  paged ``insert`` scatters exactly the prompt's blocks (flat in
  capacity).
* **per-step decode time** — dense attends the full ``max_len`` grid per
  row; paged gathers only the live blocks (bucketed), so step time tracks
  the live context.
* **max concurrent requests at a fixed KV-cell budget** — dense reserves
  ``max_len`` cells per row whether used or not; paged holds whole blocks
  of actual context.  Acceptance: >= 1.5x more concurrent requests.
* **bit-identical outputs** — both engine layouts on one fixed Poisson
  trace must emit exactly the same accepted tokens per request.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.selector import LBSS, SelectorConfig
from repro.data.workloads import make_workload
from repro.launch.serve import build_zoo
from repro.serving.engine import EngineConfig, SpinEngine, _bucket
from repro.serving.pool import DenseCachePool, PagedCachePool

VOCAB = 128
MAX_LEN = 256
BLOCK = 16
PROMPT = 40                      # typical live context in the workloads


def _prefill(llm, L, plen):
    row = np.zeros((1, _bucket(L)), np.int32)
    row[0, :L] = np.arange(L) % VOCAB
    return llm.prefill(jnp.asarray(row), jnp.asarray([L], jnp.int32), plen)


def _median_us(fn, iters=12, warmup=3):
    ts = []
    for i in range(iters + warmup):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts[warmup:]))


def bench_admission(emit, llm):
    """Admission (insert-into-pool) latency as pool capacity grows."""
    lat = {"dense": {}, "paged": {}}
    for capacity in (4, 16, 64):
        dense = DenseCachePool(llm.cfg, capacity, MAX_LEN)
        paged = PagedCachePool(llm.cfg, capacity, MAX_LEN, BLOCK)
        _, cache_d = _prefill(llm, PROMPT, MAX_LEN)
        _, cache_p = _prefill(llm, PROMPT, paged.prefill_len(_bucket(PROMPT)))

        def ins_dense():
            dense.insert(0, cache_d, PROMPT, 1)
            jax.block_until_ready(jax.tree.leaves(dense.cache)[0])
            dense.evict(0)

        def ins_paged():
            paged.insert(0, cache_p, PROMPT, 1)
            jax.block_until_ready(jax.tree.leaves(paged.cache)[0])
            paged.evict(0)

        lat["dense"][capacity] = _median_us(ins_dense)
        lat["paged"][capacity] = _median_us(ins_paged)
        emit(f"paged_admission[cap={capacity}]", lat["paged"][capacity],
             f"dense={lat['dense'][capacity]:.0f}us "
             f"paged={lat['paged'][capacity]:.0f}us")
    d_scale = lat["dense"][64] / max(lat["dense"][4], 1e-9)
    p_scale = lat["paged"][64] / max(lat["paged"][4], 1e-9)
    emit("paged_admission_scaling[cap 4->64]", 0.0,
         f"dense={d_scale:.2f}x paged={p_scale:.2f}x "
         f"(paged ~flat, dense ~linear in capacity)")
    return d_scale, p_scale


def bench_decode_step(emit, llm):
    """One batched decode step, context PROMPT, pool at MAX_LEN."""
    B = 8
    dense = DenseCachePool(llm.cfg, B, MAX_LEN)
    paged = PagedCachePool(llm.cfg, B, MAX_LEN, BLOCK)
    for r in range(B):
        _, cd = _prefill(llm, PROMPT, MAX_LEN)
        dense.insert(r, cd, PROMPT, 1)
        _, cp = _prefill(llm, PROMPT, paged.prefill_len(_bucket(PROMPT)))
        paged.insert(r, cp, PROMPT, 1)
        paged.ensure(r, PROMPT + 2)
    lengths = jnp.asarray(dense.lengths, jnp.int32)
    tok = jnp.asarray(dense.last_token, jnp.int32)[:, None]
    bt, _ = paged.block_table_array()

    def step_dense():
        lg, _ = llm.decode(dense.cache, tok, lengths)
        jax.block_until_ready(lg)

    def step_paged():
        lg, _ = llm.decode_paged(paged.cache, tok, lengths, bt)
        jax.block_until_ready(lg)

    du = _median_us(step_dense)
    pu = _median_us(step_paged)
    emit("paged_decode_step[B=8,ctx=40]", pu,
         f"dense={du:.0f}us paged={pu:.0f}us speedup={du / pu:.2f}x "
         f"(dense attends {MAX_LEN} cells/row, paged "
         f"{int(bt.shape[1]) * BLOCK})")


def bench_dispatch_counts(emit, llm):
    """Per-step dispatch counts of the FULL model decode step, unfused
    (XLA gather read) vs fused (single Pallas launch per attention site),
    measured from the step jaxprs — the launch-count reduction is a
    tracked metric, not just wall-clock (ISSUE 7)."""
    from benchmarks.bench_kernels import count_primitives
    from repro.kernels.autotune import DEFAULT_CONFIG
    from repro.serving.paged import decode_step_paged

    Bq = 4
    paged = PagedCachePool(llm.cfg, Bq, MAX_LEN, BLOCK)
    for r in range(Bq):
        _, cp = _prefill(llm, PROMPT, paged.prefill_len(_bucket(PROMPT)))
        paged.insert(r, cp, PROMPT, 1)
        paged.ensure(r, PROMPT + 2)
    lengths = jnp.asarray(paged.lengths, jnp.int32)
    tok = jnp.asarray(paged.last_token, jnp.int32)[:, None]
    bt, _ = paged.block_table_array()

    def step(fused_cfg):
        return lambda c, t, ln, b: decode_step_paged(
            llm.params, llm.cfg, c, tokens=t, lengths=ln, block_tables=b,
            fused_cfg=fused_cfg)[0]

    cu = count_primitives(step(None), paged.cache, tok, lengths, bt)
    cf = count_primitives(step(DEFAULT_CONFIG), paged.cache, tok,
                          lengths, bt)

    def total(c):
        return c.get("gather", 0) + c.get("dot_general", 0) \
            + c.get("pallas_call", 0)

    red = total(cu) / max(total(cf), 1)
    emit(f"paged_dispatch_per_step[B={Bq}]", 0.0,
         f"reduction={red:.2f}x unfused={total(cu)} fused={total(cf)} "
         f"(unfused: gather={cu.get('gather', 0)} "
         f"dot={cu.get('dot_general', 0)} pallas={cu.get('pallas_call', 0)}"
         f"; fused: gather={cf.get('gather', 0)} "
         f"dot={cf.get('dot_general', 0)} "
         f"pallas={cf.get('pallas_call', 0)})")
    if red <= 1.0:
        raise AssertionError(
            f"fusion did not reduce per-step dispatches ({red:.2f}x)")


def bench_concurrency(emit, llm):
    """Concurrent requests at the same physical KV-cell budget."""
    budget = 2048                           # cells of HBM for KV
    dense_cap = budget // MAX_LEN           # dense: a row IS max_len cells
    dense = DenseCachePool(llm.cfg, dense_cap, MAX_LEN)
    paged = PagedCachePool(llm.cfg, 64, MAX_LEN, BLOCK,
                           num_blocks=budget // BLOCK)
    _, cd = _prefill(llm, PROMPT, MAX_LEN)
    _, cp = _prefill(llm, PROMPT, paged.prefill_len(_bucket(PROMPT)))
    n_dense = n_paged = 0
    while dense.can_admit(PROMPT):
        dense.insert(n_dense, cd, PROMPT, 1)
        n_dense += 1
    while paged.can_admit(PROMPT):
        paged.insert(n_paged, cp, PROMPT, 1)
        n_paged += 1
    ratio = n_paged / max(n_dense, 1)
    emit("paged_concurrency[budget=2048cells,ctx=40]", 0.0,
         f"dense={n_dense} paged={n_paged} ratio={ratio:.2f}x")

    # the same cell budget re-priced in BYTES per --kv-dtype: quantized
    # pools mint more physical blocks from the identical HBM spend, so
    # the dtype-adjusted effective resident capacity scales with the
    # bytes-per-block ratio (headline numbers in benchmarks/bench_quant)
    byte_budget = (budget // BLOCK) * paged.bytes_per_block()
    for kv_dtype in ("bf16", "int8", "fp8"):
        probe = PagedCachePool(llm.cfg, 1, MAX_LEN, BLOCK, num_blocks=2,
                               kv_dtype=kv_dtype)
        eff_blocks = byte_budget // probe.bytes_per_block()
        eff_residents = int(eff_blocks) // paged.blocks_needed(PROMPT)
        emit(f"paged_concurrency_dtype[kv={kv_dtype},ctx=40]", 0.0,
             f"concurrency={eff_residents} physical_blocks={budget // BLOCK} "
             f"effective_blocks={eff_blocks} "
             f"bytes_per_block={probe.bytes_per_block()}")
    return ratio


def bench_equivalence(emit, llm, ssms):
    """Both layouts, one fixed trace: identical accepted tokens."""
    def run(layout):
        reqs = make_workload("mix", 8, VOCAB, seed=17, scale=0.25,
                             arrival_rate=200.0)
        sel = LBSS(SelectorConfig(n_ssms=len(ssms),
                                  batch_limits=[4] * len(ssms),
                                  alpha=4, beta=2, seed=3),
                   group_of={r.rid: r.dataset for r in reqs})
        ecfg = EngineConfig(gamma=3, max_len=128, capacity=4,
                            packed_bucket=128, straggler_mitigation=False,
                            kv_layout=layout, block_size=BLOCK)
        eng = SpinEngine(llm, ssms, sel, ecfg)
        eng.add_requests(reqs)
        t0 = time.perf_counter()
        st = eng.run(max_slots=600)
        wall = (time.perf_counter() - t0) * 1e6
        return eng, st, wall

    dense_eng, dense_st, dense_us = run("dense")
    paged_eng, paged_st, paged_us = run("paged")
    identical = all(
        dense_eng.requests[rid].emitted == paged_eng.requests[rid].emitted
        for rid in dense_eng.requests)
    emit("paged_equivalence[fixed trace]", paged_us,
         f"identical={identical} dense_wall={dense_us / 1e3:.0f}ms "
         f"paged_wall={paged_us / 1e3:.0f}ms "
         f"goodput_dense={dense_st['goodput_sim']:.1f} "
         f"goodput_paged={paged_st['goodput_sim']:.1f}")
    return identical


def main(emit):
    llm, ssms = build_zoo(VOCAB, seed=0, n_ssms=2)
    bench_admission(emit, llm)
    bench_decode_step(emit, llm)
    bench_dispatch_counts(emit, llm)
    ratio = bench_concurrency(emit, llm)
    identical = bench_equivalence(emit, llm, ssms)
    if ratio < 1.5:
        raise AssertionError(
            f"paged concurrency ratio {ratio:.2f}x below the 1.5x bar")
    if not identical:
        raise AssertionError("paged engine diverged from dense outputs")


if __name__ == "__main__":
    main(lambda n, u, d: print(f"{n},{u:.1f},{d}"))
