"""Tree vs linear speculation at equal KV budget (ISSUE 6).

Budget-split token trees trade chain depth for first-step coverage: a
b-branch tree spends the same ``gamma`` node budget across b chains
rooted at the drafter's top-b first-step candidates, and verifies the
whole tree in one packed pass over CoW-shared paged KV.  The win
condition is a drafter whose SECOND choice carries real probability
mass — covered here by drafting with a noise-perturbed copy of the
target model (rank-1 agreement ~0.6, rank-2 ~0.14) at a depth where
marginal chain-depth returns have decayed.

The section runs the same request stream twice (linear vs tree b=2) at
the same physical KV block budget and reports accepted tokens per
verification query token (the verify-FLOP proxy: every query row costs
one LLM forward column) plus sim-clock goodput.  Acceptance: the tree
run must win tokens-per-verify-token, with bit-identical emitted
streams (greedy tree verification is lossless).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core import spec_decode as sd
from repro.core.selector import LBSS, SelectorConfig
from repro.data.workloads import make_workload
from repro.models import transformer as T
from repro.serving.engine import EngineConfig, SpinEngine

VOCAB = 128
GAMMA = 16
BRANCHES = 2
SIGMA = 0.05  # drafter = target weights + SIGMA * per-leaf-std noise
N_REQUESTS = 8
CAPACITY = 8
KV_BUDGET = 1024


def _perturb(params, sigma, key):
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(key, len(leaves))
    out = [
        p + sigma * jnp.std(p) * jax.random.normal(k, p.shape, p.dtype)
        for p, k in zip(leaves, keys)
    ]
    return jax.tree.unflatten(treedef, out)


def _zoo():
    cfg = registry.reduced_for(
        "llama-7b", d_model=64, n_heads=4, n_kv_heads=4,
        vocab_size=VOCAB, n_layers=2,
    )
    llm = sd.Bundle(cfg, T.init_params(cfg, jax.random.PRNGKey(0)))
    ssm = sd.Bundle(cfg, _perturb(llm.params, SIGMA, jax.random.PRNGKey(9)))
    return llm, [ssm]


def _run(llm, ssms, **kw):
    sel = LBSS(
        SelectorConfig(n_ssms=1, batch_limits=[CAPACITY], alpha=4, beta=2,
                       seed=2)
    )
    ecfg = EngineConfig(
        gamma=GAMMA,
        max_len=192,
        capacity=CAPACITY,
        packed_bucket=192,
        straggler_mitigation=False,
        kv_budget=KV_BUDGET,
        block_size=16,
        **kw,
    )
    eng = SpinEngine(llm, ssms, sel, ecfg)
    reqs = make_workload("mix", N_REQUESTS, VOCAB, seed=13, scale=0.3)
    eng.add_requests(reqs)
    st = eng.run(max_slots=300)
    assert all(r.done for r in eng.requests.values()), "stream must drain"
    emitted = {r.rid: list(r.emitted[: r.max_new])
               for r in eng.requests.values()}
    return st, emitted


def main(emit):
    llm, ssms = _zoo()
    res, toks = {}, {}
    for shape, kw in (
        ("linear", {}),
        ("tree", dict(spec_shape="tree", spec_branch=BRANCHES)),
    ):
        t0 = time.perf_counter()
        st, emitted = _run(llm, ssms, **kw)
        us = (time.perf_counter() - t0) * 1e6
        res[shape], toks[shape] = st, emitted
        tpq = st["accepted_tokens"] / max(st["verify_tokens"], 1)
        emit(
            f"spec_shape[{shape}]",
            us,
            f"tokens_per_vq={tpq:.4f} "
            f"goodput={st['goodput_sim']:.1f}tok/s "
            f"accepted={st['accepted_tokens']} "
            f"verify_q={st['verify_tokens']} "
            f"forks={st.get('tree_forks', 0)} "
            f"adoptions={st.get('tree_adoptions', 0)}",
        )
    if toks["tree"] != toks["linear"]:
        raise AssertionError(
            "tree speculation changed emitted tokens — greedy tree "
            "verification must be lossless"
        )
    lin = res["linear"]["accepted_tokens"] / max(
        res["linear"]["verify_tokens"], 1
    )
    tre = res["tree"]["accepted_tokens"] / max(
        res["tree"]["verify_tokens"], 1
    )
    ratio = tre / max(lin, 1e-9)
    emit(
        "tree_accept_efficiency[b=2 vs linear, equal KV]",
        0.0,
        f"tokens_per_vq_ratio={ratio:.3f} tree={tre:.4f} linear={lin:.4f} "
        f"goodput_ratio="
        f"{res['tree']['goodput_sim'] / max(res['linear']['goodput_sim'], 1e-9):.3f}",
    )
    if tre <= lin:
        raise AssertionError(
            "tree speculation lost accepted-tokens-per-verify-token at "
            f"equal KV budget: tree={tre:.4f} vs linear={lin:.4f}"
        )


if __name__ == "__main__":
    main(lambda n, u, d: print(f"{n},{u:.1f},{d}"))
