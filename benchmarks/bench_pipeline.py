"""Paper Fig. 13: speculation/verification pipeline — goodput vs number of
micro-batches per SSM (calibrated event simulator over the real zoo's
measured latencies), with the §V-B heuristic's pick marked."""

from __future__ import annotations

import time


from benchmarks.common import build_zoo
from repro.core.pipeline import (choose_micro_batches, profile_cost_model,
                                 sweep_micro_batches)

GAMMA = 4
N_REQ = 16


def main(emit):
    llm, ssms = build_zoo()
    cost = profile_cost_model(ssms, llm, GAMMA)
    for dataset, rates in (("alpaca", [0.25, 0.4, 0.55, 0.65, 0.7]),
                           ("cp", [0.7, 0.8, 0.8, 0.85, 0.85])):
        # request placement mirroring Fig. 13's discussion: hard datasets
        # lean on the large SSMs, easy ones on the small SSMs
        if dataset == "alpaca":
            batches = [1, 2, 3, 5, 5]
        else:
            batches = [5, 5, 3, 2, 1]
        t0 = time.perf_counter()
        sweep = sweep_micro_batches(cost, batches, rates, max_mb=9)
        mb, g_h = choose_micro_batches(cost, batches, rates)
        us = (time.perf_counter() - t0) * 1e6
        best_m, best_g = max(sweep, key=lambda kv: kv[1])
        curve = " ".join(f"m{m}={g:.0f}" for m, g in sweep)
        emit(f"fig13_pipeline[{dataset}]", us,
             f"{curve} | best=m{best_m} heuristic={max(mb)}mb "
             f"({g_h / best_g:.0%} of opt)")


if __name__ == "__main__":
    main(lambda n, u, d: print(f"{n},{u:.1f},{d}"))
