"""Paper Fig. 11: SSM-selection ablation — LBSS vs Greedy(prompt-length) vs
epsilon-greedy, batching/pipeline disabled (as in the paper)."""

from __future__ import annotations

import time

from benchmarks.common import VOCAB, build_zoo
from repro.core.pipeline import profile_cost_model
from repro.core.selector import (LBSS, EpsilonGreedy, GreedyPromptLength,
                                 SelectorConfig)
from repro.data.workloads import make_workload
from repro.serving.engine import EngineConfig, SpinEngine

N_REQ = 8
GAMMA = 4


def main(emit):
    llm, ssms = build_zoo()
    cost = profile_cost_model(ssms, llm, GAMMA)
    for dataset in ("alpaca", "cp"):
        reqs = make_workload(dataset, N_REQ, VOCAB, seed=41, scale=0.35)
        plens = {r.rid: r.prompt_len for r in reqs}
        out = {}
        t0 = time.perf_counter()
        for name, mk in {
            "lbss": lambda: LBSS(SelectorConfig(
                n_ssms=len(ssms), batch_limits=[N_REQ] * len(ssms),
                alpha=6, beta=2, seed=7),
                group_of={r.rid: r.dataset for r in reqs}),
            "greedy": lambda: GreedyPromptLength(SelectorConfig(
                n_ssms=len(ssms), batch_limits=[2] * len(ssms), seed=7),
                plens),
            "eps_greedy": lambda: EpsilonGreedy(SelectorConfig(
                n_ssms=len(ssms), batch_limits=[N_REQ] * len(ssms),
                seed=7), eps=0.2),
        }.items():
            ecfg = EngineConfig(gamma=GAMMA, max_len=192, capacity=N_REQ,
                                use_packed_verify=False, use_pipeline=False,
                                straggler_mitigation=False)
            eng = SpinEngine(llm, ssms, mk(), ecfg, cost_model=cost)
            eng.add_requests(make_workload(dataset, N_REQ, VOCAB, seed=41,
                                           scale=0.35))
            stats = eng.run(max_slots=40)
            out[name] = stats["goodput_sim"]
        us = (time.perf_counter() - t0) * 1e6
        emit(f"fig11_selector[{dataset}]", us,
             " ".join(f"{k}={v:.0f}" for k, v in out.items())
             + f" | lbss_vs_greedy={out['lbss'] / max(out['greedy'], 1e-9):.2f}x")


if __name__ == "__main__":
    main(lambda n, u, d: print(f"{n},{u:.1f},{d}"))
