"""Shared benchmark infrastructure: the trained model zoo.

Reproduces the paper's setup at CPU scale: one LLM + five heterogeneous
SSMs (shape-faithful reductions of the LLaMA 68M..1.4B zoo), all trained on
the two-scale synthetic corpus so acceptance rates genuinely depend on
(SSM capacity x request difficulty) — the Fig. 2/3 phenomenon.

Models are trained once and cached under results/zoo/ (CheckpointManager);
delete that directory to retrain.
"""

from __future__ import annotations

import os
import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import spin_llama
from repro.core import spec_decode as sd
from repro.data.pipeline import TokenStream
from repro.models import transformer as T
from repro.models.config import reduced
from repro.optim import AdamW, cosine_schedule

VOCAB = 128
ZOO_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "zoo")

# (name template cfg, d_model, n_layers) — capacity ladder mirroring
# LLaMA-68M .. LLaMA-1.4B
SSM_SPECS = [
    (spin_llama.LLAMA_68M, 16, 1),
    (spin_llama.LLAMA_265M, 32, 1),
    (spin_llama.LLAMA_616M, 48, 2),
    (spin_llama.LLAMA_1_1B, 64, 2),
    (spin_llama.LLAMA_1_4B, 96, 3),
]
LLM_SPEC = (spin_llama.LLAMA_7B, 128, 3)


def _cfg(base, d, L):
    return reduced(base, d_model=d, n_layers=L, n_heads=4, n_kv_heads=4,
                   vocab_size=VOCAB, head_dim=d // 4)


def _train(cfg, steps: int, seed: int, lr=None) -> dict:
    # capacity-scaled recipe: bigger models need more steps + gentler lr
    n = cfg.params_count()
    if lr is None:
        lr = 1e-2 if n < 3e5 else 5e-3
    steps = int(steps * (1.0 + min(1.0, n / 1.5e6)))
    stream = TokenStream(seed=11, batch=16, seq_len=64, vocab=VOCAB)
    opt = AdamW(lr=cosine_schedule(lr, 30, steps), weight_decay=0.01)
    params = T.init_params(cfg, jax.random.PRNGKey(seed))
    state = opt.init(params)
    step_fn = jax.jit(T.make_train_step(cfg, opt, T.Opts(remat="none")))
    last = None
    for s in range(steps):
        toks, labels = stream.batch_at(s)
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
        params, state, metrics = step_fn(params, state, batch)
        last = float(metrics["loss"])
    print(f"  trained {cfg.name}: {cfg.n_layers}L x {cfg.d_model}d "
          f"{steps} steps, final loss {last:.3f}", flush=True)
    return params


def build_zoo(steps: int = 250, force: bool = False
              ) -> Tuple[sd.Bundle, List[sd.Bundle]]:
    """Returns (llm, [ssm_smallest .. ssm_largest]), trained + cached."""
    os.makedirs(ZOO_DIR, exist_ok=True)
    llm_cfg = _cfg(*LLM_SPEC)
    ssm_cfgs = [_cfg(*s) for s in SSM_SPECS]
    mgr = CheckpointManager(ZOO_DIR, keep=1)
    template = {
        "llm": T.abstract_params(llm_cfg),
        **{f"ssm{i}": T.abstract_params(c) for i, c in enumerate(ssm_cfgs)},
    }
    if not force and mgr.latest_step() is not None:
        try:
            trees, _ = mgr.restore(template)
            llm = sd.Bundle(llm_cfg, trees["llm"])
            ssms = [sd.Bundle(c, trees[f"ssm{i}"])
                    for i, c in enumerate(ssm_cfgs)]
            print("[zoo] restored cached models")
            return llm, ssms
        except Exception as e:                          # noqa: BLE001
            print(f"[zoo] cache miss ({e}); retraining")
    t0 = time.time()
    print("[zoo] training LLM + 5 heterogeneous SSMs on the synthetic "
          "corpus ...")
    trees = {"llm": _train(llm_cfg, int(steps * 1.5), seed=0)}
    for i, c in enumerate(ssm_cfgs):
        trees[f"ssm{i}"] = _train(c, steps, seed=i + 1)
    mgr.save(0, trees)
    print(f"[zoo] done in {time.time() - t0:.0f}s")
    llm = sd.Bundle(llm_cfg, trees["llm"])
    ssms = [sd.Bundle(c, trees[f"ssm{i}"]) for i, c in enumerate(ssm_cfgs)]
    return llm, ssms


SSM_NAMES = ["68m", "265m", "616m", "1.1b", "1.4b"]


def bench_record(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
