"""Paper Fig. 2 + Fig. 3: heterogeneous SSMs play differently per request.

For each dataset (alpaca / cp / cip) and each SSM, run homogeneous
speculative decoding per request and measure speculation speed, acceptance
rate, and goodput; report the fraction of requests for which each SSM is
the best (Fig. 2) and the per-SSM trade-off (Fig. 3)."""

from __future__ import annotations

import time
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import SSM_NAMES, VOCAB, build_zoo
from repro.core import spec_decode as sd
from repro.data.workloads import make_workload

GAMMA = 4
N_REQ = 10
ITERS = 6


def run_request(llm, ssm, prompt, rng):
    P = len(prompt)
    max_len = P + ITERS * (GAMMA + 2) + 4
    toks = jnp.asarray(np.asarray(prompt, np.int32))[None]
    lg, lc = llm.prefill(toks, jnp.asarray([P], jnp.int32), max_len)
    _, sc = ssm.prefill(toks, jnp.asarray([P], jnp.int32), max_len)
    lengths = jnp.asarray([P], jnp.int32)
    last = jnp.argmax(lg[:, P - 1, :VOCAB], -1, keepdims=True).astype(
        jnp.int32)
    accepted = 0
    t0 = time.perf_counter()
    for it in range(ITERS):
        rng, k = jax.random.split(rng)
        out, ol, na, lc, sc, lengths, last = sd.spec_iteration(
            llm, ssm, lc, sc, last, lengths, GAMMA, k)
        accepted += int(na[0])
    wall = time.perf_counter() - t0
    # simulated speed model: draft time ~ SSM params, verify ~ LLM params
    t_spec = ssm.cfg.params_count() / 2e9 * GAMMA * ITERS
    t_ver = llm.cfg.params_count() / 2e9 * ITERS
    tokens_out = accepted + ITERS
    return {
        "accept_rate": accepted / (GAMMA * ITERS),
        "goodput": tokens_out / (t_spec + t_ver),
        "wall": wall,
    }


def main(emit):
    llm, ssms = build_zoo()
    rng = jax.random.PRNGKey(0)
    for ds in ("alpaca", "cp", "cip"):
        reqs = make_workload(ds, N_REQ, VOCAB, seed=17, scale=0.4)
        best = Counter()
        per_ssm = {n: [] for n in SSM_NAMES}
        t0 = time.perf_counter()
        for r in reqs:
            scores = []
            for name, ssm in zip(SSM_NAMES, ssms):
                rng, k = jax.random.split(rng)
                res = run_request(llm, ssm, r.prompt, k)
                per_ssm[name].append(res)
                scores.append(res["goodput"])
            best[SSM_NAMES[int(np.argmax(scores))]] += 1
        us = (time.perf_counter() - t0) * 1e6 / (N_REQ * len(ssms))
        dist = " ".join(f"{n}:{best.get(n, 0) / N_REQ:.0%}"
                        for n in SSM_NAMES)
        emit(f"fig2_best_ssm_dist[{ds}]", us, dist)
        for n in SSM_NAMES:
            a = np.mean([x["accept_rate"] for x in per_ssm[n]])
            g = np.mean([x["goodput"] for x in per_ssm[n]])
            emit(f"fig3_ssm[{ds}/{n}]", us,
                 f"accept={a:.2f} goodput={g:.1f}tok/s")


if __name__ == "__main__":
    main(lambda n, u, d: print(f"{n},{u:.1f},{d}"))
