"""xlstm-350m [ssm]: 24L d=1024 4H d_ff=0 vocab=50304 — alternating sLSTM +
mLSTM blocks (unit = mLSTM, sLSTM). Attention-free: recurrent state replaces
the KV cache; long_500k runs (linear time). [arXiv:2405.04517; unverified]"""

from repro.models.config import MLSTM, SLSTM, ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    unit=(MLSTM, SLSTM),
    subquadratic=True,
)
