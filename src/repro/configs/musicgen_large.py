"""musicgen-large [audio]: 48L d=2048 32H (kv=32) d_ff=8192 vocab=2048 —
decoder-only over EnCodec tokens. Backbone only: the EnCodec frontend is a
stub; input_specs() provides precomputed frame embeddings (B, S, d_model).
[arXiv:2306.05284; hf]"""

from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    head_dim=64,
    unit=(ATTN,),
    embed_inputs=False,   # frame embeddings come from the (stubbed) frontend
)
