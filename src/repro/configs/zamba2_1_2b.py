"""zamba2-1.2b [hybrid]: 38L d=2048 32H (kv=32) d_ff=8192 vocab=32000,
ssm_state=64 — Mamba2 backbone + shared-weight attention block applied every
6th position (6 units of 5xMamba2 + shared-attn, 2 trailing Mamba2).
[arXiv:2411.15242; hf]"""

from repro.models.config import MAMBA2, SHARED_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    head_dim=64,
    ssm_state=64,
    unit=(MAMBA2, MAMBA2, MAMBA2, MAMBA2, MAMBA2, SHARED_ATTN),
    tail=(MAMBA2, MAMBA2),
    subquadratic=True,   # mostly linear-time; attention is 6/38 blocks
)
