"""Architecture registry: --arch <id> resolves here."""

from __future__ import annotations

from repro.models import config as C
from repro.configs import (dbrx_132b, internlm2_20b, internvl2_26b,
                           minitron_4b, mixtral_8x22b, musicgen_large,
                           qwen1_5_32b, qwen2_0_5b, spin_llama, xlstm_350m,
                           zamba2_1_2b)

ARCHS = {
    "mixtral-8x22b": mixtral_8x22b.CONFIG,
    "dbrx-132b": dbrx_132b.CONFIG,
    "musicgen-large": musicgen_large.CONFIG,
    "qwen2-0.5b": qwen2_0_5b.CONFIG,
    "minitron-4b": minitron_4b.CONFIG,
    "internlm2-20b": internlm2_20b.CONFIG,
    "qwen1.5-32b": qwen1_5_32b.CONFIG,
    "xlstm-350m": xlstm_350m.CONFIG,
    "zamba2-1.2b": zamba2_1_2b.CONFIG,
    "internvl2-26b": internvl2_26b.CONFIG,
    # the paper's own models
    **{m.name: m for m in spin_llama.LLMS + spin_llama.SSM_ZOO},
}

ASSIGNED = [
    "mixtral-8x22b", "dbrx-132b", "musicgen-large", "qwen2-0.5b",
    "minitron-4b", "internlm2-20b", "qwen1.5-32b", "xlstm-350m",
    "zamba2-1.2b", "internvl2-26b",
]


def get(name: str) -> C.ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def reduced_for(name: str, **overrides) -> C.ModelConfig:
    return C.reduced(get(name), **overrides)
