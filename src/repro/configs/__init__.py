from repro.configs.registry import ARCHS, get, reduced_for

__all__ = ["ARCHS", "get", "reduced_for"]
