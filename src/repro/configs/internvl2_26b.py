"""internvl2-26b [vlm]: 48L d=6144 48H (GQA kv=8) d_ff=16384 vocab=92553 —
InternViT (stubbed frontend: 256 precomputed patch embeddings prepended) +
InternLM2-20B language backbone. [arXiv:2404.16821; hf]"""

from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    head_dim=128,
    unit=(ATTN,),
    num_prefix_embeds=256,   # InternViT patch tokens per image (stub)
    rope_theta=1e6,
)
