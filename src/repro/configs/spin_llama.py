"""The paper's own model zoo (SPIN §VI-A): LLaMA LLMs (7B/13B/30B) and the
five heterogeneous SSMs (68M .. 1.4B), shape-faithful to the public configs.
These are selectable like any assigned arch and are what the SPIN benchmarks
instantiate (at reduced scale for CPU execution)."""

from repro.models.config import ATTN, ModelConfig


def _llama(name, n_layers, d_model, n_heads, d_ff, n_kv_heads=None):
    return ModelConfig(
        name=name, family="dense", n_layers=n_layers, d_model=d_model,
        n_heads=n_heads, n_kv_heads=n_kv_heads or n_heads, d_ff=d_ff,
        vocab_size=32000, unit=(ATTN,))


LLAMA_7B = _llama("llama-7b", 32, 4096, 32, 11008)
LLAMA_13B = _llama("llama-13b", 40, 5120, 40, 13824)
LLAMA_30B = _llama("llama-30b", 60, 6656, 52, 17920)

# SSM zoo (speculative models), smallest to largest.
LLAMA_68M = _llama("llama-68m", 2, 768, 12, 3072)
LLAMA_265M = _llama("llama-265m", 12, 1024, 16, 2816)
LLAMA_616M = _llama("llama-616m", 16, 1536, 16, 4096)
LLAMA_1_1B = _llama("llama-1.1b", 22, 2048, 16, 5632)
LLAMA_1_4B = _llama("llama-1.4b", 24, 2048, 32, 5504)

SSM_ZOO = [LLAMA_68M, LLAMA_265M, LLAMA_616M, LLAMA_1_1B, LLAMA_1_4B]
LLMS = [LLAMA_7B, LLAMA_13B, LLAMA_30B]
