"""Fault-tolerant checkpointing.

Design for 1000+ nodes (and tested at CPU scale here):

* Atomic: writes go to ``step_<n>.tmp/`` then os.replace() to ``step_<n>/``;
  a crash mid-write can never corrupt the latest checkpoint.
* Versioned + retention: ``latest`` is a pointer file (written last);
  ``keep`` newest checkpoints are retained.
* Async: ``save(..., blocking=False)`` hands the host transfer to a
  background thread so the train loop keeps stepping (overlap with compute).
* Elastic / resharding restore: arrays are stored UNSHARDED per leaf (numpy,
  npz per pytree leaf path); ``restore(..., shardings=...)`` re-places them
  under ANY mesh, so a job restarted on a different topology (e.g. after
  losing a pod) resumes seamlessly.  At real multi-pod scale the same
  layout maps onto a distributed filesystem; per-leaf files keep writes
  parallel across hosts.
* Self-describing: a JSON manifest stores the step, leaf paths and dtypes.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        out.append((key, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self.save_failures = 0

    # ------------------------------------------------------------- save --
    def save(self, step: int, tree: Any, blocking: bool = True,
             max_retries: int = 3):
        host_tree = jax.tree.map(np.asarray, tree)   # device -> host copy

        def _write():
            for attempt in range(max_retries):
                try:
                    self._write_once(step, host_tree)
                    return
                except OSError:
                    self.save_failures += 1
                    time.sleep(0.01 * (attempt + 1))
            raise RuntimeError(f"checkpoint save failed after "
                               f"{max_retries} retries")

        # a still-running async save may be writing this very step's tmp
        # dir (e.g. the loop's periodic async save of the final step
        # followed by the shutdown blocking save): serialize with it
        # first, or the two writers race on rmtree/makedirs/replace
        self.wait()
        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def _write_once(self, step: int, host_tree):
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": []}
        for key, leaf in _flatten_with_paths(host_tree):
            fn = key.replace("/", "__") + ".npy"
            arr = np.asarray(leaf)
            dtype_name = str(arr.dtype)
            if dtype_name == "bfloat16":       # npy can't round-trip bf16
                arr = arr.view(np.uint16)
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"].append(
                {"key": key, "file": fn, "dtype": dtype_name})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        # pointer file written LAST -> atomic latest
        with open(os.path.join(self.dir, "latest.tmp"), "w") as f:
            f.write(str(step))
        os.replace(os.path.join(self.dir, "latest.tmp"),
                   os.path.join(self.dir, "latest"))
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore --
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.dir, "latest")
        if not os.path.exists(p):
            steps = self.all_steps()
            return steps[-1] if steps else None
        with open(p) as f:
            step = int(f.read().strip())
        if not os.path.exists(os.path.join(self.dir, f"step_{step}")):
            steps = self.all_steps()           # pointer ahead of a crash
            return steps[-1] if steps else None
        return step

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``template``.  If ``shardings`` is
        given (a pytree of NamedSharding, possibly for a DIFFERENT mesh than
        the one the checkpoint was written under), leaves are placed with
        jax.device_put — this is the elastic-resharding path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_key = {l["key"]: (l["file"], l["dtype"])
                  for l in manifest["leaves"]}
        flat = _flatten_with_paths(template)
        shard_flat = (jax.tree.leaves(shardings) if shardings is not None
                      else [None] * len(flat))
        out = []
        for (key, leaf), sh in zip(flat, shard_flat):
            fn, dtype_name = by_key[key]
            arr = np.load(os.path.join(d, fn))
            if dtype_name == "bfloat16":
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr))
        treedef = jax.tree.structure(template)
        return jax.tree.unflatten(treedef, out), step
