"""AdamW + LR schedules, pure JAX (no optax in this environment).

Moments are stored in f32 regardless of param dtype (mixed-precision master
statistics); weight decay is decoupled.  Global-norm clipping is fused into
the update.  The optimizer state shards exactly like the parameters (the
sharding rule table maps the same logical axes), giving ZeRO-style
partitioned optimizer state under the `data` mesh axis for free.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def linear_warmup(peak_lr: float, warmup: int) -> Callable:
    def fn(step):
        return peak_lr * jnp.minimum(1.0, (step + 1) / max(warmup, 1))
    return fn


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1) -> Callable:
    def fn(step):
        warm = (step + 1) / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return peak_lr * jnp.minimum(warm, cos)
    return fn


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> AdamWState:
        def f32(p):
            return jnp.zeros(p.shape, jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(f32, params),
                          nu=jax.tree.map(f32, params))

    def abstract_state(self, abstract_params) -> AdamWState:
        def f32(p):
            return jax.ShapeDtypeStruct(p.shape, jnp.float32)
        return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                          mu=jax.tree.map(f32, abstract_params),
                          nu=jax.tree.map(f32, abstract_params))

    def update(self, params, grads, state: AdamWState):
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr

        if self.clip_norm:
            gnorm = jnp.sqrt(sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
        else:
            scale = 1.0

        b1, b2 = self.b1, self.b2
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / c1
            vhat = v / c2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2 and self.weight_decay:   # no decay on norms/biases
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state.mu)
        flat_v = tdef.flatten_up_to(state.nu)
        out = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, AdamWState(step=step, mu=new_m, nu=new_v)
