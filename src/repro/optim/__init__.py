from repro.optim.adamw import AdamW, cosine_schedule, linear_warmup

__all__ = ["AdamW", "cosine_schedule", "linear_warmup"]
