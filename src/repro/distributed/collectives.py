"""Cross-pod (DCN) collective helpers: gradient compression.

At multi-pod scale the `pod` axis rides DCN (~25 GB/s/host vs 50+ GB/s/link
ICI), so the cross-pod gradient all-reduce is the straggler.  Two standard
tricks, implemented as drop-in reductions for shard_map over the pod axis:

* int8 quantized all-reduce: per-tensor symmetric scale, ~4x wire saving,
  with optional error-feedback residual (Seide et al.) carried by the
  caller across steps.
* top-k sparsification: send only the k largest-|g| entries (values +
  indices), accumulate the rest into the residual.

CPU-testable without any mesh (quantize/dequantize are pure functions).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax


def quantize_int8(x) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum_int8(x, axis_name: str, residual=None):
    """int8-quantized psum over `axis_name` (inside shard_map).  Returns
    (reduced, new_residual).  Error feedback: the quantization error is
    returned for the caller to add to the next step's gradient."""
    if residual is not None:
        x = x + residual
    q, scale = quantize_int8(x)
    deq = dequantize_int8(q, scale)
    new_residual = x - deq
    # wire format: int8 payload + f32 scale (psum of dequantized values is
    # mathematically what a scale-exchanging ring implements)
    reduced = lax.psum(deq, axis_name)
    return reduced, new_residual


def topk_sparsify(x, frac: float = 0.01):
    """Keep the top-|frac| entries by magnitude; returns (sparse_x, mask)."""
    flat = x.reshape(-1)
    k = max(1, int(flat.size * frac))
    thresh = lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(x) >= thresh
    return jnp.where(mask, x, 0.0), mask


def compressed_psum_topk(x, axis_name: str, frac: float = 0.01,
                         residual=None):
    if residual is not None:
        x = x + residual
    sparse, mask = topk_sparsify(x, frac)
    new_residual = x - sparse
    reduced = lax.psum(sparse, axis_name)
    return reduced, new_residual
