"""Distribution: logical-axis sharding rules, mesh helpers, collectives."""
