"""Logical-axis sharding (MaxText-style rule table).

Every parameter / activation / cache dimension carries a *logical* axis name
(see models/params.py specs and ``constrain`` call sites).  A rule table maps
logical names to mesh-axis candidates; assignment is greedy by priority with
divisibility checks, so one table serves every architecture (e.g. kv_heads=8
cannot shard over model=16 -> the cache sequence dim takes the model axis
instead).  Hillclimbing sharding = swapping rule tables (see launch/dryrun).

``constrain`` is a no-op outside an active rule context, so model code runs
unchanged in single-device CPU tests.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

Candidate = Optional[Tuple[str, ...]]     # mesh axes for one dim (None = repl)
Rules = Dict[str, List[Candidate]]

# Lower priority = assigned first (gets first pick of mesh axes).
PRIORITY: Dict[str, int] = {
    "batch": 10, "act_batch": 10, "cache_batch": 10,
    "vocab": 20, "heads": 20, "kv_heads": 22, "experts": 20, "mlp": 24,
    "ssm_in": 20, "ssm_inner": 20, "ssm_conv": 20, "xl_up": 20,
    "xl_inner": 26, "xl_inner2": 20, "ssm_heads": 20,
    "embed": 30, "act_embed": 30, "exp_embed": 30,
    "cache_seq": 40, "seq": 45, "exp_cap": 18,
}
DEFAULT_PRIORITY = 50


def _axes_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes]))


def assign_spec(rules: Rules, dims: Sequence[Optional[str]],
                shape: Sequence[int], mesh: Mesh) -> PartitionSpec:
    """Pick mesh axes per dim: greedy by priority, divisibility-checked,
    each mesh axis used at most once."""
    order = sorted(range(len(dims)),
                   key=lambda i: PRIORITY.get(dims[i] or "", DEFAULT_PRIORITY))
    used: set = set()
    chosen: List[Candidate] = [None] * len(dims)
    for i in order:
        name = dims[i]
        if name is None:
            continue
        for cand in rules.get(name, [None]):
            if cand is None:
                break
            cand = tuple(cand)
            if any(a in used for a in cand):
                continue
            if any(a not in mesh.shape for a in cand):
                continue
            if shape[i] % _axes_size(mesh, cand) != 0:
                continue
            chosen[i] = cand
            used.update(cand)
            break
    parts = [c if c is None else (c[0] if len(c) == 1 else c) for c in chosen]
    return PartitionSpec(*parts)


# Rule tables ---------------------------------------------------------------

def train_rules(multi_pod: bool = False) -> Rules:
    dp = ("pod", "data") if multi_pod else ("data",)
    return {
        # activations
        "batch": [dp, ("data",), None],
        "seq": [None],
        "act_embed": [None],
        "exp_cap": [dp, ("data",), None],
        # weights: FSDP over data, TP over model
        "embed": [("data",), None],
        "exp_embed": [("data",), None],
        "vocab": [("model",), None],
        "heads": [("model",), None],
        "kv_heads": [("model",), None],
        "mlp": [("model",), None],
        "experts": [("model",), None],
        "ssm_in": [("model",), None],
        "ssm_inner": [("model",), None],
        "ssm_conv": [("model",), None],
        "xl_up": [("model",), None],
        "xl_inner": [("data",), None],
        "xl_inner2": [("model",), None],
        "ssm_heads": [("model",), None],
        # caches (unused in train)
        "cache_batch": [dp, ("data",), None],
        "cache_seq": [None],
    }


def serve_rules(multi_pod: bool = False) -> Rules:
    """Inference: batch DP over (pod,)data; TP over model; KV cache sharded
    over batch x (kv_heads | seq)."""
    r = train_rules(multi_pod)
    r.update({
        "cache_seq": [("model",), None],     # used when kv_heads can't shard
        "kv_heads": [("model",), None],
        "seq": [None],
    })
    return r


# Hillclimb rule variants (see EXPERIMENTS.md §Perf) ------------------------

def train_rules_seqparallel(multi_pod: bool = False) -> Rules:
    """Megatron-style sequence parallelism: residual-stream activations are
    sharded over `model` along the sequence axis, so norms/elementwise ops
    and their HBM traffic shrink by the TP degree (all-gather moves to the
    attention/mlp boundary)."""
    r = train_rules(multi_pod)
    r["seq"] = [("model",), None]
    return r


def train_rules_noremat_zero1(multi_pod: bool = False) -> Rules:
    """ZeRO-1 style: parameters replicated over data (only optimizer state
    sharded), showing what FSDP weight-sharding buys (baseline ablation)."""
    r = train_rules(multi_pod)
    for k in ("embed", "xl_inner"):
        r[k] = [None]
    return r


def serve_rules_seqshard(multi_pod: bool = False) -> Rules:
    """Flash-decode style: KV cache sequence sharded over `model` (for GQA
    archs whose kv_heads don't divide the TP degree); partial softmax is
    combined by XLA's reduction collectives."""
    r = serve_rules(multi_pod)
    r["cache_seq"] = [("model",), None]
    r["kv_heads"] = [None]
    return r


def serve_rules_batch_model(multi_pod: bool = False) -> Rules:
    """Decode batch sharded over BOTH data and model axes (weights fully
    replicated over model): trades weight memory for zero TP collectives in
    the per-token matmuls."""
    r = serve_rules(multi_pod)
    r["batch"] = [("data", "model"), ("data",), None]
    r["cache_batch"] = [("data", "model"), ("data",), None]
    for k in ("heads", "kv_heads", "mlp", "experts", "vocab", "ssm_in",
              "ssm_inner", "ssm_conv", "xl_up", "xl_inner2", "ssm_heads"):
        r[k] = [None]
    return r


def serve_rules_zero1(multi_pod: bool = False) -> Rules:
    """Inference: weights replicated over `data` (TP-only sharding) — kills
    the per-layer FSDP weight all-gathers at the cost of weight memory;
    viable when params/TP-degree fits HBM."""
    r = serve_rules(multi_pod)
    for k in ("embed", "exp_embed", "xl_inner"):
        r[k] = [None]
    return r


def serve_rules_attn_repl(multi_pod: bool = False) -> Rules:
    """MoE serving hybrid: small attention/router weights replicated over
    `data` (no per-layer gather); the big expert tensors stay FSDP-sharded
    (their gather is unavoidable without weight quantization)."""
    r = serve_rules(multi_pod)
    r["embed"] = [None]          # attention + embedding tables replicated
    r["exp_embed"] = [("data",), None]
    return r


def serve_rules_seq_data(multi_pod: bool = False) -> Rules:
    """Long-context prefill: shard the SEQUENCE over `data` (context/ring
    style) instead of batch — for cells where batch < data axis."""
    r = serve_rules(multi_pod)
    r["seq"] = [("data",), None]
    r["cache_seq"] = [("data",), ("model",), None]
    return r


RULE_VARIANTS = {
    "train": train_rules,
    "serve": serve_rules,
    "train_seqparallel": train_rules_seqparallel,
    "train_zero1": train_rules_noremat_zero1,
    "serve_seqshard": serve_rules_seqshard,
    "serve_batch_model": serve_rules_batch_model,
    "serve_zero1": serve_rules_zero1,
    "serve_attn_repl": serve_rules_attn_repl,
    "serve_seq_data": serve_rules_seq_data,
}


# Context -------------------------------------------------------------------

class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Optional[Rules] = None


_CTX = _Ctx()


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: Rules):
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def active() -> bool:
    return _CTX.mesh is not None


def constrain(x, *dims: Optional[str]):
    """with_sharding_constraint through the active rule table (no-op if none).
    Trailing dims not named are treated as replicated."""
    if _CTX.mesh is None or _CTX.rules is None:
        return x
    names = list(dims) + [None] * (x.ndim - len(dims))
    spec = assign_spec(_CTX.rules, names, x.shape, _CTX.mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, spec))


# Sharding trees ------------------------------------------------------------

def sharding_tree(mesh: Mesh, rules: Rules, axes_tree, abstract_tree):
    """NamedSharding pytree for jit in_/out_shardings.

    axes_tree: tree of logical-axis tuples (same structure as abstract_tree).
    abstract_tree: tree of ShapeDtypeStructs (for divisibility checks).
    """
    def one(axes, ab):
        return NamedSharding(mesh, assign_spec(rules, axes, ab.shape, mesh))
    return jax.tree.map(one, axes_tree, abstract_tree,
                        is_leaf=lambda a: isinstance(a, tuple) and all(
                            isinstance(e, (str, type(None))) for e in a))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, PartitionSpec())


def replica_sharding_trees(submeshes: Sequence[Mesh], rules: Rules,
                           axes_tree, abstract_tree) -> List:
    """Per-replica NamedSharding pytrees for multi-replica serving: the
    same rule table applied over each replica's sub-mesh (from
    ``launch.mesh.replica_submeshes``).  Rule tables never name the
    ``replica`` axis — replicas are full parameter copies, and each
    sub-mesh only exposes the remaining axes, so divisibility checks and
    axis assignment behave exactly as on a single-replica mesh.  Placing
    one copy of the params with each returned tree materialises the
    replicated-over-replica layout without any cross-replica collective.
    """
    for m in submeshes:
        if "replica" in m.shape:
            raise ValueError(
                "sub-mesh still carries a 'replica' axis — carve with "
                "launch.mesh.replica_submeshes before building shardings")
    return [sharding_tree(m, rules, axes_tree, abstract_tree)
            for m in submeshes]
