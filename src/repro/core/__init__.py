"""SPIN's contribution: heterogeneous speculative decoding.

  spec_decode  draft / verify / accept-reject primitives (lossless)
  selector     learning-based SSM selection (LBSS, paper Alg. 1+2) + baselines
  decompose    request decomposition for fast batch verification (paper SV-A)
  pipeline     micro-batch speculation/verification pipelining (paper SV-B)
  switching    fast SSM switching via destination KV pre-compute (paper SIV-C)
"""
