"""Speculation/verification pipelining via micro-batches (paper §V-B).

Heterogeneous SSMs finish drafting at different times; without pipelining
the LLM idles until the slowest SSM completes (paper Fig. 6a).  SPIN splits
each SSM's batch into micro-batches: as soon as a micro-batch's draft is
done it queues for LLM verification while the SSM drafts the next one
(Fig. 6b).

Two layers here:

* an *event-time simulator* (deterministic, host-side): given per-SSM draft
  time models and an LLM verification time model, compute the makespan and
  LLM idle time of a micro-batched schedule.  This is the "offline profile"
  the paper uses to evaluate splits without running them.

* the paper's split heuristic: start at b0 = 2 micro-batches per SSM and
  keep increasing while simulated throughput does not degrade by more than
  ``tol``; stop at the first significant drop (§V-B).

On real TPU deployments the schedule is realized by dispatching draft and
verify computations to disjoint device groups (serving/engine.py); JAX's
async dispatch overlaps them exactly as simulated.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional, Sequence, Tuple


@dataclasses.dataclass
class CostModel:
    """Simple latency models (seconds).  Defaults follow the shape of the
    paper's measurements: drafting ~ linear in batch and SSM size;
    verification ~ affine in query tokens + attention KV cells (so padded
    vs decomposed-packed KV grids cost differently, paper §V-A)."""
    ssm_time_per_token: Sequence[float]      # per SSM: sec per drafted token
    ssm_fixed: Sequence[float]               # per SSM: launch overhead
    llm_fixed: float                         # verification launch overhead
    llm_time_per_token: float                # sec per (gamma+1) query token
    gamma: int = 4                           # default draft depth per request
    llm_time_per_kv_cell: float = 0.0        # sec per attended KV cell

    def draft_time(self, ssm: int, batch: int,
                   tokens: Optional[float] = None) -> float:
        """Draft latency for ``batch`` requests; ``tokens`` overrides the
        total drafted-token count (per-request adaptive depths make it
        != batch * gamma)."""
        if batch <= 0:
            return 0.0
        if tokens is None:
            tokens = batch * self.gamma
        return self.ssm_fixed[ssm] + self.ssm_time_per_token[ssm] * tokens

    def verify_time(self, batch: int, kv_cells: float = 0.0,
                    q_tokens: Optional[float] = None) -> float:
        """Verification latency; ``q_tokens`` overrides the LLM query-token
        count (ragged depths: Σ (k_i + 1) instead of batch * (gamma+1))."""
        if batch <= 0:
            return 0.0
        if q_tokens is None:
            q_tokens = batch * (self.gamma + 1)
        return (self.llm_fixed
                + self.llm_time_per_token * q_tokens
                + self.llm_time_per_kv_cell * kv_cells)

    def prefill_time(self, tokens: int, kv_cells: float = 0.0) -> float:
        """LLM time to ingest prompt tokens (monolithic admission or the
        slot's chunk grants): affine in query tokens plus the attended
        KV cells, same per-token rates as verification — prefill queries
        run through the identical forward."""
        if tokens <= 0:
            return 0.0
        return (self.llm_fixed + self.llm_time_per_token * tokens
                + self.llm_time_per_kv_cell * kv_cells)


@dataclasses.dataclass
class SimResult:
    makespan: float
    llm_busy: float
    llm_idle_frac: float
    per_ssm_finish: List[float]


def _per_req(val, j: int, default: float = 0.0) -> float:
    """Per-request quantity for SSM j, given either a scalar (uniform
    across SSMs) or a per-SSM sequence.

    Continuous batching makes per-slot batches ragged: each SSM drafts for
    however many requests are currently assigned to it, and those requests
    have genuinely different context lengths (``kv_cells_per_req``) and —
    with the goodput-aware gamma controller — genuinely different draft
    depths (``depth_per_req``)."""
    if val is None:
        return default
    if isinstance(val, (int, float)):
        return float(val)
    return float(val[j])


def simulate(cost: CostModel, ssm_batches: Sequence[int],
             micro_batches: Sequence[int],
             kv_cells_per_req=0.0, prefill_time: float = 0.0,
             depth_per_req=None, verify_extra_per_req=None) -> SimResult:
    """Event-time simulation of one speculation+verification iteration.

    ssm_batches[j]: requests drafted on SSM j.  micro_batches[j]: number of
    micro-batches SSM j splits into.  The LLM verifies micro-batches FIFO as
    they become ready; verification of micro-batch m overlaps drafting of
    m+1 (paper Fig. 6b).  kv_cells_per_req: attended KV cells per request —
    scalar (padded grid, §V-A) or per-SSM sequence (ragged per-slot batches
    under continuous batching).  depth_per_req: draft depth per request —
    scalar or per-SSM sequence of mean granted depths (the gamma
    controller makes speculation depth a per-request quantity; default
    cost.gamma reproduces the uniform-depth model).  prefill_time: LLM
    time spent ingesting prompt tokens this slot (chunked-prefill grants
    or a monolithic admission); it occupies the LLM before any
    verification starts, while SSM drafting proceeds concurrently — the
    interleaving a token-budget step planner exists to bound.
    verify_extra_per_req: extra LLM query tokens per request beyond the
    linear k+1 — tree speculation verifies one root copy per branch, so
    a b-branch tree costs ``k + b`` query tokens (extra = b - 1); the
    default 0 reproduces the linear model exactly."""
    ready: List[Tuple[float, int, int]] = []   # (ready_time, ssm, size)
    finish = [0.0] * len(ssm_batches)
    for j, (bj, mj) in enumerate(zip(ssm_batches, micro_batches)):
        if bj <= 0:
            continue
        kj = _per_req(depth_per_req, j, cost.gamma)
        mj = max(1, min(mj, bj))
        sizes = [bj // mj + (1 if r < bj % mj else 0) for r in range(mj)]
        t = 0.0
        for sz in sizes:
            t += cost.draft_time(j, sz, tokens=sz * kj)
            heapq.heappush(ready, (t, j, sz))
        finish[j] = t
    llm_t = max(0.0, float(prefill_time))
    busy = llm_t
    while ready:
        rt, j, sz = heapq.heappop(ready)
        start = max(llm_t, rt)
        kj = _per_req(depth_per_req, j, cost.gamma)
        vx = _per_req(verify_extra_per_req, j)
        dur = cost.verify_time(sz, _per_req(kv_cells_per_req, j) * sz,
                               q_tokens=sz * (kj + 1 + vx))
        llm_t = start + dur
        busy += dur
    makespan = llm_t
    idle = 1.0 - busy / makespan if makespan > 0 else 0.0
    return SimResult(makespan=makespan, llm_busy=busy, llm_idle_frac=idle,
                     per_ssm_finish=finish)


def goodput_estimate(cost: CostModel, ssm_batches: Sequence[int],
                     micro_batches: Sequence[int],
                     accept_rates: Sequence[float],
                     kv_cells_per_req=0.0, depth_per_req=None,
                     verify_extra_per_req=None) -> float:
    """Accepted tokens per second for one iteration under the schedule."""
    sim = simulate(cost, ssm_batches, micro_batches, kv_cells_per_req,
                   depth_per_req=depth_per_req,
                   verify_extra_per_req=verify_extra_per_req)
    if sim.makespan <= 0:
        return 0.0
    tokens = sum(b * (a * _per_req(depth_per_req, j, cost.gamma) + 1.0)
                 for j, (b, a) in enumerate(zip(ssm_batches, accept_rates)))
    return tokens / sim.makespan


def choose_micro_batches(cost: CostModel, ssm_batches: Sequence[int],
                         accept_rates: Sequence[float], *, b0: int = 2,
                         tol: float = 0.02, max_mb: int = 16,
                         kv_cells_per_req=0.0,
                         depth_per_req=None,
                         verify_extra_per_req=None) -> Tuple[List[int], float]:
    """Paper §V-B heuristic: iteratively split each SSM's batch further while
    the (offline-profiled) throughput does not significantly degrade."""
    n = len(ssm_batches)
    mb = [1] * n
    best = goodput_estimate(cost, ssm_batches, mb, accept_rates,
                            kv_cells_per_req, depth_per_req,
                            verify_extra_per_req)
    cur = [min(b0, max(1, b)) for b in ssm_batches]
    cur_g = goodput_estimate(cost, ssm_batches, cur, accept_rates,
                             kv_cells_per_req, depth_per_req,
                             verify_extra_per_req)
    if cur_g >= best * (1 - tol):
        mb, best = cur, max(best, cur_g)
        while max(mb) < max_mb:
            nxt = [min(m + 1, max(1, b)) for m, b in zip(mb, ssm_batches)]
            if nxt == mb:
                break
            g = goodput_estimate(cost, ssm_batches, nxt, accept_rates,
                                 kv_cells_per_req, depth_per_req,
                                 verify_extra_per_req)
            if g < best * (1 - tol):        # significant degradation: stop
                break
            if g > best:
                best = g
            mb = nxt
    return mb, best


def sweep_micro_batches(cost: CostModel, ssm_batches: Sequence[int],
                        accept_rates: Sequence[float], max_mb: int = 10
                        ) -> List[Tuple[int, float]]:
    """Goodput for m = 1..max_mb uniform micro-batches (paper Fig. 13)."""
    out = []
    for m in range(1, max_mb + 1):
        g = goodput_estimate(cost, ssm_batches, [m] * len(ssm_batches),
                             accept_rates)
        out.append((m, g))
    return out


def profile_cost_model(ssm_bundles, llm_bundle, gamma: int,
                       sample_batch: int = 2, sample_len: int = 32
                       ) -> CostModel:
    """Offline profiling (paper: 'we can offline profile the inference
    throughput of the LLM with different workloads').  Measures wall-clock
    draft/verify latency of the actual jitted models on this host."""
    import time
    import jax
    import jax.numpy as jnp

    def _time(fn, *a):
        fn(*a)                     # compile
        t0 = time.perf_counter()
        for _ in range(3):
            out = fn(*a)
        jax.block_until_ready(jax.tree.leaves(out)[0])
        return (time.perf_counter() - t0) / 3

    per_tok, fixed = [], []
    for b in ssm_bundles:
        toks = jnp.zeros((sample_batch, sample_len), jnp.int32)
        _, cache = b.prefill(toks, jnp.full((sample_batch,), sample_len,
                                            jnp.int32), sample_len + gamma + 2)
        lengths = jnp.full((sample_batch,), sample_len, jnp.int32)
        t = _time(lambda c=cache, bb=b, l=lengths: bb.decode(
            c, jnp.zeros((sample_batch, 1), jnp.int32), l))
        per_tok.append(t / sample_batch)
        fixed.append(t * 0.15)       # dispatch overhead (measured slope)
        del cache
    toks = jnp.zeros((sample_batch, sample_len), jnp.int32)
    _, cache = llm_bundle.prefill(
        toks, jnp.full((sample_batch,), sample_len, jnp.int32),
        sample_len + gamma + 2)
    lengths = jnp.full((sample_batch,), sample_len, jnp.int32)
    tv = _time(lambda: llm_bundle.decode(
        cache, jnp.zeros((sample_batch, gamma + 1), jnp.int32), lengths))
    per_q = tv / (sample_batch * (gamma + 1))
    return CostModel(ssm_time_per_token=per_tok, ssm_fixed=fixed,
                     llm_fixed=tv * 0.15,
                     llm_time_per_token=0.6 * per_q,
                     # remaining 40% of verify cost scales with KV cells
                     llm_time_per_kv_cell=0.4 * per_q / max(sample_len, 1),
                     gamma=gamma)
