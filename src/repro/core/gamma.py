"""Goodput-aware speculation-depth control (per-request gamma).

SPIN's LBSS selector (§IV) learns which SSM drafts best for each request,
but the seed engine still drafted a *fixed* ``gamma`` tokens for every
request every slot.  That is the wrong depth almost everywhere: a request
whose drafts are nearly always accepted should speculate deeper (more
committed tokens per LLM verification launch), while a request whose
drafts are mostly rejected burns ``gamma + 1`` verification query tokens
to commit ~1 — SpecServe-style systems make exactly this depth decision
per request, per step.

``GammaController`` chooses a depth ``k ∈ [1, gamma_max]`` for every
decode-active request each slot:

* **expected-goodput argmax** — with per-token acceptance estimate ``a``
  (the selector's per-(request, SSM) running mean, shared within request
  groups like every other LBSS estimate), the expected committed tokens
  of a depth-``k`` iteration under the standard i.i.d. acceptance model
  is ``E(k) = (1 - a^(k+1)) / (1 - a)`` (accepted prefix + bonus token),
  and its marginal cost is ``draft(k) + verify(k + 1)`` from the same
  ``CostModel`` the pipeline simulator uses.  The controller picks the
  ``k`` maximizing ``E(k) / time(k)``.  ``E`` is log-supermodular in
  ``(k, a)``, so the granted depth is monotone non-decreasing in the
  acceptance estimate — property-tested in tests/test_gamma.py.  Before
  the selector has any acceptance observation the controller grants the
  configured default depth ``gamma`` (the cold-start contract of
  ``--gamma`` under the adaptive policy).

* **load-aware cap** — when the step planner's token budget is contended
  (a ``token_budget`` is set and this slot's plan already granted prompt
  chunks from the same budget), the controller trims the deepest grants
  until the decode demand ``Σ (k_i + 1)`` fits the budget net of the
  granted chunk tokens, so speculation depth never starves prompt
  ingestion.  Every request keeps at least depth 1 (the slot still
  commits ≥ 1 token per request).

* **deadline-headroom cap** — when a request carries an SLO (the
  engine passes per-request seconds-to-deadline via ``slo_slack``),
  deep speculation is only granted if the TPOT slack affords a *failed*
  verify: a depth-``k`` iteration whose drafts all get rejected still
  pays ``iteration_time(ssm, k)`` to commit one token, so the depth is
  trimmed to the largest ``k`` whose iteration time fits the slack
  (floor 1).  SpecServe/AdaSpec condition depth on exactly this term;
  deadline-free requests are untouched.

The ``fixed`` policy returns ``cfg.gamma`` for every request
unconditionally and is bit-identical to the pre-controller engine.

Invariants (previously stated only in PR descriptions):

* **Grant bounds** — every granted depth satisfies
  ``1 <= k_i <= gamma_max``: depth 1 is the progress floor (each slot
  still commits >= 1 token per request), ``gamma_max`` is the worst case
  everything else reserves.
* **KV margins** — a depth-``k_i`` slot writes speculative KV at exactly
  ``[ctx, ctx + k_i + 1)`` (drafts + bonus token); the engine grows /
  scrubs per-row windows at ``ctx + k_i + 1``, while admission, pool
  sizing, switch-precompute widths and the scheduler's ``kv_need`` all
  reserve ``ctx + gamma_max + 1`` — a grant can never make an admitted
  request overflow its reservation.
* **Budget currency** — ``token_budget`` / ``reserved_tokens`` are LLM
  query tokens per slot: a decode slot costs ``k_i + 1``, this slot's
  already-granted prompt chunks cost ``reserved_tokens``, and the cap
  trims the deepest grants (deterministically: max depth, ties by rid)
  until the sum fits — the same currency the scheduler's step planner
  spends (``decode_cost``).
* **Losslessness** — depth only moves *when* tokens commit, never
  *which*: greedy speculative decoding emits the LLM's own continuation
  at any depth (tests/test_gamma.py, bench_gamma.py assert
  token-for-token equality between policies).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Sequence

POLICIES = ("fixed", "adaptive")


@dataclasses.dataclass(kw_only=True)
class GammaConfig:
    """Keyword-only like the other engine configs (fields are appended as
    the controller grows)."""

    policy: str = "fixed"
    gamma: int = 4  # fixed depth; adaptive cold-start depth (no estimate)
    gamma_max: int = 4  # adaptive depth cap (fixed policy: == gamma)
    # tree speculation: a depth-k grant is spent as a token TREE of
    # min(branches, k) branches totalling k draft nodes, verified with
    # k + min(branches, k) query tokens (every branch re-verifies its own
    # root copy).  branches=1 is the linear chain: cost k + 1 exactly.
    branches: int = 1
    # replica-class depth cap (elastic fleet): a prefill-heavy replica
    # reserves its verify budget for prompt-chunk ingestion, so its
    # ADAPTIVE grants are clamped to this ceiling (None = no class cap;
    # the fixed policy ignores it — `--gamma-policy fixed` stays
    # bit-identical regardless of replica class).
    depth_cap: Optional[int] = None

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(f"unknown gamma policy {self.policy!r}")
        if self.gamma < 1:
            raise ValueError("gamma must be >= 1")
        if self.gamma_max < 1:
            raise ValueError("gamma_max must be >= 1")
        if self.branches < 1:
            raise ValueError("branches must be >= 1")
        if self.depth_cap is not None and self.depth_cap < 1:
            raise ValueError("depth_cap must be >= 1 (None = uncapped)")


def expected_tokens(accept: float, k: int) -> float:
    """Expected committed tokens of a depth-k iteration: the accepted
    prefix of k drafts plus the verifier's bonus/correction token, under
    i.i.d. per-token acceptance probability ``accept``."""
    a = min(max(float(accept), 0.0), 1.0)
    if a >= 1.0:
        return float(k + 1)
    return (1.0 - a ** (k + 1)) / (1.0 - a)


class GammaController:
    """Grants a per-request speculation depth each slot.

    ``cost`` is the engine's :class:`repro.core.pipeline.CostModel`;
    ``selector`` is consulted through its optional ``accept_estimate``
    hook (LBSS implements it; baselines without it always grant the
    default depth ``gamma``, degrading the controller to a constant).
    """

    def __init__(self, cfg: GammaConfig, cost, selector=None):
        self.cfg = cfg
        self.cost = cost
        self.selector = selector
        self.granted: Dict[int, int] = {}  # last grant per live request
        self.grants = 0  # total per-request grants issued
        self.depth_sum = 0  # sum of granted depths (mean = sum/grants)
        self.capped = 0  # grants trimmed by the load-aware cap
        self.slo_capped = 0  # grants trimmed by the deadline-headroom cap
        self.class_capped = 0  # grants trimmed by the replica-class cap
        self.depth_hist: Dict[int, int] = {}  # depth -> grant count
        self._best: Dict[tuple, int] = {}  # (ssm, quantized a) -> depth

    # ------------------------------------------------------- estimates --
    def accept_estimate(self, rid: int, ssm: int) -> Optional[float]:
        """The selector's acceptance estimate for (request, SSM), clamped
        to [0, 1]; None before any observation exists (cold start) or
        when the selector has no ``accept_estimate`` hook (baselines)."""
        est = None
        if self.selector is not None:
            hook = getattr(self.selector, "accept_estimate", None)
            if hook is not None:
                est = hook(rid, ssm)
        if est is None:
            return None
        return min(max(float(est), 0.0), 1.0)

    def _depth_for(self, rid: int, ssm: int) -> int:
        est = self.accept_estimate(rid, ssm)
        if est is None:
            # cold start: the configured default depth, clamped to the cap
            return min(self.cfg.gamma, self.cfg.gamma_max)
        return self.best_depth(est, ssm)

    def iteration_time(self, ssm: int, k: int) -> float:
        """Marginal cost of one depth-k draft+verify iteration for one
        request: the same affine models the pipeline simulator uses,
        without the batching/KV terms (they are shared across the slot
        and do not change the per-request argmax).  Under tree
        speculation the verify pass carries ``k + min(branches, k)``
        query tokens (k draft nodes + one root copy per branch)."""
        b_eff = max(1, min(self.cfg.branches, k))
        return self.cost.draft_time(ssm, 1, tokens=k) + self.cost.verify_time(
            1, q_tokens=k + b_eff
        )

    def best_depth(self, accept: float, ssm: int) -> int:
        """argmax_k E(k) / time(k) over k in [1, gamma_max]; ties break
        toward the shallower depth (less KV + verify pressure)."""
        a = min(max(float(accept), 0.0), 1.0)
        key = (ssm, round(a * 256))
        hit = self._best.get(key)
        if hit is not None:
            return hit
        best_k, best_g = 1, -1.0
        for k in range(1, self.cfg.gamma_max + 1):
            g = expected_tokens(a, k) / max(self.iteration_time(ssm, k), 1e-12)
            if g > best_g * (1.0 + 1e-12):
                best_k, best_g = k, g
        self._best[key] = best_k
        return best_k

    # ----------------------------------------------------------- grant --
    def grant(
        self,
        ids: Sequence[int],
        assign: Mapping[int, int],
        *,
        token_budget: Optional[int] = None,
        reserved_tokens: int = 0,
        slo_slack: Optional[Mapping[int, float]] = None,
    ) -> Dict[int, int]:
        """Depths for this slot's decode-active requests.  ``assign`` maps
        request -> SSM (the selector's placement this slot);
        ``reserved_tokens`` is the budget already committed to this
        slot's prefill chunk grants; ``slo_slack`` maps request ->
        seconds until its next-token deadline (only SLO-carrying
        requests appear — absent/None means no deadline pressure)."""
        if self.cfg.policy == "fixed":
            depths = {rid: self.cfg.gamma for rid in ids}
        else:
            depths = {rid: self._depth_for(rid, assign.get(rid, 0)) for rid in ids}
            if self.cfg.depth_cap is not None:
                cap = self.cfg.depth_cap
                for rid, k in depths.items():
                    if k > cap:
                        self.class_capped += k - cap
                        depths[rid] = cap
            self._apply_slo_cap(depths, assign, slo_slack)
            self._apply_budget_cap(depths, token_budget, reserved_tokens)
        for rid, k in depths.items():
            self.granted[rid] = k
            self.grants += 1
            self.depth_sum += k
            self.depth_hist[k] = self.depth_hist.get(k, 0) + 1
        return depths

    def _apply_slo_cap(
        self,
        depths: Dict[int, int],
        assign: Mapping[int, int],
        slo_slack: Optional[Mapping[int, float]],
    ) -> None:
        """Deadline-headroom cap (SpecServe/AdaSpec): a deep grant is only
        worth its KV + verify cost if the request's TPOT slack affords
        the *whole* draft+verify iteration — when drafts get rejected, a
        depth-k iteration still pays ``iteration_time(ssm, k)`` to commit
        one token, so a request close to its deadline must speculate
        shallow.  Trims each SLO-carrying request's depth to the largest
        ``k`` whose iteration time fits its slack; depth 1 is the floor
        (the request still needs a verify launch to make progress at
        all, and a late token beats no token)."""
        if not slo_slack:
            return
        for rid, k in depths.items():
            slack = slo_slack.get(rid)
            if slack is None or slack <= 0 or k <= 1:
                # no contract, or already past the deadline — a late
                # request gains nothing from shallow grants (the next
                # token cannot meet its deadline either way), so it keeps
                # the throughput-optimal depth to catch up fastest
                continue
            ssm = assign.get(rid, 0)
            while k > 1 and self.iteration_time(ssm, k) > slack:
                k -= 1
            if k < depths[rid]:
                self.slo_capped += depths[rid] - k
                depths[rid] = k

    def _apply_budget_cap(
        self,
        depths: Dict[int, int],
        token_budget: Optional[int],
        reserved_tokens: int,
    ) -> None:
        """Trim the deepest grants until decode demand Σ(k_i + 1) fits the
        token budget net of the prompt-chunk tokens this slot's plan
        already granted, so decode + prefill together respect the step
        planner's bound (up to the depth-1 floor, the decode analogue of
        the idle-slot progress rule).  Deterministic: always trims the
        currently-deepest grant, ties by request id."""
        if token_budget is None or not depths:
            return
        avail = token_budget - max(0, int(reserved_tokens))
        avail = max(avail, 2 * len(depths))  # floor: depth 1 + bonus each

        def node_cost(k: int) -> int:
            # verify query tokens of a depth-k grant: the k draft nodes
            # plus one root copy per branch (linear: k + 1)
            return k + max(1, min(self.cfg.branches, k))

        while sum(node_cost(k) for k in depths.values()) > avail:
            rid = min(depths, key=lambda r: (-depths[r], r))
            if depths[rid] <= 1:
                break
            depths[rid] -= 1
            self.capped += 1

    # ---------------------------------------------------------- engine --
    def retire(self, rid: int) -> None:
        self.granted.pop(rid, None)

    @property
    def stats(self) -> dict:
        return {
            "policy": self.cfg.policy,
            "gamma_max": self.cfg.gamma_max,
            "grants": self.grants,
            "mean_depth": self.depth_sum / self.grants if self.grants else 0.0,
            "capped": self.capped,
            "slo_capped": self.slo_capped,
            "class_capped": self.class_capped,
            "depth_hist": dict(sorted(self.depth_hist.items())),
        }
