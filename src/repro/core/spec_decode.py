"""Speculative decoding primitives.

One iteration = SSM drafts ``gamma`` candidate tokens (autoregressive decode
steps), then the LLM scores ``[last_token, c_1..c_gamma]`` in ONE forward
(decode_step with T=gamma+1) and accepts a prefix:

  greedy mode    accept while draft token == LLM argmax (deterministic,
                 output identical to plain LLM greedy decoding)
  sampling mode  Leviathan-style lossless accept/reject: accept c_i with
                 prob min(1, p_i(c_i)/q_i(c_i)); on first rejection resample
                 from norm(max(0, p_i - q_i)).  Output distribution provably
                 equals the LLM's.

Both verifiers return per-row accept counts so ragged batches work; caches
are rolled back by invalidating rejected slots (segment id -1) — attention
caches only, recurrent-state verifiers use snapshot+recompute (see engine).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import config as C
from repro.models import transformer as T


@dataclasses.dataclass
class Bundle:
    """A model + jitted entry points (one per (B, T) shape, cached by jit)."""
    cfg: C.ModelConfig
    params: dict

    def __post_init__(self):
        self._prefill = jax.jit(
            lambda p, toks, lengths, max_len: T.prefill(
                p, self.cfg, tokens=toks, lengths=lengths, max_len=max_len),
            static_argnames=("max_len",))
        self._decode = jax.jit(
            lambda p, cache, toks, lengths: T.decode_step(
                p, self.cfg, cache, tokens=toks, lengths=lengths))
        # paged entry points are cached per fused_cfg (None = XLA gather
        # path; a kernels/autotune.FusedConfig = fused Pallas path) — the
        # config is static under jit, so each distinct config is its own
        # trace and flipping --fused-kernels never retraces the other path
        self._decode_paged = {}
        self._verify_paged = {}
        self._verify_paged_tree = {}
        self._append = None
        self._append_paged = {}

    def prefill(self, toks, lengths, max_len):
        return self._prefill(self.params, toks, lengths, max_len)

    def decode(self, cache, toks, lengths):
        return self._decode(self.params, cache, toks, lengths)

    def append(self, cache, toks, lengths, segments):
        """Chunked-prefill append on a batch-1 dense row cache: ingest T
        context tokens at positions lengths..lengths+T-1.  ``segments``
        marks bucket-padding tokens with -1 so their KV writes land
        invalidated and one trace serves every chunk width bucket."""
        if self._append is None:
            self._append = jax.jit(
                lambda p, c, t, l, s: T.decode_step(
                    p, self.cfg, c, tokens=t, lengths=l, segments=s))
        return self._append(self.params, cache, toks, lengths, segments)

    def append_paged(self, cache, toks, lengths, segments, block_tables,
                     fused_cfg=None):
        """Chunked-prefill append through a paged block pool: the (1, T)
        chunk writes straight into the row's blocks and attends its prior
        context blocks (see serving/paged.decode_step_paged)."""
        if fused_cfg not in self._append_paged:
            from repro.serving.paged import decode_step_paged
            self._append_paged[fused_cfg] = jax.jit(
                lambda p, c, t, l, s, bt: decode_step_paged(
                    p, self.cfg, c, tokens=t, lengths=l, segments=s,
                    block_tables=bt, fused_cfg=fused_cfg))
        return self._append_paged[fused_cfg](self.params, cache, toks,
                                             lengths, segments, block_tables)

    def decode_paged(self, cache, toks, lengths, block_tables,
                     fused_cfg=None):
        """Decode against a paged block pool (serving/pool.PagedCachePool).
        block_tables is a *traced* argument: table contents change every
        step without retracing."""
        if fused_cfg not in self._decode_paged:
            from repro.serving.paged import decode_step_paged
            self._decode_paged[fused_cfg] = jax.jit(
                lambda p, c, t, l, bt: decode_step_paged(
                    p, self.cfg, c, tokens=t, lengths=l, block_tables=bt,
                    fused_cfg=fused_cfg))
        return self._decode_paged[fused_cfg](self.params, cache, toks,
                                             lengths, block_tables)

    def verify_paged(self, cache, tokens, positions, segments, q_rows,
                     block_tables, block_ids, block_owner, fused_cfg=None):
        """Packed verification gathering KV fragments straight from the
        paged block pool (no flat packed copy)."""
        if fused_cfg not in self._verify_paged:
            from repro.serving.paged import verify_step_paged
            self._verify_paged[fused_cfg] = jax.jit(
                lambda p, c, t, pos, seg, qr, bt, ids, ow: verify_step_paged(
                    p, self.cfg, c, tokens=t, positions=pos, segments=seg,
                    q_rows=qr, block_tables=bt, block_ids=ids,
                    block_owner=ow, fused_cfg=fused_cfg))
        return self._verify_paged[fused_cfg](
            self.params, cache, tokens, positions, segments, q_rows,
            block_tables, block_ids, block_owner)

    def verify_paged_tree(self, cache, tokens, positions, segments, q_rows,
                          block_tables, block_ids, block_owner, q_anc,
                          block_node, fused_cfg=None):
        """Tree-topology packed verification: like :meth:`verify_paged`
        plus the ancestor-bitmask / per-slot node-tag mask term, so one
        pass scores every root-to-leaf path of a token tree."""
        if fused_cfg not in self._verify_paged_tree:
            from repro.serving.paged import verify_step_paged
            self._verify_paged_tree[fused_cfg] = jax.jit(
                lambda p, c, t, pos, seg, qr, bt, ids, ow, anc, node:
                verify_step_paged(
                    p, self.cfg, c, tokens=t, positions=pos, segments=seg,
                    q_rows=qr, block_tables=bt, block_ids=ids,
                    block_owner=ow, q_anc=anc, block_node=node,
                    fused_cfg=fused_cfg))
        return self._verify_paged_tree[fused_cfg](
            self.params, cache, tokens, positions, segments, q_rows,
            block_tables, block_ids, block_owner, q_anc, block_node)

    @property
    def has_recurrent_state(self) -> bool:
        kinds = set(self.cfg.unit) | set(self.cfg.tail)
        return bool(kinds & {C.MAMBA2, C.MLSTM, C.SLSTM})


def logits_to_probs(logits, temperature: float, vocab_size: int):
    logits = logits.astype(jnp.float32)
    if logits.shape[-1] > vocab_size:   # mask vocab padding
        mask = jnp.arange(logits.shape[-1]) < vocab_size
        logits = jnp.where(mask, logits, -1e30)
    if temperature <= 0.0:
        # one-hot argmax (greedy "distribution")
        return jax.nn.one_hot(jnp.argmax(logits, -1), logits.shape[-1],
                              dtype=jnp.float32)
    return jax.nn.softmax(logits / temperature, axis=-1)


def sample(probs, rng):
    return jax.random.categorical(rng, jnp.log(jnp.maximum(probs, 1e-30)))


# ------------------------------------------------------------------ draft --

def draft(ssm: Bundle, cache, last_tokens, lengths, gamma: int, rng,
          temperature: float = 0.0, collect_probs: bool = False,
          block_tables=None, fused_cfg=None):
    """Generate gamma candidates. last_tokens: (B,1) previous accepted token.
    Returns (cand (B,gamma), qprobs (B,gamma,V)|None, cache).
    block_tables routes the decode steps through the paged KV pool;
    fused_cfg additionally routes them through the fused Pallas kernel."""
    cands, qs = [], []
    tok = last_tokens
    for g in range(gamma):
        rng, k = jax.random.split(rng)
        if block_tables is not None:
            logits, cache = ssm.decode_paged(cache, tok, lengths + g,
                                             block_tables, fused_cfg)
        else:
            logits, cache = ssm.decode(cache, tok, lengths + g)
        probs = logits_to_probs(logits[:, -1], temperature,
                                ssm.cfg.vocab_size)
        tok = (jnp.argmax(probs, -1, keepdims=True) if temperature <= 0
               else sample(probs, k)[:, None]).astype(jnp.int32)
        cands.append(tok)
        if collect_probs:
            qs.append(probs)
    cand = jnp.concatenate(cands, axis=1)
    qprobs = jnp.stack(qs, axis=1) if collect_probs else None
    return cand, qprobs, cache


def draft_tree(ssm: Bundle, cache, last_tokens, lengths, gamma: int, ranks,
               block_tables=None, fused_cfg=None):
    """Greedy tree drafting: each pool row autoregressively extends ONE
    branch of a request's token tree.

    Rows of the same request share identical context (the engine forks
    their block tables copy-on-write), so their step-1 logits are
    identical; ``ranks[b]`` selects which top-k candidate row b commits to
    at the first step (rank 0 = argmax, the main chain) — after that every
    row continues greedily down its own branch.  No cross-row
    communication is needed, and with all ranks 0 (single branch) the
    emitted tokens are bitwise identical to :func:`draft` at
    temperature 0.  Returns (cand (B, gamma), cache)."""
    ranks_np = np.asarray(ranks)
    kmax = int(ranks_np.max()) + 1 if ranks_np.size else 1
    ranks = jnp.asarray(ranks_np, jnp.int32)
    cands = []
    tok = last_tokens
    for g in range(gamma):
        if block_tables is not None:
            logits, cache = ssm.decode_paged(cache, tok, lengths + g,
                                             block_tables, fused_cfg)
        else:
            logits, cache = ssm.decode(cache, tok, lengths + g)
        probs = logits_to_probs(logits[:, -1], 0.0, ssm.cfg.vocab_size)
        best = jnp.argmax(probs, -1, keepdims=True).astype(jnp.int32)
        if g == 0 and kmax > 1:
            lg = logits[:, -1].astype(jnp.float32)
            if lg.shape[-1] > ssm.cfg.vocab_size:   # mask vocab padding
                vmask = jnp.arange(lg.shape[-1]) < ssm.cfg.vocab_size
                lg = jnp.where(vmask, lg, -1e30)
            _, topi = jax.lax.top_k(lg, kmax)
            ranked = jnp.take_along_axis(topi.astype(jnp.int32),
                                         ranks[:, None], axis=1)
            # rank 0 keeps argmax's tie-breaking (== linear draft exactly)
            tok = jnp.where(ranks[:, None] == 0, best, ranked)
        else:
            tok = best
        cands.append(tok)
    return jnp.concatenate(cands, axis=1), cache


# ----------------------------------------------------------------- verify --

def verify_greedy(llm: Bundle, cache, last_tokens, cand, lengths):
    """Greedy verification.  Returns (n_accept (B,), out_tokens (B, gamma+1),
    out_len (B,), cache).  out_tokens[i, :out_len[i]] are the tokens emitted
    this iteration (accepted prefix + 1 correction/bonus token)."""
    B, gamma = cand.shape
    inp = jnp.concatenate([last_tokens, cand], axis=1)       # (B, gamma+1)
    logits, cache = llm.decode(cache, inp, lengths)
    greedy = jnp.argmax(logits.astype(jnp.float32)[..., :llm.cfg.vocab_size],
                        axis=-1).astype(jnp.int32)           # (B, gamma+1)
    # position i of `greedy` predicts the token after input i
    match = greedy[:, :gamma] == cand                        # (B, gamma)
    n_accept = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)
    # output: accepted candidates then the LLM's own next token
    idx = jnp.arange(gamma + 1)[None, :]
    out = jnp.where(idx < n_accept[:, None],
                    jnp.pad(cand, ((0, 0), (0, 1))),
                    0)
    bonus = jnp.take_along_axis(greedy, n_accept[:, None], axis=1)
    out = out.at[jnp.arange(B), n_accept].set(bonus[:, 0])
    out_len = n_accept + 1
    return n_accept, out, out_len, cache


def verify_sampling(llm: Bundle, cache, last_tokens, cand, qprobs, lengths,
                    rng, temperature: float = 1.0):
    """Lossless speculative sampling (Leviathan et al.).  qprobs: (B,g,V)."""
    B, gamma = cand.shape
    V = qprobs.shape[-1]
    inp = jnp.concatenate([last_tokens, cand], axis=1)
    logits, cache = llm.decode(cache, inp, lengths)
    p = logits_to_probs(logits, temperature, llm.cfg.vocab_size)  # (B,g+1,V)
    p_cand = p[:, :gamma]
    q_cand = qprobs
    pc = jnp.take_along_axis(p_cand, cand[..., None], -1)[..., 0]  # (B,g)
    qc = jnp.take_along_axis(q_cand, cand[..., None], -1)[..., 0]
    rng, k1, k2 = jax.random.split(rng, 3)
    u = jax.random.uniform(k1, (B, gamma))
    accept = u < jnp.minimum(1.0, pc / jnp.maximum(qc, 1e-30))
    n_accept = jnp.sum(jnp.cumprod(accept.astype(jnp.int32), 1), 1)  # (B,)
    # residual distribution at the first rejected position
    pos = jnp.minimum(n_accept, gamma - 1)
    p_rej = jnp.take_along_axis(p_cand, pos[:, None, None].repeat(V, -1),
                                1)[:, 0]
    q_rej = jnp.take_along_axis(q_cand, pos[:, None, None].repeat(V, -1),
                                1)[:, 0]
    resid = jnp.maximum(p_rej - q_rej, 0.0)
    resid = resid / jnp.maximum(jnp.sum(resid, -1, keepdims=True), 1e-30)
    # when everything accepted: bonus sampled from p[:, gamma]
    bonus_probs = jnp.where((n_accept == gamma)[:, None], p[:, gamma], resid)
    nxt = sample(bonus_probs, k2).astype(jnp.int32)
    idx = jnp.arange(gamma + 1)[None, :]
    out = jnp.where(idx < n_accept[:, None],
                    jnp.pad(cand, ((0, 0), (0, 1))), 0)
    out = out.at[jnp.arange(B), n_accept].set(nxt)
    out_len = n_accept + 1
    return n_accept, out, out_len, cache


# --------------------------------------------------------------- rollback --

def invalidate_slots(cache, new_lengths, upper):
    """Mark attention-cache slots with new_len <= pos < upper as empty.
    Works on the whole cache tree (scan-stacked and tail entries)."""
    def fix(entry):
        if not (isinstance(entry, dict) and "seg" in entry):
            return entry
        pos, seg = entry["pos"], entry["seg"]
        nl = new_lengths[:, None]
        up = upper[:, None]
        if pos.ndim == 3:   # scan-stacked (U, B, S)
            nl, up = nl[None], up[None]
        bad = (pos >= nl) & (pos < up)
        out = dict(entry)
        out["seg"] = jnp.where(bad, -1, seg)
        return out

    out = {}
    for key, val in cache.items():
        if key == "scan":
            out["scan"] = {k: fix(v) for k, v in val.items()}
        else:
            out[key] = fix(val)
    return out


invalidate_slots_jit = jax.jit(invalidate_slots)


# ------------------------------------------------------------- iteration --

def spec_iteration(llm: Bundle, ssm: Bundle, llm_cache, ssm_cache,
                   last_tokens, lengths, gamma, rng, temperature=0.0):
    """One full speculation+verification iteration for a batch.
    Returns (out_tokens, out_len, n_accept, llm_cache, ssm_cache,
    new_lengths, new_last)."""
    sampling = temperature > 0.0
    cand, qprobs, ssm_cache = draft(ssm, ssm_cache, last_tokens, lengths,
                                    gamma, rng, temperature,
                                    collect_probs=sampling)
    if sampling:
        rng, k = jax.random.split(rng)
        n_acc, out, out_len, llm_cache = verify_sampling(
            llm, llm_cache, last_tokens, cand, qprobs, lengths, k,
            temperature)
    else:
        n_acc, out, out_len, llm_cache = verify_greedy(
            llm, llm_cache, last_tokens, cand, lengths)
    new_lengths = lengths + out_len
    # llm cache holds K/V for inputs [last, c_1..c_gamma] at positions
    # lengths..lengths+gamma: keep last + accepted prefix, drop the rest.
    # (The correction token's KV enters next iteration as the new `last`.)
    llm_cache = invalidate_slots_jit(llm_cache, lengths + 1 + n_acc,
                                     lengths + gamma + 1)
    # SSM catch-up: the draft loop never wrote c_gamma's KV (it was produced,
    # not consumed).  One batched decode_step re-feeds this iteration's
    # outputs at positions lengths+1.., filling any hole (idempotent for
    # slots already valid); rejected-slot writes are invalidated after.
    _, ssm_cache = ssm.decode(ssm_cache, out, lengths + 1)
    ssm_cache = invalidate_slots_jit(ssm_cache, new_lengths + 1,
                                     lengths + gamma + 2)
    new_last = jnp.take_along_axis(out, (out_len - 1)[:, None], axis=1)
    return out, out_len, n_acc, llm_cache, ssm_cache, new_lengths, new_last
