"""Fast SSM switching (paper §IV-C).

Switching request i from SSM a to SSM b requires re-computing b's KV cache
over all tokens generated so far (the switching cost c_{i,j}(t), which grows
with context length).  The insight: newly drafted tokens cannot change the
KV of existing tokens, so the destination's cache can be pre-computed IN
PARALLEL with ongoing drafting on the source SSM.

During exploration the destination is known (chunk schedule); during
exploitation we pre-compute for the *predicted* destination = argmax
estimated goodput (selector.predicted_destination).  The engine calls
``precompute`` during SSM idle slots; a prediction hit makes the switch
free, a miss falls back to synchronous recompute (cost accounted).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax.numpy as jnp


@dataclasses.dataclass
class PrecomputedKV:
    ssm_idx: int
    upto_length: int
    cache: object
    lengths: object
    # sequence capacity the cache was prefilled with.  Historically always
    # the pool's max_len; with paged pools the engine passes a bucketed
    # width (O(context), not O(capacity)) — a switch whose context has
    # outgrown the width falls back to a miss instead of silently dropping
    # catch-up KV writes past the grid.
    width: int = 0


class SwitchManager:
    """Tracks per-request destination pre-computation and switch costs."""

    def __init__(self, ssm_bundles):
        self.ssms = ssm_bundles
        self.pre: Dict[int, PrecomputedKV] = {}
        self.hits = 0
        self.misses = 0
        self.recompute_tokens = 0    # tokens re-prefillled synchronously
        self.saved_tokens = 0        # tokens whose recompute was hidden

    @staticmethod
    def _padded(tokens, length: int, align: int = 16):
        """Pad the token row to a bucketed shape (bounds jit retraces)."""
        import math
        import numpy as np
        pb = max(align, int(math.ceil(length / align) * align))
        row = np.zeros((1, pb), np.int32)
        row[0, :length] = np.asarray(tokens[:length], np.int32)
        return jnp.asarray(row)

    def precompute(self, request_id: int, dst: int, tokens, length: int,
                   max_len: int):
        """Prefill request context on the destination SSM (issued during
        source-SSM idle time; JAX async dispatch overlaps it).  ``max_len``
        is the cache width to build — pool max_len for dense pools, a
        bucketed O(context) width for paged ones (the engine adds a
        gamma+1 growth margin so the common next-slot switch still hits)."""
        b = self.ssms[dst]
        toks = self._padded(tokens, length)
        lengths = jnp.asarray([length], jnp.int32)
        _, cache = b.prefill(toks, lengths, max_len)
        self.pre[request_id] = PrecomputedKV(
            ssm_idx=dst, upto_length=length, cache=cache, lengths=lengths,
            width=max_len)

    def switch(self, request_id: int, dst: int, tokens, length: int,
               max_len: int) -> Tuple[object, int]:
        """Returns (cache_on_dst, tokens_recomputed_synchronously)."""
        pre = self.pre.pop(request_id, None)
        if (pre is not None and pre.ssm_idx == dst
                and pre.width and length > pre.width):
            # context outgrew the precomputed grid (bucketed paged width):
            # catch-up writes would fall off the cache — treat as a miss
            pre = None
        if pre is not None and pre.ssm_idx == dst:
            self.hits += 1
            delta = length - pre.upto_length
            self.saved_tokens += pre.upto_length
            if delta <= 0:
                return pre.cache, 0
            # catch up the few tokens drafted since pre-compute (bucketed
            # width; over-written garbage slots invalidated afterwards)
            from repro.core.spec_decode import invalidate_slots_jit
            b = self.ssms[dst]
            toks = self._padded(tokens[pre.upto_length:length], delta,
                                align=8)
            cache = pre.cache
            lengths = jnp.asarray([pre.upto_length], jnp.int32)
            _, cache = b.decode(cache, toks, lengths)
            cache = invalidate_slots_jit(
                cache, jnp.asarray([length], jnp.int32),
                jnp.asarray([pre.upto_length + toks.shape[1]], jnp.int32))
            self.recompute_tokens += delta
            return cache, delta
        # miss: full synchronous recompute
        self.misses += 1
        b = self.ssms[dst]
        toks = self._padded(tokens, length)
        lengths = jnp.asarray([length], jnp.int32)
        _, cache = b.prefill(toks, lengths, max_len)
        self.recompute_tokens += length
        return cache, length

    @property
    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "recompute_tokens": self.recompute_tokens,
                "saved_tokens": self.saved_tokens}
