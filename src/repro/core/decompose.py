"""Fast batch verification via request decomposition (paper §V-A).

GPU formulation (paper Fig. 9): rip overlong KV rows, stitch them with short
ones into a dense (B x L) grid, replicate Q rows, and fix the softmax with
Eq. (13)'s indicator I_{j,S} so the denominator spans all fragments of the
same request.

TPU-native formulation (this module): the packed grid is *flattened* and
tokens carry (request-segment, absolute-position) metadata; attention is
segment-restricted and position-causal.  This computes exactly Eq. (13)
— the denominator sums F(Q_i,K_j) over all packed tokens with I_{j,S}=1 —
with two improvements over the paper's version (recorded in DESIGN.md):
  * no Q-row replication is needed (queries address fragments through
    segment ids, not row alignment), and
  * the Pallas kernel (kernels/verify_attention.py) skips whole KV blocks
    whose segment range cannot match the query block, so compute tracks the
    *packed* size rather than the padded size.

The planner below is the paper's L-search: fix the width bound B (max rows),
then pick the KV-grid length L (128-aligned for MXU tiles) minimizing padded
cells.  ``rows*L`` vs ``n_requests*max_len`` is the padding saving reported
in benchmarks/bench_verification.py (paper Fig. 12).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence

import jax.numpy as jnp
import numpy as np

# Tree speculation encodes each query's root-to-node path as a bitmask in
# one int32 (kernels/verify_attention.py, kernels/paged_attention.py,
# kernels/fused_verify.py), so the node budget per request is the mask
# width.  Everything that validates tree shapes (engine construction,
# launch/serve.py, build_tree_layout) derives its limit from here — a
# wider mask dtype changes the budget in exactly one place.
ANCESTOR_MASK_BITS = 32


def max_tree_nodes() -> int:
    """Largest per-request tree node count (= sum of (depth_j + 1) over
    branches) the ancestor-bitmask verify kernels can express."""
    return ANCESTOR_MASK_BITS


@dataclasses.dataclass
class PackPlan:
    L: int                     # KV grid row length
    rows: int                  # number of rows (paper's width B)
    gather_b: np.ndarray       # (rows*L,) source request per packed cell
    gather_s: np.ndarray       # (rows*L,) source cache slot per packed cell
    valid: np.ndarray          # (rows*L,) bool
    lengths: np.ndarray        # (N,) request KV lengths packed
    padded_cells: int          # rows*L - sum(lengths)
    baseline_cells: int        # n_requests * max(lengths)  (padded scheme)

    @property
    def total(self) -> int:
        return self.rows * self.L

    @property
    def saving(self) -> float:
        return 1.0 - self.total / max(self.baseline_cells, 1)


def _pack_for_L(lengths: Sequence[int], L: int):
    rows_per_req = [max(1, math.ceil(l / L)) for l in lengths]
    rows = sum(rows_per_req)
    padding = rows * L - sum(lengths)
    return rows, padding


def plan_decomposition(lengths: Sequence[int], *, max_rows: int = 0,
                       align: int = 128,
                       slot_fn: Optional[Callable[[int, int], int]] = None
                       ) -> PackPlan:
    """Search L (paper §V-A): minimize total padded cells subject to the
    row/width bound.  lengths: per-request KV token counts."""
    lengths = [int(l) for l in lengths]
    n = len(lengths)
    max_len = max(lengths)
    if max_rows <= 0:
        # paper: fixed width bound B limits Q-replication overhead; our
        # formulation has no Q copies, so the bound is looser (grid rows
        # only affect kernel grid size).
        max_rows = 4 * n
    cands = []
    L = align
    while L <= max(align, int(math.ceil(max_len / align) * align)):
        rows, padding = _pack_for_L(lengths, L)
        if rows <= max_rows:
            cands.append((rows * L, rows, L, padding))
        L += align
    if not cands:                              # fall back: one row per req
        L = int(math.ceil(max_len / align) * align)
        rows, padding = _pack_for_L(lengths, L)
        cands.append((rows * L, rows, L, padding))
    total, rows, L, padding = min(cands)

    gather_b = np.zeros(rows * L, np.int32)
    gather_s = np.zeros(rows * L, np.int32)
    valid = np.zeros(rows * L, bool)
    cell = 0
    for i, l in enumerate(lengths):
        for p in range(l):
            gather_b[cell] = i
            gather_s[cell] = slot_fn(i, p) if slot_fn else p
            valid[cell] = True
            cell += 1
        # round the request up to a full row boundary (fragment padding)
        cell += (L - (l % L)) % L
    return PackPlan(L=L, rows=rows, gather_b=gather_b, gather_s=gather_s,
                    valid=valid, lengths=np.array(lengths, np.int64),
                    padded_cells=padding, baseline_cells=n * max_len)


def packed_gather(cache_entry: dict, gather_b, gather_s, valid):
    """Gather a canonical per-request attention cache entry
    {k,v,pos,seg: (B,S,...)} into the packed flattened view (1, P, ...).
    Valid cells take segment = source request index; padding cells -1."""
    k = cache_entry["k"][gather_b, gather_s][None]
    v = cache_entry["v"][gather_b, gather_s][None]
    pos = cache_entry["pos"][gather_b, gather_s][None]
    src_seg = cache_entry["seg"][gather_b, gather_s]
    seg = jnp.where(valid & (src_seg >= 0), gather_b, -1)[None]
    pos = jnp.where(seg >= 0, pos, -1)
    return k, v, pos, seg


def make_attn_override(gather_b, gather_s, valid, q_rows):
    """Returns an attention override for transformer._attn_block that
    implements packed verification: attend q over [packed KV ; new KV] and
    scatter the new K/V back into the canonical cache. q_rows: (Tq,) source
    request per query token."""
    from repro.models.layers import attention

    gather_b = jnp.asarray(gather_b)
    gather_s = jnp.asarray(gather_s)
    valid = jnp.asarray(valid)
    q_rows = jnp.asarray(q_rows)

    def override(q, k_new, v_new, positions, segments, kv_cache, cfg, opts):
        # q,k_new,v_new: (1, Tq, H/Kh, hd); positions/segments: (1, Tq)
        pk, pv, ppos, pseg = packed_gather(kv_cache, gather_b, gather_s,
                                           valid)
        kk = jnp.concatenate([pk, k_new], axis=1)
        vv = jnp.concatenate([pv, v_new], axis=1)
        kpos = jnp.concatenate([ppos, positions], axis=1)
        kseg = jnp.concatenate([pseg, segments], axis=1)
        o = attention(q, kk, vv, q_positions=positions, kv_positions=kpos,
                      q_segments=segments, kv_segments=kseg,
                      window=cfg.sliding_window, q_block=opts.q_block)
        # scatter new K/V back into the canonical cache
        wpos = positions[0]
        kc = kv_cache["k"].at[q_rows, wpos].set(
            k_new[0].astype(kv_cache["k"].dtype))
        vc = kv_cache["v"].at[q_rows, wpos].set(
            v_new[0].astype(kv_cache["v"].dtype))
        pc = kv_cache["pos"].at[q_rows, wpos].set(wpos)
        sc = kv_cache["seg"].at[q_rows, wpos].set(0)
        return o, {"k": kc, "v": vc, "pos": pc, "seg": sc}

    return override


def build_query_layout(lengths: Sequence[int], gamma):
    """Query tokens for verification: gamma_i+1 per request, positions
    lengths[i]..lengths[i]+gamma_i, segment = request index.

    ``gamma`` is either a scalar (uniform speculation depth — every
    request contributes gamma+1 query tokens, the seed layout) or a
    per-request sequence of draft depths (the goodput-aware gamma
    controller grants ragged depths, so the packed query count is
    Σ (k_i + 1) instead of n * (gamma + 1)).
    Returns (q_rows (Tq,), q_positions (1,Tq), q_segments (1,Tq))."""
    n = len(lengths)
    if np.ndim(gamma) == 0:
        gam = np.full(n, int(gamma), np.int32)
    else:
        gam = np.asarray(gamma, np.int32)
        if len(gam) != n:
            raise ValueError(
                f"per-request gamma has {len(gam)} entries for {n} requests")
    q_rows = np.repeat(np.arange(n, dtype=np.int32), gam + 1)
    offs = np.concatenate(
        [np.arange(g + 1, dtype=np.int32) for g in gam]) if n else \
        np.zeros(0, np.int32)
    q_pos = (np.asarray(lengths, np.int32)[q_rows] + offs)[None]
    q_seg = q_rows[None].astype(np.int32)
    return q_rows, q_pos, q_seg


@dataclasses.dataclass
class TreeLayout:
    """Packed query layout for single-pass token-tree verification.

    One request contributes ``sum_j (k_j + 1)`` query tokens: every branch
    carries its own copy of the root token (the pending last token, at
    position ``lengths[i]``) followed by its ``k_j`` draft tokens.  Branch
    0 is the main greedy chain; with a single branch the layout degenerates
    to ``build_query_layout`` exactly (root + k draft queries).

    Node ids are per-request: branch ``j`` owns the contiguous id range
    ``[offset_j, offset_j + k_j]`` (root first), so a query at depth ``d``
    has the ancestor bitmask ``((1 << (d+1)) - 1) << offset_j`` — its own
    branch's nodes up to and including itself, nothing from siblings.
    """
    q_req: np.ndarray          # (Tq,) active-request index per query
    q_branch: np.ndarray       # (Tq,) branch index within the request
    q_depth: np.ndarray        # (Tq,) 0 = root, d >= 1 = draft depth d
    q_pos: np.ndarray          # (1, Tq) absolute positions
    q_seg: np.ndarray          # (1, Tq) segment = active-request index
    q_anc: np.ndarray          # (Tq,) ancestor bitmask per query
    node_id: np.ndarray        # (Tq,) tree-node id the query writes as
    offsets: list              # offsets[i][j] = first query index of
    #                            request i's branch j (root query)


def build_tree_layout(lengths: Sequence[int], branch_depths) -> TreeLayout:
    """Tree analogue of ``build_query_layout``.

    ``branch_depths[i]`` is request i's list of branch draft depths
    ``[k_0, k_1, ...]`` (each >= 1; total node count ``sum (k_j + 1)`` must
    fit the 32-bit ancestor mask).  Queries are emitted request-major,
    branch-major, depth-minor — for a single branch this is exactly the
    linear ``[root, c_1..c_k]`` order.
    """
    q_req, q_branch, q_depth, q_pos, q_anc, node = [], [], [], [], [], []
    offsets = []
    for i, (length, depths) in enumerate(zip(lengths, branch_depths)):
        total_nodes = sum(int(k) + 1 for k in depths)
        if total_nodes > max_tree_nodes():
            raise ValueError(
                f"request {i}: {total_nodes} tree nodes exceed the "
                f"{ANCESTOR_MASK_BITS}-bit ancestor mask (trim branches "
                "or depth)")
        off, req_offsets = 0, []
        for j, k in enumerate(depths):
            k = int(k)
            if k < 1:
                raise ValueError(f"request {i} branch {j}: depth must be >= 1")
            req_offsets.append(len(q_req))
            for d in range(k + 1):
                q_req.append(i)
                q_branch.append(j)
                q_depth.append(d)
                q_pos.append(int(length) + d)
                q_anc.append(((1 << (d + 1)) - 1) << off)
                node.append(off + d)
            off += k + 1
        offsets.append(req_offsets)
    q_req = np.asarray(q_req, np.int32)
    return TreeLayout(
        q_req=q_req,
        q_branch=np.asarray(q_branch, np.int32),
        q_depth=np.asarray(q_depth, np.int32),
        q_pos=np.asarray(q_pos, np.int32)[None],
        q_seg=q_req[None].copy(),
        q_anc=np.asarray(q_anc, np.int32),
        node_id=np.asarray(node, np.int32),
        offsets=offsets,
    )


def build_tree_row_layout(lengths: Sequence[int], W: int, tree_rows: dict):
    """Row-major tree-verify query layout over a full pool.

    Every pool row contributes ``W + 1`` queries at positions
    ``lengths[r] .. lengths[r] + W`` (the engine's static verify shape).
    ``tree_rows`` maps pool row -> ``(seg_row, offset, k)`` for rows that
    carry a tree branch: their queries take segment ``seg_row`` (the
    request's main row, so forked rows attend the shared prefix) and
    ancestor bitmask ``((1 << (min(d, k) + 1)) - 1) << offset`` — depth-d
    queries see their own branch's nodes only; depths beyond ``k`` are
    padding whose mask saturates at the leaf (their outputs land in
    scrubbed cells / unused greedy positions).  Rows absent from
    ``tree_rows`` get anc = -1 ("attend any node"), the linear semantics.

    With every active row mapped as ``(row, 0, k_row)`` and no forks this
    produces exactly ``build_query_layout(lengths, W)`` plus an anc vector
    whose mask term is redundant (single-chain causality), which is what
    makes branching=1 bit-identical to the linear engine.

    Returns (q_rows (Tq,), q_pos (1, Tq), q_seg (1, Tq), q_anc (Tq,)).
    """
    n = len(lengths)
    q_rows = np.repeat(np.arange(n, dtype=np.int32), W + 1)
    d = np.tile(np.arange(W + 1, dtype=np.int32), n)
    q_pos = (np.asarray(lengths, np.int32)[q_rows] + d)[None]
    seg = np.arange(n, dtype=np.int64)
    anc = np.full((n, W + 1), -1, np.int64)
    dd = np.arange(W + 1, dtype=np.int64)
    for row, (seg_row, off, k) in tree_rows.items():
        seg[row] = seg_row
        anc[row] = ((1 << (np.minimum(dd, int(k)) + 1)) - 1) << int(off)
    q_seg = seg.astype(np.int32)[q_rows][None]
    q_anc = anc.astype(np.uint32).astype(np.int32).reshape(-1)
    return q_rows, q_pos, q_seg, q_anc


def split_tree_depths(k: int, branches: int) -> list:
    """Split a granted node budget ``k`` into per-branch draft depths.

    Branch 0 (the main greedy chain) gets the deepest share; extra
    branches get the remainder round-robin.  ``branches`` is capped at
    ``k`` (every branch must draft at least one token), so ``branches=1``
    or ``k=1`` degenerates to the linear ``[k]``."""
    b = max(1, min(int(branches), int(k)))
    base, rem = divmod(int(k), b)
    return [base + (1 if j < rem else 0) for j in range(b)]


def padding_stats(lengths: Sequence[int], plan: PackPlan) -> dict:
    return {
        "packed_cells": plan.total,
        "padded_cells": plan.baseline_cells,
        "saving_frac": plan.saving,
        "L": plan.L,
        "rows": plan.rows,
    }
