"""Fast batch verification via request decomposition (paper §V-A).

GPU formulation (paper Fig. 9): rip overlong KV rows, stitch them with short
ones into a dense (B x L) grid, replicate Q rows, and fix the softmax with
Eq. (13)'s indicator I_{j,S} so the denominator spans all fragments of the
same request.

TPU-native formulation (this module): the packed grid is *flattened* and
tokens carry (request-segment, absolute-position) metadata; attention is
segment-restricted and position-causal.  This computes exactly Eq. (13)
— the denominator sums F(Q_i,K_j) over all packed tokens with I_{j,S}=1 —
with two improvements over the paper's version (recorded in DESIGN.md):
  * no Q-row replication is needed (queries address fragments through
    segment ids, not row alignment), and
  * the Pallas kernel (kernels/verify_attention.py) skips whole KV blocks
    whose segment range cannot match the query block, so compute tracks the
    *packed* size rather than the padded size.

The planner below is the paper's L-search: fix the width bound B (max rows),
then pick the KV-grid length L (128-aligned for MXU tiles) minimizing padded
cells.  ``rows*L`` vs ``n_requests*max_len`` is the padding saving reported
in benchmarks/bench_verification.py (paper Fig. 12).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class PackPlan:
    L: int                     # KV grid row length
    rows: int                  # number of rows (paper's width B)
    gather_b: np.ndarray       # (rows*L,) source request per packed cell
    gather_s: np.ndarray       # (rows*L,) source cache slot per packed cell
    valid: np.ndarray          # (rows*L,) bool
    lengths: np.ndarray        # (N,) request KV lengths packed
    padded_cells: int          # rows*L - sum(lengths)
    baseline_cells: int        # n_requests * max(lengths)  (padded scheme)

    @property
    def total(self) -> int:
        return self.rows * self.L

    @property
    def saving(self) -> float:
        return 1.0 - self.total / max(self.baseline_cells, 1)


def _pack_for_L(lengths: Sequence[int], L: int):
    rows_per_req = [max(1, math.ceil(l / L)) for l in lengths]
    rows = sum(rows_per_req)
    padding = rows * L - sum(lengths)
    return rows, padding


def plan_decomposition(lengths: Sequence[int], *, max_rows: int = 0,
                       align: int = 128,
                       slot_fn: Optional[Callable[[int, int], int]] = None
                       ) -> PackPlan:
    """Search L (paper §V-A): minimize total padded cells subject to the
    row/width bound.  lengths: per-request KV token counts."""
    lengths = [int(l) for l in lengths]
    n = len(lengths)
    max_len = max(lengths)
    if max_rows <= 0:
        # paper: fixed width bound B limits Q-replication overhead; our
        # formulation has no Q copies, so the bound is looser (grid rows
        # only affect kernel grid size).
        max_rows = 4 * n
    cands = []
    L = align
    while L <= max(align, int(math.ceil(max_len / align) * align)):
        rows, padding = _pack_for_L(lengths, L)
        if rows <= max_rows:
            cands.append((rows * L, rows, L, padding))
        L += align
    if not cands:                              # fall back: one row per req
        L = int(math.ceil(max_len / align) * align)
        rows, padding = _pack_for_L(lengths, L)
        cands.append((rows * L, rows, L, padding))
    total, rows, L, padding = min(cands)

    gather_b = np.zeros(rows * L, np.int32)
    gather_s = np.zeros(rows * L, np.int32)
    valid = np.zeros(rows * L, bool)
    cell = 0
    for i, l in enumerate(lengths):
        for p in range(l):
            gather_b[cell] = i
            gather_s[cell] = slot_fn(i, p) if slot_fn else p
            valid[cell] = True
            cell += 1
        # round the request up to a full row boundary (fragment padding)
        cell += (L - (l % L)) % L
    return PackPlan(L=L, rows=rows, gather_b=gather_b, gather_s=gather_s,
                    valid=valid, lengths=np.array(lengths, np.int64),
                    padded_cells=padding, baseline_cells=n * max_len)


def packed_gather(cache_entry: dict, gather_b, gather_s, valid):
    """Gather a canonical per-request attention cache entry
    {k,v,pos,seg: (B,S,...)} into the packed flattened view (1, P, ...).
    Valid cells take segment = source request index; padding cells -1."""
    k = cache_entry["k"][gather_b, gather_s][None]
    v = cache_entry["v"][gather_b, gather_s][None]
    pos = cache_entry["pos"][gather_b, gather_s][None]
    src_seg = cache_entry["seg"][gather_b, gather_s]
    seg = jnp.where(valid & (src_seg >= 0), gather_b, -1)[None]
    pos = jnp.where(seg >= 0, pos, -1)
    return k, v, pos, seg


def make_attn_override(gather_b, gather_s, valid, q_rows):
    """Returns an attention override for transformer._attn_block that
    implements packed verification: attend q over [packed KV ; new KV] and
    scatter the new K/V back into the canonical cache. q_rows: (Tq,) source
    request per query token."""
    from repro.models.layers import attention

    gather_b = jnp.asarray(gather_b)
    gather_s = jnp.asarray(gather_s)
    valid = jnp.asarray(valid)
    q_rows = jnp.asarray(q_rows)

    def override(q, k_new, v_new, positions, segments, kv_cache, cfg, opts):
        # q,k_new,v_new: (1, Tq, H/Kh, hd); positions/segments: (1, Tq)
        pk, pv, ppos, pseg = packed_gather(kv_cache, gather_b, gather_s,
                                           valid)
        kk = jnp.concatenate([pk, k_new], axis=1)
        vv = jnp.concatenate([pv, v_new], axis=1)
        kpos = jnp.concatenate([ppos, positions], axis=1)
        kseg = jnp.concatenate([pseg, segments], axis=1)
        o = attention(q, kk, vv, q_positions=positions, kv_positions=kpos,
                      q_segments=segments, kv_segments=kseg,
                      window=cfg.sliding_window, q_block=opts.q_block)
        # scatter new K/V back into the canonical cache
        wpos = positions[0]
        kc = kv_cache["k"].at[q_rows, wpos].set(
            k_new[0].astype(kv_cache["k"].dtype))
        vc = kv_cache["v"].at[q_rows, wpos].set(
            v_new[0].astype(kv_cache["v"].dtype))
        pc = kv_cache["pos"].at[q_rows, wpos].set(wpos)
        sc = kv_cache["seg"].at[q_rows, wpos].set(0)
        return o, {"k": kc, "v": vc, "pos": pc, "seg": sc}

    return override


def build_query_layout(lengths: Sequence[int], gamma):
    """Query tokens for verification: gamma_i+1 per request, positions
    lengths[i]..lengths[i]+gamma_i, segment = request index.

    ``gamma`` is either a scalar (uniform speculation depth — every
    request contributes gamma+1 query tokens, the seed layout) or a
    per-request sequence of draft depths (the goodput-aware gamma
    controller grants ragged depths, so the packed query count is
    Σ (k_i + 1) instead of n * (gamma + 1)).
    Returns (q_rows (Tq,), q_positions (1,Tq), q_segments (1,Tq))."""
    n = len(lengths)
    if np.ndim(gamma) == 0:
        gam = np.full(n, int(gamma), np.int32)
    else:
        gam = np.asarray(gamma, np.int32)
        if len(gam) != n:
            raise ValueError(
                f"per-request gamma has {len(gam)} entries for {n} requests")
    q_rows = np.repeat(np.arange(n, dtype=np.int32), gam + 1)
    offs = np.concatenate(
        [np.arange(g + 1, dtype=np.int32) for g in gam]) if n else \
        np.zeros(0, np.int32)
    q_pos = (np.asarray(lengths, np.int32)[q_rows] + offs)[None]
    q_seg = q_rows[None].astype(np.int32)
    return q_rows, q_pos, q_seg


def padding_stats(lengths: Sequence[int], plan: PackPlan) -> dict:
    return {
        "packed_cells": plan.total,
        "padded_cells": plan.baseline_cells,
        "saving_frac": plan.saving,
        "L": plan.L,
        "rows": plan.rows,
    }
