"""Learning-based SSM selection (paper §IV, Algorithms 1+2).

The SSM-selection problem is a multi-armed bandit over heterogeneous SSMs.
Time is divided into epochs k = 1, 2, ...; each epoch runs

  Exploration (alpha slots, grouped into chunks of beta slots): requests get
  RANDOM SSMs, re-drawn once per chunk (chunking bounds the switching cost,
  Fig. 8), batch caps B_j enforced by dropping overflow to other SSMs.
  Goodput observations r_{i,j}(t) update running means g~_{i,j}.

  Exploitation (2^k slots): assignment = maximum-weight bipartite matching
  between requests and B_j-replicated SSM slots on the estimated goodputs —
  the paper's KM algorithm; we use scipy's Hungarian implementation
  (linear_sum_assignment) with a pure-python auction fallback.

Regret = goodput regret + lambda * switching (KV recompute) cost; Theorem 1
gives O(log2 T) — tests/test_selector.py checks the empirical curve.

Baselines from §VI-B2: Greedy (prompt-length buckets) and epsilon-greedy.
"""

from __future__ import annotations

import dataclasses
import random
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

try:
    from scipy.optimize import linear_sum_assignment as _lsa
    _HAVE_SCIPY = True
except Exception:                                      # pragma: no cover
    _HAVE_SCIPY = False


def km_match(weights: np.ndarray) -> List[int]:
    """Maximum-weight matching of rows (requests) to columns (SSM slots).
    Returns col index per row (-1 if unmatched).  weights: (N, S)."""
    n, s = weights.shape
    if _HAVE_SCIPY:
        # pad to square so every request can stay unmatched at weight 0
        size = max(n, s)
        pad = np.zeros((size, size))
        pad[:n, :s] = weights
        rows, cols = _lsa(pad, maximize=True)
        out = [-1] * n
        for r, c in zip(rows, cols):
            if r < n and c < s:
                out[r] = int(c)
        return out
    return _greedy_match(weights)


def _greedy_match(weights: np.ndarray) -> List[int]:  # pragma: no cover
    n, s = weights.shape
    order = np.dstack(np.unravel_index(
        np.argsort(-weights, axis=None), weights.shape))[0]
    used_r, used_c = set(), set()
    out = [-1] * n
    for r, c in order:
        if r in used_r or c in used_c:
            continue
        out[int(r)] = int(c)
        used_r.add(int(r))
        used_c.add(int(c))
    return out


@dataclasses.dataclass
class SelectorConfig:
    n_ssms: int
    batch_limits: Sequence[int]          # B_j per SSM
    alpha: int = 6                       # exploration slots per epoch
    beta: int = 2                        # chunk size (slots per chunk)
    lam: float = 0.1                     # switching-cost weight in regret
    seed: int = 0


class LBSS:
    """Stateful selector: call ``assign(request_ids)`` once per time slot,
    then ``observe(request_id, ssm, goodput)`` with measured goodput.

    Beyond-paper extension: optional ``group_of`` maps request -> cluster
    (e.g. dataset / difficulty-marker).  Goodput estimates are then shared
    WITHIN a cluster, so short-lived requests exploit what earlier requests
    of the same kind already learned (hierarchical bandit).  With no
    group_of each request is its own group = the paper's per-request MAB."""

    def __init__(self, cfg: SelectorConfig, group_of=None):
        self.cfg = cfg
        self.group_of = group_of or {}
        self.rng = random.Random(cfg.seed)
        self.epoch = 1
        self.slot_in_phase = 0
        self.phase = "explore"
        self.sum: Dict[Tuple[int, int], float] = defaultdict(float)
        self.cnt: Dict[Tuple[int, int], int] = defaultdict(int)
        # per-(group, SSM) draft-acceptance running means, the input the
        # goodput-aware gamma controller (core/gamma.py) reads.  Kept
        # separate from goodput: goodput folds in batch/timing effects,
        # acceptance is the pure draft-quality signal the depth argmax
        # needs.
        self.acc_sum: Dict[Tuple[int, int], float] = defaultdict(float)
        self.acc_cnt: Dict[Tuple[int, int], int] = defaultdict(int)
        self._chunk_assign: Dict[int, int] = {}
        self._exploit_assign: Dict[int, int] = {}
        self._exploit_cohort: frozenset = frozenset()
        self.switches = 0
        self._last: Dict[int, int] = {}

    def retire(self, request_id: int):
        """Drop a departed request (finished or preempted) from live
        assignment state.  Under continuous batching the cohort changes
        every slot; stale entries would otherwise occupy matching slots and
        pin exploitation assignments to dead requests.  Learned goodput
        estimates are kept — a preempted request (or its group) resumes
        with everything it already learned."""
        self._chunk_assign.pop(request_id, None)
        self._exploit_assign.pop(request_id, None)
        self._last.pop(request_id, None)
        self._exploit_cohort = self._exploit_cohort - {request_id}

    def _group(self, i: int):
        return self.group_of.get(i, i)

    # -- estimates ----------------------------------------------------------
    def estimate(self, i: int, j: int) -> float:
        g = self._group(i)
        c = self.cnt[(g, j)]
        if c == 0:
            # optimistic default: global mean (encourages coverage)
            tot = sum(self.sum.values())
            n = sum(self.cnt.values())
            return tot / n if n else 0.0
        return self.sum[(g, j)] / c

    def observe(self, request_id: int, ssm: int, goodput: float):
        g = self._group(request_id)
        self.sum[(g, ssm)] += goodput
        self.cnt[(g, ssm)] += 1

    def observe_accept(self, request_id: int, ssm: int, rate: float):
        """Record one iteration's draft-acceptance fraction
        (accepted / drafted) for the request's group on this SSM."""
        g = self._group(request_id)
        self.acc_sum[(g, ssm)] += float(rate)
        self.acc_cnt[(g, ssm)] += 1

    def accept_estimate(self, request_id: int, ssm: int) -> Optional[float]:
        """Mean acceptance rate of the request's group on this SSM; falls
        back to the global mean over all (group, SSM) pairs, and to None
        before any observation at all (the gamma controller then applies
        its prior).  Like ``estimate``, survives retire() — a re-admitted
        request resumes with everything its group already learned."""
        g = self._group(request_id)
        c = self.acc_cnt[(g, ssm)]
        if c:
            return self.acc_sum[(g, ssm)] / c
        n = sum(self.acc_cnt.values())
        if n:
            return sum(self.acc_sum.values()) / n
        return None

    # -- assignment ---------------------------------------------------------
    def _random_capped(self, request_ids: Sequence[int]) -> Dict[int, int]:
        """Algorithm 2 lines 3-11: random choice then cap at B_j."""
        M = self.cfg.n_ssms
        assign = {i: self.rng.randrange(M) for i in request_ids}
        for j in range(M):
            members = [i for i, a in assign.items() if a == j]
            cap = self.cfg.batch_limits[j]
            overflow = members[cap:]
            if overflow:
                # reassign overflow to SSMs with headroom
                for i in overflow:
                    for j2 in sorted(range(M), key=lambda x: self.rng.random()):
                        load = sum(1 for a in assign.values() if a == j2)
                        if load < self.cfg.batch_limits[j2]:
                            assign[i] = j2
                            break
        return assign

    def _matching(self, request_ids: Sequence[int]) -> Dict[int, int]:
        """Exploitation: KM on estimated goodputs with B_j replicas."""
        slots: List[int] = []
        for j in range(self.cfg.n_ssms):
            slots += [j] * self.cfg.batch_limits[j]
        W = np.zeros((len(request_ids), len(slots)))
        for a, i in enumerate(request_ids):
            for b, j in enumerate(slots):
                W[a, b] = self.estimate(i, j)
        cols = km_match(W)
        out = {}
        load = [0] * self.cfg.n_ssms
        unmatched = []
        for i, c in zip(request_ids, cols):
            if c >= 0:
                out[i] = slots[c]
                load[slots[c]] += 1
            else:
                unmatched.append(i)
        # unmatched requests (all-zero estimates / padding-column ties)
        # fill SSMs by remaining headroom — defaulting them all to SSM 0
        # can overflow its batch cap B_0 and with it the draft pool
        for i in unmatched:
            j = min(range(self.cfg.n_ssms),
                    key=lambda x: load[x] - self.cfg.batch_limits[x])
            out[i] = j
            load[j] += 1
        return out

    def assign(self, request_ids: Sequence[int]) -> Dict[int, int]:
        """One time slot: returns request_id -> ssm index."""
        cfg = self.cfg
        if self.phase == "explore":
            if self.slot_in_phase % cfg.beta == 0:
                self._chunk_assign = self._random_capped(request_ids)
            else:
                # keep chunk assignment; new arrivals get random slots —
                # redirected to the least-loaded SSM when the random pick
                # is already at its batch cap (Algorithm 2's overflow
                # rule; same rng stream when caps never bind)
                load = [0] * cfg.n_ssms
                for r in request_ids:
                    a = self._chunk_assign.get(r)
                    if a is not None:
                        load[a] += 1
                for i in request_ids:
                    if i not in self._chunk_assign:
                        j = self.rng.randrange(cfg.n_ssms)
                        if load[j] >= cfg.batch_limits[j]:
                            j = min(range(cfg.n_ssms),
                                    key=lambda x: load[x]
                                    - cfg.batch_limits[x])
                        self._chunk_assign[i] = j
                        load[j] += 1
            out = {i: self._chunk_assign[i] for i in request_ids}
            self.slot_in_phase += 1
            if self.slot_in_phase >= cfg.alpha:
                self.phase = "exploit"
                self.slot_in_phase = 0
                self._exploit_assign = {}
        else:
            cohort = frozenset(request_ids)
            # Re-match whenever the live cohort changed (admission,
            # completion, preemption) — continuous batching means the set
            # of requests is different slot to slot, and a matching
            # computed for an old cohort misallocates the B_j slots.
            if not self._exploit_assign or cohort != self._exploit_cohort:
                self._exploit_assign = self._matching(request_ids)
                self._exploit_cohort = cohort
            out = {i: self._exploit_assign[i] for i in request_ids}
            self.slot_in_phase += 1
            if self.slot_in_phase >= 2 ** self.epoch:
                self.epoch += 1
                self.phase = "explore"
                self.slot_in_phase = 0
        # switching accounting
        for i, j in out.items():
            if i in self._last and self._last[i] != j:
                self.switches += 1
        self._last.update(out)
        return out

    def predicted_destination(self, request_id: int) -> int:
        """Fast-switching hint (§IV-C): the SSM whose KV cache should be
        pre-computed during idle time = argmax estimated goodput."""
        ests = [self.estimate(request_id, j)
                for j in range(self.cfg.n_ssms)]
        return int(np.argmax(ests))


class EpsilonGreedy:
    """§VI-B2 baseline: prob. eps -> best-known SSM, else random."""

    def __init__(self, cfg: SelectorConfig, eps: float = 0.2):
        self.cfg = cfg
        self.eps = eps
        self.rng = random.Random(cfg.seed)
        self.sum = defaultdict(float)
        self.cnt = defaultdict(int)
        self._last: Dict[int, int] = {}
        self.switches = 0

    def observe(self, request_id, ssm, goodput):
        self.sum[(request_id, ssm)] += goodput
        self.cnt[(request_id, ssm)] += 1

    def retire(self, request_id):
        self._last.pop(request_id, None)

    def assign(self, request_ids):
        out = {}
        load = [0] * self.cfg.n_ssms
        for i in request_ids:
            if self.rng.random() < self.eps:
                ests = [self.sum[(i, j)] / self.cnt[(i, j)]
                        if self.cnt[(i, j)] else 0.0
                        for j in range(self.cfg.n_ssms)]
                j = int(np.argmax(ests))
            else:
                j = self.rng.randrange(self.cfg.n_ssms)
            if load[j] >= self.cfg.batch_limits[j]:
                j = min(range(self.cfg.n_ssms),
                        key=lambda x: load[x] - self.cfg.batch_limits[x])
            load[j] += 1
            out[i] = j
            if i in self._last and self._last[i] != j:
                self.switches += 1
        self._last.update(out)
        return out


class GreedyPromptLength:
    """§VI-B2 baseline: short prompts -> small SSMs, long -> large."""

    def __init__(self, cfg: SelectorConfig, prompt_lens: Dict[int, int]):
        self.cfg = cfg
        self.prompt_lens = prompt_lens
        self._last: Dict[int, int] = {}
        self.switches = 0

    def observe(self, *a, **k):
        pass

    def retire(self, request_id):
        self._last.pop(request_id, None)

    def assign(self, request_ids):
        ordered = sorted(request_ids, key=lambda i: self.prompt_lens.get(i, 0))
        out = {}
        slot_iter = []
        for j in range(self.cfg.n_ssms):
            slot_iter += [j] * self.cfg.batch_limits[j]
        for i, j in zip(ordered, slot_iter):
            out[i] = j
        for i in request_ids:
            out.setdefault(i, self.cfg.n_ssms - 1)
        for i, j in out.items():
            if i in self._last and self._last[i] != j:
                self.switches += 1
        self._last.update(out)
        return out
