"""Mixture-of-Experts FFN (top-k routing, capacity-bounded, sort-free).

Dispatch uses rank-within-expert scatter/gather (memory ops, no O(T*E*C)
matmul) so the lowered FLOPs match a real EP implementation:
~ 3 * E * C * d_model * d_ff with C = ceil(T * top_k / E * capacity_factor).

Expert weights carry an "experts" logical axis so they can be sharded over
the model axis (EP) when divisible, else d_ff is tensor-parallel instead —
see distributed/sharding.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax



def capacity(n_tokens: int, n_experts: int, top_k: int, cf: float) -> int:
    c = int(n_tokens * top_k * cf / n_experts)
    return max(128, int((c + 127) // 128 * 128))  # 128-aligned for MXU tiles


def moe_ffn(x, router_w, w_gate, w_up, w_down, *, top_k: int, cf: float):
    """x: (T, d). w_*: (E, d, ff) / (E, ff, d). Returns (T, d), aux losses."""
    T, d = x.shape
    E = router_w.shape[1]
    C = capacity(T, E, top_k, cf)

    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = lax.top_k(probs, top_k)                  # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    flat_e = expert_ids.reshape(-1)                                  # (T*k,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)              # (T*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot                   # rank
    pos = jnp.sum(pos_in_e * onehot, axis=-1)                        # (T*k,)
    keep = pos < C
    token_idx = jnp.repeat(jnp.arange(T), top_k)

    # scatter token indices into (E, C) slots; dropped entries are routed to
    # an out-of-bounds expert index so mode="drop" discards them entirely.
    # Unfilled slots keep token 0 with validity 0, making the gather harmless.
    e_idx = jnp.where(keep, flat_e, E)
    slot_tok = jnp.zeros((E, C), jnp.int32).at[e_idx, pos].set(
        token_idx, mode="drop")
    slot_valid = jnp.zeros((E, C), x.dtype).at[e_idx, pos].set(
        jnp.ones_like(keep, x.dtype), mode="drop")

    xin = x[slot_tok] * slot_valid[..., None]                        # (E, C, d)
    # NOTE (§Perf, refuted hypothesis): constraining the dispatched slots
    # to stay data-local (exp_cap -> data) halves HBM traffic but inflates
    # collective bytes 1.4x (GSPMD inserts explicit reshards around the
    # data-dependent gather) — measured in results/perf_mixtral_moelocal.
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, w_gate)) \
        * jnp.einsum("ecd,edf->ecf", xin, w_up)
    y = jnp.einsum("ecf,efd->ecd", h, w_down)                        # (E, C, d)

    # combine: for each (token, k-slot) gather its expert output
    gather_pos = jnp.where(keep, pos, 0)
    yk = y[flat_e, gather_pos]                                       # (T*k, d)
    yk = yk * keep[:, None].astype(y.dtype)
    yk = yk.reshape(T, top_k, d) * gate_vals[..., None].astype(y.dtype)
    out = jnp.sum(yk, axis=1)

    # load-balancing aux loss (Switch-style) + router z-loss
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)
    zloss = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    return out.astype(x.dtype), aux, zloss
