"""Mamba2 (SSD) block in pure JAX — chunked-parallel train/prefill, O(1) decode.

State-space recurrence per head h with scalar decay:
    a_t = exp(A_h * dt_t),   S_t = a_t * S_{t-1} + dt_t * B_t x_t^T,
    y_t = C_t . S_t + D_h * x_t
Train/prefill uses the chunked (SSD) form: within-chunk quadratic term with
log-space decay ratios + cross-chunk state carry; mathematically identical to
the sequential recurrence (tested in tests/test_models.py).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import rms_norm
from repro.models.params import P


class Mamba2State(NamedTuple):
    ssd: jax.Array    # (B, nh, hd, ds) f32
    conv: jax.Array   # (B, k-1, conv_dim) rolling raw inputs


def param_spec(cfg):
    d, di, ds, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * ds
    return {
        "ln": P((d,), ("embed",), init="zeros"),
        "in_proj": P((d, 2 * di + 2 * ds + nh), ("embed", "ssm_in")),
        "conv_w": P((cfg.conv_kernel, conv_dim), (None, "ssm_conv")),
        "conv_b": P((conv_dim,), ("ssm_conv",), init="zeros"),
        "A_log": P((nh,), ("ssm_heads",), init="zeros"),
        "dt_bias": P((nh,), ("ssm_heads",), init="zeros"),
        "D": P((nh,), ("ssm_heads",), init="zeros"),
        "norm_w": P((di,), ("ssm_inner",), init="zeros"),
        "out_proj": P((di, d), ("ssm_inner", "embed")),
    }


def _split(cfg, proj):
    di, ds, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * ds]
    dt = proj[..., di + di + 2 * ds:]
    return z, xbc, dt


def _conv(cfg, xbc, conv_w, conv_b, prev):
    """Depthwise causal conv, kernel k.  prev: (B, k-1, C) history or None."""
    k = cfg.conv_kernel
    if prev is None:
        pad = jnp.zeros(xbc.shape[:-2] + (k - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = prev.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=-2)          # (B, S+k-1, C)
    out = sum(xp[..., i:i + xbc.shape[-2], :] * conv_w[i] for i in range(k))
    out = jax.nn.silu(out + conv_b)
    new_prev = xp[..., xp.shape[-2] - (k - 1):, :]
    return out, new_prev


def _ssd_chunk(xh, Bk, Ck, dt, a_log, state):
    """One chunk of SSD. xh: (B,Q,nh,hd)  Bk/Ck: (B,Q,ds)  dt,a_log: (B,Q,nh)
    state: (B,nh,hd,ds) f32.  Returns (y, new_state)."""
    B, Q, nh, hd = xh.shape
    la = jnp.cumsum(a_log, axis=1)                     # (B,Q,nh) log cumdecay
    # intra-chunk: y[i] += sum_{j<=i} (C_i.B_j) exp(la_i - la_j) dt_j x_j
    G = jnp.einsum("bis,bjs->bij", Ck, Bk)             # (B,Q,Q)
    ratio = la[:, :, None, :] - la[:, None, :, :]      # (B,i,j,nh)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    W = jnp.where(mask[None, :, :, None], jnp.exp(ratio), 0.0)
    W = W * G[..., None] * dt[:, None, :, :]           # (B,i,j,nh)
    y = jnp.einsum("bijh,bjhd->bihd", W, xh)
    # inter-chunk: y[i] += C_i . state * exp(la_i)
    y = y + jnp.einsum("bis,bhds,bih->bihd", Ck, state, jnp.exp(la))
    # state update: S' = exp(la_end) S + sum_j exp(la_end - la_j) dt_j B_j x_j^T
    wj = jnp.exp(la[:, -1:, :] - la) * dt              # (B,Q,nh)
    new_state = state * jnp.exp(la[:, -1])[:, :, None, None] \
        + jnp.einsum("bjh,bjhd,bjs->bhds", wj, xh, Bk)
    return y, new_state


def forward(params, x, cfg, *, state=None, chunk: int = 128,
            unroll_inner: bool = False):
    """x: (B, S, d). Returns (out, Mamba2State)."""
    Bsz, S, d = x.shape
    nh, hd, ds = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    dt_ = x.dtype

    proj = x @ params["in_proj"]
    z, xbc, dt = _split(cfg, proj)
    prev_conv = state.conv if state is not None else None
    xbc, new_conv = _conv(cfg, xbc, params["conv_w"], params["conv_b"],
                          prev_conv)
    xc = xbc[..., :cfg.d_inner]
    Bk = xbc[..., cfg.d_inner:cfg.d_inner + ds].astype(jnp.float32)
    Ck = xbc[..., cfg.d_inner + ds:].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,nh)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))                 # (nh,)
    a_log = A * dt                                                    # (B,S,nh)
    xh = xc.reshape(Bsz, S, nh, hd).astype(jnp.float32)

    s0 = state.ssd if state is not None else \
        jnp.zeros((Bsz, nh, hd, ds), jnp.float32)

    if S <= chunk:
        y, s_new = _ssd_chunk(xh, Bk, Ck, dt, a_log, s0)
    else:
        assert S % chunk == 0, (S, chunk)
        nc = S // chunk

        def body(s, xs):
            xh_c, B_c, C_c, dt_c, al_c = xs
            y_c, s = _ssd_chunk(xh_c, B_c, C_c, dt_c, al_c, s)
            return s, y_c

        def cs(t):  # (B,S,...) -> (nc, B, chunk, ...)
            return t.reshape((Bsz, nc, chunk) + t.shape[2:]).swapaxes(0, 1)

        s_new, ys = lax.scan(body, s0, (cs(xh), cs(Bk), cs(Ck), cs(dt),
                                        cs(a_log)),
                             unroll=nc if unroll_inner else 1)
        y = ys.swapaxes(0, 1).reshape(Bsz, S, nh, hd)

    y = y + params["D"].astype(jnp.float32)[:, None] * xh
    y = y.reshape(Bsz, S, cfg.d_inner).astype(dt_)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["norm_w"], cfg.norm_eps)
    out = y @ params["out_proj"]
    return out, Mamba2State(ssd=s_new, conv=new_conv)


def decode_step(params, x, cfg, state):
    """x: (B, 1, d) single token. O(1) sequential recurrence."""
    return forward(params, x, cfg, state=state, chunk=1)


def init_state(cfg, batch, dtype=jnp.float32):
    nh, hd, ds = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = cfg.d_inner + 2 * ds
    return Mamba2State(
        ssd=jnp.zeros((batch, nh, hd, ds), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim), dtype))


def abstract_state(cfg, batch, dtype=jnp.float32):
    nh, hd, ds = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = cfg.d_inner + 2 * ds
    return Mamba2State(
        ssd=jax.ShapeDtypeStruct((batch, nh, hd, ds), jnp.float32),
        conv=jax.ShapeDtypeStruct((batch, cfg.conv_kernel - 1, conv_dim),
                                  dtype))
