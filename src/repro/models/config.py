"""Model configuration.

One frozen dataclass covers every assigned architecture family:
dense / moe / ssm (mamba2, xlstm) / hybrid / audio-backbone / vlm-backbone.

Per-layer structure is expressed with ``block_pattern``: a tuple of block kind
strings.  Homogeneous stacks use a single kind and are scanned; heterogeneous
stacks (xlstm, zamba2) use repeating *units* so the layer stack still lowers to
a single ``lax.scan`` (small HLO, fast SPMD partitioning at 512 devices).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax.numpy as jnp

# Block kinds
ATTN = "attn"          # self-attention + SwiGLU MLP (pre-norm)
MOE = "moe"            # self-attention + MoE FFN
MAMBA2 = "mamba2"      # Mamba2 (SSD) block
MLSTM = "mlstm"        # xLSTM matrix-memory block
SLSTM = "slstm"        # xLSTM scalar-memory block
SHARED_ATTN = "shared_attn"  # zamba2-style shared-weight attention block


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False
    sliding_window: int = 0          # 0 -> full attention
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM
    ssm_state: int = 0               # mamba2 d_state
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_kernel: int = 4
    # Hybrid / heterogeneous stacks: the repeating unit of block kinds.
    # n_layers counts *all* block applications (len(unit) * n_units + tail).
    unit: Tuple[str, ...] = (ATTN,)
    tail: Tuple[str, ...] = ()       # trailing blocks not part of the scan
    # Frontend stubs for audio/vlm: inputs are precomputed embeddings.
    embed_inputs: bool = True        # False -> forward takes (B, S, d_model) embeds
    num_prefix_embeds: int = 0       # vlm: patch embeddings prepended to text
    # misc
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"          # compute dtype
    # Sub-quadratic flag used by launch/dryrun to honour long_500k skip rules.
    subquadratic: bool = False

    # ---- derived ----
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so it shards over the model axis."""
        return int(math.ceil(self.vocab_size / 256) * 256)

    @property
    def n_units(self) -> int:
        body = self.n_layers - len(self.tail)
        assert body % len(self.unit) == 0, (
            f"{self.name}: n_layers-{len(self.tail)} not divisible by unit "
            f"{self.unit}")
        return body // len(self.unit)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attn_positions(self) -> Tuple[int, ...]:
        """Indices (application order) of attention-bearing blocks."""
        kinds = list(self.unit) * self.n_units + list(self.tail)
        return tuple(i for i, k in enumerate(kinds)
                     if k in (ATTN, MOE, SHARED_ATTN))

    @property
    def is_attention_free(self) -> bool:
        return not self.attn_positions

    def params_count(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.hd
        n_q, n_kv = self.n_heads, self.n_kv_heads
        per = {}
        per[ATTN] = (d * (n_q + 2 * n_kv) * hd + n_q * hd * d
                     + 3 * d * self.d_ff + 2 * d)
        per[MOE] = (d * (n_q + 2 * n_kv) * hd + n_q * hd * d
                    + self.n_experts * 3 * d * self.d_ff + d * self.n_experts
                    + 2 * d)
        di, ds, nh = self.d_inner, self.ssm_state, self.ssm_heads
        per[MAMBA2] = (d * (2 * di + 2 * ds + nh) + di * d
                       + self.conv_kernel * (di + 2 * ds) + 3 * nh + di + d)
        pf = 2
        per[MLSTM] = (d * pf * d * 2 + pf * d * d          # up/down proj
                      + 3 * (pf * d) * (pf * d) // 1       # q,k,v proj (inner)
                      + 4 * pf * d + d)
        per[SLSTM] = (4 * d * d + 4 * d * (d // max(self.n_heads, 1))
                      + 2 * d * int(4 * d / 3) + d)
        per[SHARED_ATTN] = 0  # counted once below
        kinds = list(self.unit) * self.n_units + list(self.tail)
        n = sum(per[k] for k in kinds)
        if SHARED_ATTN in kinds:
            n += (d * (n_q + 2 * n_kv) * hd + n_q * hd * d
                  + 3 * d * self.d_ff + 2 * d)  # one shared copy
        n += self.padded_vocab * d  # embeddings
        if not self.tie_embeddings:
            n += self.padded_vocab * d  # lm head
        n += d  # final norm
        return int(n)

    def active_params_count(self) -> int:
        """Params touched per token (MoE: only top_k experts) for 6*N*D."""
        if self.n_experts and self.top_k:
            d = self.d_model
            dense_like = dataclasses.replace(
                self, n_experts=0, top_k=0,
                unit=tuple(ATTN if k == MOE else k for k in self.unit),
                tail=tuple(ATTN if k == MOE else k for k in self.tail))
            n_dense = dense_like.params_count()
            kinds = list(self.unit) * self.n_units + list(self.tail)
            n_moe_layers = sum(1 for k in kinds if k == MOE)
            # dense_like counted 1 expert worth of FFN; add (top_k - 1) more
            n_active = n_dense + n_moe_layers * (self.top_k - 1) * 3 * d * self.d_ff
            return int(n_active)
        return self.params_count()


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    # keep one unit + tail so every block kind is exercised
    small_unit = cfg.unit
    n_layers = 2 * len(small_unit) + len(cfg.tail)
    base = dict(
        name=cfg.name + "-reduced",
        family=cfg.family,
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=512,
        head_dim=16,
        qkv_bias=cfg.qkv_bias,
        sliding_window=min(cfg.sliding_window, 32) if cfg.sliding_window else 0,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16,
        ssm_expand=cfg.ssm_expand,
        conv_kernel=cfg.conv_kernel,
        unit=cfg.unit,
        tail=cfg.tail,
        embed_inputs=cfg.embed_inputs,
        num_prefix_embeds=min(cfg.num_prefix_embeds, 4),
        tie_embeddings=cfg.tie_embeddings,
        dtype="float32",
        subquadratic=cfg.subquadratic,
    )
    base.update(overrides)
    return ModelConfig(**base)
