"""Core layers: RMSNorm, RoPE, chunked-causal attention, SwiGLU MLP.

The attention here is the XLA-path reference used by training / prefill /
decode / SPIN packed verification.  It is flash-style *chunked over query
blocks* so no (S x S) score tensor is ever materialized — required for the
32k-prefill and 500k-decode dry-run shapes.  The Pallas kernels in
``repro.kernels`` implement the same math for the TPU hot path and are
validated against ``repro.kernels.ref`` which mirrors this module.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def rms_norm(x, weight, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * lax.rsqrt(var + eps) * (1.0 + weight.astype(jnp.float32))
            ).astype(dt)


def rope(x, positions, theta: float = 10000.0):
    """Rotary embeddings. x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    assert d % 2 == 0, f"RoPE needs even head_dim, got {d}"
    half = d // 2
    freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    # positions: (B, S) -> ang: (B, S, 1, half)
    ang = positions[:, :, None, None].astype(jnp.float32) * freq
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _attn_block(q, k, v, q_pos, kv_pos, q_seg, kv_seg, window, scale,
                q_anc=None, kv_node=None):
    """Attention for one query block against full K/V.

    q: (B, Qb, Kh, G, D)   k,v: (B, Skv, Kh, D)
    q_pos: (B, Qb)  kv_pos: (B, Skv)  segs same shapes (or None)
    q_anc/kv_node (optional, same shapes as segs): tree-speculation
    topology term — q_anc is the query's ancestor bitmask (-1 = any),
    kv_node the slot's tree-node tag (-1 committed, -2 dead, n >= 0 the
    node that wrote it; attendable iff bit n of q_anc is set).
    """
    s = jnp.einsum("bqkgd,bskd->bkgqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = kv_pos[:, None, :] <= q_pos[:, :, None]                # causal
    if window:
        mask &= kv_pos[:, None, :] > (q_pos[:, :, None] - window)
    if q_seg is not None:
        mask &= q_seg[:, :, None] == kv_seg[:, None, :]
    if kv_node is not None:
        nd = kv_node[:, None, :]
        on_path = ((q_anc[:, :, None] >> jnp.clip(nd, 0, 31)) & 1
                   ).astype(bool)
        mask &= jnp.where(nd == -1, True, jnp.where(nd < -1, False, on_path))
    s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    # rows with no valid key (padding query) -> all NEG_INF; keep finite
    m = jnp.maximum(m, -1e29)
    p = jnp.exp(s - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-30)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.astype(v.dtype)


def attention(q, k, v, *, q_positions, kv_positions,
              q_segments=None, kv_segments=None,
              q_anc=None, kv_node=None,
              window: int = 0, q_block: int = 512):
    """GQA chunked-causal attention.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Kh, D).  Hq % Kh == 0.
    positions are absolute token indices (causality = kv_pos <= q_pos).
    segments (optional) restrict attention to equal segment ids — this is the
    TPU-native form of SPIN Eq. (13): the softmax denominator sums over all
    packed tokens of the same original request and nothing else.
    q_anc / kv_node (optional) add the tree-speculation topology term on
    top: a query attends a node-tagged slot only along its own
    root-to-leaf path (see ``_attn_block``); omitted = linear behaviour.
    """
    B, Sq, Hq, D = q.shape
    Kh = k.shape[2]
    G = Hq // Kh
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    qg = q.reshape(B, Sq, Kh, G, D)

    if Sq <= q_block:
        o = _attn_block(qg, k, v, q_positions, kv_positions,
                        q_segments, kv_segments, window, scale,
                        q_anc, kv_node)
        return o.reshape(B, Sq, Hq, D)

    if Sq % q_block:
        # pad queries to a block multiple (e.g. vlm prefix makes S=33024);
        # padded rows carry position -1 -> fully masked -> sliced away.
        pad = q_block - Sq % q_block
        qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, pad)),
                              constant_values=-1)
        if q_segments is not None:
            q_segments = jnp.pad(q_segments, ((0, 0), (0, pad)),
                                 constant_values=-1)
        if q_anc is not None:
            q_anc = jnp.pad(q_anc, ((0, 0), (0, pad)), constant_values=0)
        out = attention(qg.reshape(B, Sq + pad, Hq, D), k, v,
                        q_positions=q_positions, kv_positions=kv_positions,
                        q_segments=q_segments, kv_segments=kv_segments,
                        q_anc=q_anc, kv_node=kv_node,
                        window=window, q_block=q_block)
        return out[:, :Sq]

    nq = Sq // q_block
    qs_blocks = qg.reshape(B, nq, q_block, Kh, G, D).transpose(1, 0, 2, 3, 4, 5)
    qp_blocks = q_positions.reshape(B, nq, q_block).transpose(1, 0, 2)
    if q_segments is None:
        seg_blocks = jnp.zeros((nq, B, q_block), jnp.int32)
        kv_segments_ = jnp.zeros_like(kv_positions)
    else:
        seg_blocks = q_segments.reshape(B, nq, q_block).transpose(1, 0, 2)
        kv_segments_ = kv_segments
    if q_anc is None:
        anc_blocks = jnp.full((nq, B, q_block), -1, jnp.int32)
        kv_node_ = None if kv_node is None else kv_node
    else:
        anc_blocks = q_anc.reshape(B, nq, q_block).transpose(1, 0, 2)
        kv_node_ = kv_node

    def body2(carry, xs):
        qb, qp, qs, qa = xs
        o = _attn_block(qb, k, v, qp, kv_positions, qs, kv_segments_,
                        window, scale,
                        None if kv_node_ is None else qa, kv_node_)
        return carry, o

    _, outs = lax.scan(body2, None,
                       (qs_blocks, qp_blocks, seg_blocks, anc_blocks))
    o = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, Hq, D)
    return o


def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def embed(tokens, table):
    return jnp.take(table, tokens, axis=0)


def unembed(x, table):
    return x @ table.T if table.shape[0] != x.shape[-1] else x @ table


def softmax_cross_entropy(logits, labels, mask=None, vocab_size: int = 0):
    """Mean CE over valid positions; logits may be vocab-padded."""
    logits = logits.astype(jnp.float32)
    if vocab_size and logits.shape[-1] > vocab_size:
        pad = logits.shape[-1] - vocab_size
        neg = jnp.full((pad,), NEG_INF, jnp.float32)
        logits = logits + jnp.concatenate(
            [jnp.zeros((vocab_size,), jnp.float32), neg])
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
