"""Decoder-only LM assembly for every assigned architecture family.

The layer stack is a single ``lax.scan`` over repeating *units* (see
config.ModelConfig.unit) so full-size models lower to a small HLO even at 512
devices.  Caches are uniform: attention blocks carry a (B, S, Kh, hd) KV grid
plus per-slot absolute positions and segment ids (-1 = empty slot).  This one
representation supports ragged serving batches, sliding-window ring buffers,
and SPIN's packed/decomposed verification (segment-restricted softmax =
paper Eq. 13) without shape changes.

Entry points
  apply(...)            train / scoring forward over a full sequence
  prefill(...)          forward + cache construction
  decode_step(...)      one-token generation step (the dry-run ``serve_step``)
  make_train_step(...)  loss + AdamW update, remat/scan configurable
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import config as C
from repro.models import mamba2, moe, xlstm
from repro.models import params as pp
from repro.models.layers import (attention, embed, rms_norm, rope,
                                 softmax_cross_entropy, swiglu)
from repro.models.params import P
from repro.distributed.sharding import constrain


@dataclasses.dataclass(frozen=True)
class Opts:
    q_block: int = 512          # query-block size of chunked attention
    ssd_chunk: int = 128        # mamba2 / mlstm chunk length
    unroll_inner: bool = False  # unroll inner scans (roofline accounting mode)
    unroll_layers: bool = False # unroll the unit scan (roofline mode)
    remat: str = "full"         # full | dots | none  (train only)
    scan_layers: bool = True
    attn_stub: bool = False     # perf accounting: replace attention by a
                                # zero-cost stub (measures the attention
                                # subgraph's exact share of flops/bytes)


# ------------------------------------------------------------- param spec --

def _attn_spec(cfg: C.ModelConfig, is_moe: bool) -> Dict[str, Any]:
    d, hd = cfg.d_model, cfg.hd
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    s: Dict[str, Any] = {
        "ln1": P((d,), ("embed",), init="zeros"),
        "wq": P((d, nq, hd), ("embed", "heads", "head_dim")),
        "wk": P((d, nkv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": P((d, nkv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": P((nq, hd, d), ("heads", "head_dim", "embed")),
        "ln2": P((d,), ("embed",), init="zeros"),
    }
    if cfg.qkv_bias:
        s["bq"] = P((nq, hd), ("heads", "head_dim"), init="zeros")
        s["bk"] = P((nkv, hd), ("kv_heads", "head_dim"), init="zeros")
        s["bv"] = P((nkv, hd), ("kv_heads", "head_dim"), init="zeros")
    if is_moe:
        s["router"] = P((d, cfg.n_experts), ("embed", None), scale=0.02)
        s["w_gate"] = P((cfg.n_experts, d, cfg.d_ff),
                        ("experts", "exp_embed", "mlp"))
        s["w_up"] = P((cfg.n_experts, d, cfg.d_ff),
                      ("experts", "exp_embed", "mlp"))
        s["w_down"] = P((cfg.n_experts, cfg.d_ff, d),
                        ("experts", "mlp", "exp_embed"))
    else:
        s["w_gate"] = P((d, cfg.d_ff), ("embed", "mlp"))
        s["w_up"] = P((d, cfg.d_ff), ("embed", "mlp"))
        s["w_down"] = P((cfg.d_ff, d), ("mlp", "embed"))
    return s


def _block_spec(cfg: C.ModelConfig, kind: str):
    if kind == C.ATTN:
        return _attn_spec(cfg, is_moe=False)
    if kind == C.MOE:
        return _attn_spec(cfg, is_moe=True)
    if kind == C.SHARED_ATTN:
        return {"ln1": P((cfg.d_model,), ("embed",), init="zeros")}  # see below
    if kind == C.MAMBA2:
        return mamba2.param_spec(cfg)
    if kind == C.MLSTM:
        return xlstm.mlstm_spec(cfg)
    if kind == C.SLSTM:
        return xlstm.slstm_spec(cfg)
    raise ValueError(kind)


def _stack_spec(spec, n: int):
    return jax.tree.map(
        lambda p: P((n,) + p.shape, ("layers",) + p.axes, p.init, p.scale),
        spec, is_leaf=pp.is_leaf)


def param_spec(cfg: C.ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    spec: Dict[str, Any] = {}
    if cfg.embed_inputs:
        spec["embed"] = P((cfg.padded_vocab, d), ("vocab", "embed"), scale=0.02)
    if not cfg.tie_embeddings or not cfg.embed_inputs:
        spec["lm_head"] = P((d, cfg.padded_vocab), ("embed", "vocab"))
    spec["final_norm"] = P((d,), ("embed",), init="zeros")

    unit = {}
    for i, kind in enumerate(cfg.unit):
        if kind == C.SHARED_ATTN:
            # per-application layernorms are private; weights shared (below)
            unit[f"u{i}_{kind}"] = _block_spec(cfg, kind)
        else:
            unit[f"u{i}_{kind}"] = _block_spec(cfg, kind)
    spec["scan"] = _stack_spec(unit, cfg.n_units)
    for i, kind in enumerate(cfg.tail):
        spec[f"tail{i}_{kind}"] = _block_spec(cfg, kind)
    if C.SHARED_ATTN in cfg.unit or C.SHARED_ATTN in cfg.tail:
        spec["shared_attn"] = _attn_spec(cfg, is_moe=False)
    return spec


def init_params(cfg, key, dtype=None):
    dtype = dtype or cfg.compute_dtype
    return pp.init_params(param_spec(cfg), key, dtype)


def abstract_params(cfg, dtype=None):
    dtype = dtype or cfg.compute_dtype
    return pp.abstract_params(param_spec(cfg), dtype)


def logical_axes(cfg):
    return pp.logical_axes(param_spec(cfg))


# ------------------------------------------------------------------ cache --

def cache_len(cfg: C.ModelConfig, max_len: int) -> int:
    if cfg.sliding_window:
        return min(max_len, cfg.sliding_window)
    return max_len


def _attn_cache_spec(cfg, batch, S):
    dt = cfg.compute_dtype
    Kh, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": ((batch, S, Kh, hd), dt),
        "v": ((batch, S, Kh, hd), dt),
        "pos": ((batch, S), jnp.int32),
        "seg": ((batch, S), jnp.int32),
    }


def _kind_cache(cfg, kind, batch, S, make):
    if kind in (C.ATTN, C.MOE, C.SHARED_ATTN):
        return {k: make(sh, dt) for k, (sh, dt)
                in _attn_cache_spec(cfg, batch, S).items()}
    if kind == C.MAMBA2:
        nh, hd, ds = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        conv_dim = cfg.d_inner + 2 * ds
        return mamba2.Mamba2State(
            ssd=make((batch, nh, hd, ds), jnp.float32),
            conv=make((batch, cfg.conv_kernel - 1, conv_dim),
                      cfg.compute_dtype))
    if kind == C.MLSTM:
        nh = cfg.n_heads
        dk = xlstm.PF_M * cfg.d_model // nh
        return xlstm.MLstmState(C=make((batch, nh, dk, dk), jnp.float32),
                                n=make((batch, nh, dk), jnp.float32))
    if kind == C.SLSTM:
        nh = cfg.n_heads
        hd = cfg.d_model // nh
        return xlstm.SLstmState(*[make((batch, nh, hd), jnp.float32)
                                  for _ in range(4)])
    raise ValueError(kind)


def _make_cache(cfg, batch, max_len, make):
    S = cache_len(cfg, max_len)

    def stacked(sh, dt):
        return make((cfg.n_units,) + sh, dt)

    cache: Dict[str, Any] = {"scan": {}}
    for i, kind in enumerate(cfg.unit):
        cache["scan"][f"u{i}_{kind}"] = _kind_cache(cfg, kind, batch, S,
                                                    stacked)
    for i, kind in enumerate(cfg.tail):
        cache[f"tail{i}_{kind}"] = _kind_cache(cfg, kind, batch, S, make)
    return cache


def init_cache(cfg, batch, max_len):
    def make(sh, dt):
        if dt == jnp.int32:
            return jnp.full(sh, -1, dt)   # seg/pos = -1 -> empty slot
        return jnp.zeros(sh, dt)
    return _make_cache(cfg, batch, max_len, make)


def _check_pageable(cfg):
    kinds = set(cfg.unit) | set(cfg.tail)
    bad = kinds - {C.ATTN, C.MOE, C.SHARED_ATTN}
    if bad:
        raise ValueError(f"paged KV needs attention-only models; {cfg.name} "
                         f"has recurrent-state blocks {sorted(bad)}")
    if cfg.sliding_window:
        raise ValueError("paged KV does not support sliding-window ring "
                         "buffers (window tail lives in the dense layout)")


def init_paged_cache(cfg, num_blocks, block_size, kv_dtype: str = "bf16"):
    """Paged KV block pool: same tree structure as ``init_cache`` but the
    leading cache axes are (physical block, slot-in-block) instead of
    (request row, position) — requests address it through block tables
    (serving/pool.py).  Attention-only models; see serving/paged.py.

    ``kv_dtype`` selects the block storage precision (kernels/quant.py):
    ``"bf16"`` keeps the compute dtype and the exact unquantized tree;
    ``"int8"``/``"fp8"`` store K/V quantized and add float32
    ``k_scale``/``v_scale`` sidecar leaves of shape
    ``(num_blocks, block_size, Kh)`` to every attention entry — indexed
    by the same block table as the blocks they scale."""
    from repro.kernels import quant
    _check_pageable(cfg)
    cache = init_cache(cfg, num_blocks, block_size)
    qdt = quant.storage_dtype(kv_dtype)
    if qdt is None:
        return cache

    def requant(entry):
        out = dict(entry)
        for leaf in ("k", "v"):
            out[leaf] = jnp.zeros(entry[leaf].shape, qdt)
            out[leaf + "_scale"] = jnp.zeros(entry[leaf].shape[:-1],
                                             jnp.float32)
        return out

    out = {"scan": {k: requant(v) for k, v in cache["scan"].items()}}
    for key, sub in cache.items():
        if key != "scan":
            out[key] = requant(sub)
    return out


def abstract_cache(cfg, batch, max_len):
    return _make_cache(cfg, batch, max_len,
                       lambda sh, dt: jax.ShapeDtypeStruct(sh, dt))


def cache_logical_axes(cfg, batch, max_len):
    """Logical-axis tree matching abstract_cache's structure (consumed by
    distributed/sharding.sharding_tree to build NamedShardings)."""
    attn_names = {
        4: ("cache_batch", "cache_seq", "kv_heads", "head_dim"),
        2: ("cache_batch", "cache_seq"),
    }
    ssm_names = {
        4: ("cache_batch", "ssm_heads", None, None),          # ssd state
        3: ("cache_batch", None, "ssm_conv"),                 # conv history
    }

    def axes_for(kind, leaf_shape, stacked):
        nd = len(leaf_shape) - (1 if stacked else 0)
        if kind in (C.ATTN, C.MOE, C.SHARED_ATTN):
            base = attn_names[nd]
        elif kind == C.MAMBA2:
            base = ssm_names.get(nd, ("cache_batch",) + (None,) * (nd - 1))
        else:  # mlstm / slstm states: (B, nh, ...), heads shardable
            base = ("cache_batch", "heads") + (None,) * (nd - 2)
        return (("layers",) + base) if stacked else base

    ab = abstract_cache(cfg, batch, max_len)

    def walk(tree, kind, stacked):
        return jax.tree.map(lambda l: axes_for(kind, l.shape, stacked), tree)

    out = {"scan": {}}
    for i, kind in enumerate(cfg.unit):
        name = f"u{i}_{kind}"
        out["scan"][name] = walk(ab["scan"][name], kind, True)
    for i, kind in enumerate(cfg.tail):
        name = f"tail{i}_{kind}"
        out[name] = walk(ab[name], kind, False)
    return out


# ----------------------------------------------------------------- blocks --

def _project_qkv(p, h, cfg, positions):
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _attn_block(p, x, cfg, opts, *, positions, segments, kv_cache,
                write_idx, is_moe, attend_cache=True, attn_override=None):
    """Returns (x_out, new_kv_cache, (moe_aux, moe_z)).

    kv_cache None              -> pure training forward (attend in-sequence)
    kv_cache, attend_cache=F   -> prefill: write K/V into the cache grid but
                                  attend over the full in-sequence K/V (the
                                  ring buffer only keeps the window tail)
    kv_cache, attend_cache=T   -> decode/verify: write at write_idx slots,
                                  attend over the whole cache grid.
    """
    B, S, d = x.shape
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(p, h, cfg, positions)
    segs = segments if segments is not None else jnp.zeros(
        (B, S), jnp.int32)

    new_cache = None
    if attn_override is not None:
        # SPIN packed verification: override handles attention + write-back
        o, new_cache = attn_override(q, k, v, positions, segs, kv_cache,
                                     cfg, opts)
    elif kv_cache is not None:
        bidx = jnp.arange(B)[:, None]
        kc = kv_cache["k"].at[bidx, write_idx].set(k.astype(kv_cache["k"].dtype))
        vc = kv_cache["v"].at[bidx, write_idx].set(v.astype(kv_cache["v"].dtype))
        pc = kv_cache["pos"].at[bidx, write_idx].set(positions)
        sc = kv_cache["seg"].at[bidx, write_idx].set(segs)
        new_cache = {"k": kc, "v": vc, "pos": pc, "seg": sc}

    if attn_override is not None:
        pass
    elif opts.attn_stub:
        # flash-accounting stub: keeps q/k/v projections + output shape,
        # removes the attention math (see benchmarks/perf_hillclimb.py)
        o = q * (jnp.mean(v) + jnp.mean(k))
    elif kv_cache is not None and attend_cache:
        o = attention(q, new_cache["k"], new_cache["v"],
                      q_positions=positions, kv_positions=new_cache["pos"],
                      q_segments=segs, kv_segments=new_cache["seg"],
                      window=cfg.sliding_window, q_block=opts.q_block)
    else:
        o = attention(q, k, v, q_positions=positions, kv_positions=positions,
                      q_segments=segments, kv_segments=segments,
                      window=cfg.sliding_window, q_block=opts.q_block)
    o = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    x = x + o
    x = constrain(x, "batch", "seq", "act_embed")

    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    aux = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
    if is_moe:
        hf = h.reshape(B * S, d)
        out, a, z = moe.moe_ffn(hf, p["router"], p["w_gate"], p["w_up"],
                                p["w_down"], top_k=cfg.top_k,
                                cf=cfg.capacity_factor)
        x = x + out.reshape(B, S, d)
        aux = (a, z)
    else:
        x = x + swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
    x = constrain(x, "batch", "seq", "act_embed")
    return x, new_cache, aux


def _apply_kind(kind, p, x, cfg, opts, *, positions, segments, cache,
                write_idx, shared, attend_cache=True, attn_override=None):
    zero = (jnp.zeros((), jnp.float32),) * 2
    if kind in (C.ATTN, C.MOE, C.SHARED_ATTN):
        weights = shared if kind == C.SHARED_ATTN else p
        if kind == C.SHARED_ATTN:
            weights = dict(shared)
            weights["ln1"] = p["ln1"]   # private per-application norm
        x, new_cache, aux = _attn_block(
            weights, x, cfg, opts, positions=positions, segments=segments,
            kv_cache=cache, write_idx=write_idx, is_moe=(kind == C.MOE),
            attend_cache=attend_cache, attn_override=attn_override)
        return x, new_cache, aux
    if kind == C.MAMBA2:
        h = rms_norm(x, p["ln"], cfg.norm_eps)
        out, st = mamba2.forward(p, h, cfg, state=cache,
                                 chunk=opts.ssd_chunk,
                                 unroll_inner=opts.unroll_inner)
        return constrain(x + out, "batch", "seq", "act_embed"), st, zero
    if kind == C.MLSTM:
        h = rms_norm(x, p["ln"], cfg.norm_eps)
        out, st = xlstm.mlstm_forward(p, h, cfg, state=cache,
                                      chunk=opts.ssd_chunk,
                                      unroll_inner=opts.unroll_inner)
        return constrain(x + out, "batch", "seq", "act_embed"), st, zero
    if kind == C.SLSTM:
        h = rms_norm(x, p["ln"], cfg.norm_eps)
        out, st = xlstm.slstm_forward(p, h, cfg, state=cache)
        return constrain(x + out, "batch", "seq", "act_embed"), st, zero
    raise ValueError(kind)


# ------------------------------------------------------------------ stack --

def _run_stack(params, x, cfg, opts, *, positions, segments, cache,
               write_idx, attend_cache=True, attn_override=None):
    """Run all units + tail. cache may be None (train).  Returns
    (x, new_cache_or_None, (aux_moe, aux_z))."""
    shared = params.get("shared_attn")
    want_cache = cache is not None

    def unit_body(carry, xs):
        x, am, az = carry
        p_unit, c_unit = xs
        new_c = {}
        for i, kind in enumerate(cfg.unit):
            name = f"u{i}_{kind}"
            c_in = c_unit[name] if want_cache else None
            x, c_out, (a, z) = _apply_kind(
                kind, p_unit[name], x, cfg, opts, positions=positions,
                segments=segments, cache=c_in, write_idx=write_idx,
                shared=shared, attend_cache=attend_cache,
                attn_override=attn_override)
            if want_cache:
                new_c[name] = c_out
            am, az = am + a, az + z
        return (x, am, az), (new_c if want_cache else 0)

    if opts.remat != "none":
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if opts.remat == "dots" else None)
        unit_body = jax.checkpoint(unit_body, policy=policy,
                                   prevent_cse=not opts.scan_layers)

    zero = jnp.zeros((), jnp.float32)
    c_scan = cache["scan"] if want_cache else _dummy_scan_xs(cfg)
    if opts.scan_layers:
        (x, am, az), ys = lax.scan(
            unit_body, (x, zero, zero), (params["scan"], c_scan),
            unroll=cfg.n_units if opts.unroll_layers else 1)
        new_scan = ys if want_cache else None
    else:
        carry = (x, zero, zero)
        outs = []
        for u in range(cfg.n_units):
            xs_u = jax.tree.map(lambda t: t[u], (params["scan"], c_scan))
            carry, y = unit_body(carry, xs_u)
            outs.append(y)
        (x, am, az) = carry
        new_scan = (jax.tree.map(lambda *ts: jnp.stack(ts), *outs)
                    if want_cache else None)

    new_cache = {"scan": new_scan} if want_cache else None
    for i, kind in enumerate(cfg.tail):
        name = f"tail{i}_{kind}"
        c_in = cache[name] if want_cache else None
        x, c_out, (a, z) = _apply_kind(
            kind, params[name], x, cfg, opts, positions=positions,
            segments=segments, cache=c_in, write_idx=write_idx, shared=shared,
            attend_cache=attend_cache, attn_override=attn_override)
        if want_cache:
            new_cache[name] = c_out
        am, az = am + a, az + z
    return x, new_cache, (am, az)


def _dummy_scan_xs(cfg):
    # scan requires xs with a leading axis; use tiny zeros when no cache.
    return {f"u{i}_{k}": jnp.zeros((cfg.n_units,), jnp.float32)
            for i, k in enumerate(cfg.unit)}


# ------------------------------------------------------------ entrypoints --

def _inputs_to_x(cfg, params, tokens, inputs_embeds, prefix_embeds):
    if cfg.embed_inputs:
        x = embed(tokens, params["embed"]).astype(cfg.compute_dtype)
    else:
        x = inputs_embeds.astype(cfg.compute_dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate(
            [prefix_embeds.astype(cfg.compute_dtype), x], axis=1)
    return x


def _logits(cfg, params, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings and cfg.embed_inputs:
        return x @ params["embed"].T
    return x @ params["lm_head"]


def apply(params, cfg, *, tokens=None, inputs_embeds=None, prefix_embeds=None,
          positions=None, segments=None, opts: Opts = Opts()):
    """Full-sequence forward. Returns (logits, (moe_aux, moe_z))."""
    x = _inputs_to_x(cfg, params, tokens, inputs_embeds, prefix_embeds)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = constrain(x, "batch", "seq", "act_embed")
    x, _, aux = _run_stack(params, x, cfg, opts, positions=positions,
                           segments=segments, cache=None, write_idx=None)
    logits = _logits(cfg, params, x)
    logits = constrain(logits, "batch", "seq", "vocab")
    return logits, aux


def prefill(params, cfg, *, tokens=None, inputs_embeds=None,
            prefix_embeds=None, lengths=None, max_len=None, segments=None,
            positions=None, last_logits_only=False, opts: Opts = Opts()):
    """Process prompts, build cache.  Returns (logits, cache).

    lengths: (B,) valid prompt lengths (tokens beyond are padding).
    max_len: cache capacity (defaults to prompt length + 0 slack).
    """
    x = _inputs_to_x(cfg, params, tokens, inputs_embeds, prefix_embeds)
    B, S, _ = x.shape
    if lengths is None:
        lengths = jnp.full((B,), S, jnp.int32)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if segments is None:
        segments = jnp.where(positions < lengths[:, None], 0, -1)
    max_len = max_len or S
    cache = init_cache(cfg, B, max_len)
    Sc = cache_len(cfg, max_len)
    if cfg.sliding_window and Sc < S:
        # ring buffer: only the last Sc positions land in the cache; earlier
        # ones are redirected out of bounds (scatter drops OOB updates).
        write_idx = jnp.where(positions >= S - Sc, positions % Sc, Sc)
    else:
        write_idx = jnp.minimum(positions, Sc - 1)
    x = constrain(x, "batch", "seq", "act_embed")
    x, cache, aux = _run_stack(params, x, cfg, opts, positions=positions,
                               segments=segments, cache=cache,
                               write_idx=write_idx, attend_cache=False)
    if last_logits_only:
        # gather each row's last valid position BEFORE the lm head so the
        # (B, S, vocab) logits tensor is never materialized (32k prefill).
        idx = jnp.maximum(lengths - 1, 0)
        x = jnp.take_along_axis(x, idx[:, None, None].astype(jnp.int32)
                                .repeat(x.shape[-1], -1), axis=1)
    logits = _logits(cfg, params, x)
    return logits, cache


def decode_step(params, cfg, cache, *, tokens=None, inputs_embeds=None,
                lengths=None, segments=None, attn_override=None,
                opts: Opts = Opts()):
    """One generation step. tokens: (B, T) with T new tokens per row (T=1 for
    plain serving; T=gamma+1 for SPIN verification rows).
    lengths: (B,) current context length per row.  Returns (logits, cache).
    attn_override (optional) replaces attention + KV write-back per layer —
    the paged-KV path (serving/paged.py) routes block tables through it."""
    x = _inputs_to_x(cfg, params, tokens, inputs_embeds, None)
    B, T, _ = x.shape
    positions = lengths[:, None] + jnp.arange(T, dtype=jnp.int32)[None]
    any_attn = bool(cfg.attn_positions)
    Sc = None
    if any_attn:
        # cache capacity from any attention entry
        for i, kind in enumerate(cfg.unit):
            if kind in (C.ATTN, C.MOE, C.SHARED_ATTN):
                Sc = cache["scan"][f"u{i}_{kind}"]["k"].shape[2]
                break
        if Sc is None:
            for i, kind in enumerate(cfg.tail):
                if kind in (C.ATTN, C.MOE, C.SHARED_ATTN):
                    Sc = cache[f"tail{i}_{kind}"]["k"].shape[1]
                    break
    if Sc is not None:
        write_idx = positions % Sc if cfg.sliding_window else positions
    else:
        write_idx = positions
    if segments is None:
        segments = jnp.zeros((B, T), jnp.int32)
    x = constrain(x, "batch", "seq", "act_embed")
    x, cache, _ = _run_stack(params, x, cfg, opts, positions=positions,
                             segments=segments, cache=cache,
                             write_idx=write_idx, attn_override=attn_override)
    logits = _logits(cfg, params, x)
    return logits, cache


def verify_step_packed(params, cfg, cache, *, tokens, positions, segments,
                       attn_override, opts: Opts = Opts()):
    """SPIN packed verification: all requests' query tokens flattened into
    one (1, Tq) row; attention and cache write-back are handled by the
    decompose.make_attn_override closure.  Returns (logits, cache)."""
    x = _inputs_to_x(cfg, params, tokens, None, None)
    x = constrain(x, "batch", "seq", "act_embed")
    x, cache, _ = _run_stack(params, x, cfg, opts, positions=positions,
                             segments=segments, cache=cache,
                             write_idx=None, attn_override=attn_override)
    logits = _logits(cfg, params, x)
    return logits, cache


# -------------------------------------------------------------- train step --

def loss_fn(params, cfg, batch, opts: Opts = Opts()):
    logits, (aux, z) = apply(
        params, cfg, tokens=batch.get("tokens"),
        inputs_embeds=batch.get("inputs_embeds"),
        prefix_embeds=batch.get("prefix_embeds"), opts=opts)
    labels = batch["labels"]
    if "prefix_embeds" in batch and batch["prefix_embeds"] is not None:
        Ppre = batch["prefix_embeds"].shape[1]
        logits = logits[:, Ppre:]
    # next-token prediction: logits[t] predicts labels[t]
    loss = softmax_cross_entropy(logits, labels, batch.get("mask"),
                                 cfg.vocab_size)
    total = loss + 0.01 * aux + 1e-3 * z
    return total, {"loss": loss, "moe_aux": aux, "moe_z": z}


def make_train_step(cfg, optimizer, opts: Opts = Opts()):
    def train_step(params, opt_state, batch):
        grad_fn = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, opts), has_aux=True)
        (total, metrics), grads = grad_fn(params)
        params, opt_state = optimizer.update(params, grads, opt_state)
        metrics["total"] = total
        return params, opt_state, metrics
    return train_step
