"""Parameter spec trees.

Model code declares a nested dict of ``P`` leaf specs (shape + logical axis
names + init).  Interpreters turn the spec into real arrays, abstract
ShapeDtypeStructs (for the dry-run: no allocation), or logical-axes trees
(consumed by distributed/sharding.py to build NamedShardings).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class P:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis name per dim (None = replicated)
    init: str = "normal"              # normal | zeros | ones
    scale: Optional[float] = None     # default: 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_leaf(x) -> bool:
    return isinstance(x, P)


def init_params(spec, key, dtype):
    """Materialize real parameter arrays from a spec tree."""
    leaves, treedef = jax.tree.flatten(spec, is_leaf=is_leaf)
    keys = jax.random.split(key, len(leaves))
    out = []
    for p, k in zip(leaves, keys):
        if p.init == "zeros":
            out.append(jnp.zeros(p.shape, dtype))
        elif p.init == "ones":
            out.append(jnp.ones(p.shape, dtype))
        else:
            fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
            scale = p.scale if p.scale is not None else 1.0 / np.sqrt(fan_in)
            out.append((jax.random.normal(k, p.shape, jnp.float32) * scale
                        ).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def abstract_params(spec, dtype):
    """ShapeDtypeStruct tree — used by .lower() so nothing is allocated."""
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype), spec, is_leaf=is_leaf)


def logical_axes(spec):
    """Tree of logical-axis tuples, same structure as the param tree."""
    return jax.tree.map(lambda p: p.axes, spec, is_leaf=is_leaf)


def count(spec) -> int:
    return sum(int(np.prod(p.shape))
               for p in jax.tree.leaves(spec, is_leaf=is_leaf))
