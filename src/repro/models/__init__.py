"""Model substrate: dense / MoE / SSM / hybrid decoder LMs in pure JAX."""

from repro.models.config import ModelConfig
from repro.models import transformer

__all__ = ["ModelConfig", "transformer"]
