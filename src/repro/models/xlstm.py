"""xLSTM blocks: mLSTM (matrix memory, chunked-parallel) and sLSTM (scalar
memory, strictly sequential recurrence).

mLSTM is computed with the same chunked log-space-decay machinery as Mamba2's
SSD: per-head scalar forget gate f_t acts as decay, exp input gate i_t as the
input scale, with both a value readout (numerator) and a key-sum readout
(denominator n).  This is the exact unstabilized mLSTM recurrence evaluated
stably in f32 with clamped input-gate logits (see DESIGN.md §8).

sLSTM keeps the h_{t-1} -> gates recurrence (not parallelizable); train uses
``lax.scan`` over time.  Its roofline contribution is corrected analytically
by the roofline driver (scan bodies are counted once by HLO cost analysis).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import rms_norm
from repro.models.params import P

PF_M = 2          # mLSTM up-projection factor
PF_S = 4.0 / 3.0  # sLSTM FFN factor
CLAMP = 8.0       # input-gate logit clamp


class MLstmState(NamedTuple):
    C: jax.Array   # (B, nh, dk, dv) f32
    n: jax.Array   # (B, nh, dk) f32


class SLstmState(NamedTuple):
    c: jax.Array   # (B, nh, hd) f32
    n: jax.Array
    h: jax.Array
    m: jax.Array


# ----------------------------------------------------------------- mLSTM --

def mlstm_spec(cfg):
    d = cfg.d_model
    di = PF_M * d
    return {
        "ln": P((d,), ("embed",), init="zeros"),
        "up_proj": P((d, 2 * di), ("embed", "xl_up")),
        "wq": P((di, di), ("xl_inner", "xl_inner2")),
        "wk": P((di, di), ("xl_inner", "xl_inner2")),
        "wv": P((di, di), ("xl_inner", "xl_inner2")),
        "w_gates": P((d, 2 * cfg.n_heads), ("embed", None)),
        "b_gates": P((2 * cfg.n_heads,), (None,), init="zeros"),
        "norm_w": P((di,), ("xl_inner",), init="zeros"),
        "down_proj": P((di, d), ("xl_inner", "embed")),
    }


def _mlstm_chunk(q, k, v, ig, la, state):
    """q,k,v: (B,Q,nh,dk/dv) f32; ig (input gate): (B,Q,nh); la: (B,Q,nh)
    log forget decay.  state: MLstmState.  Returns (h, new_state)."""
    B, Q, nh, dk = q.shape
    lac = jnp.cumsum(la, axis=1)
    G = jnp.einsum("bihd,bjhd->bijh", q, k)                 # (B,Q,Q,nh)
    ratio = lac[:, :, None, :] - lac[:, None, :, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    W = jnp.where(mask[None, :, :, None], jnp.exp(ratio), 0.0)
    W = W * G * ig[:, None, :, :]
    num = jnp.einsum("bijh,bjhe->bihe", W, v)
    den = jnp.sum(W, axis=2)                                # (B,Q,nh)
    decay_i = jnp.exp(lac)
    num = num + jnp.einsum("bihd,bhde->bihe", q, state.C) * decay_i[..., None]
    den = den + jnp.einsum("bihd,bhd->bih", q, state.n) * decay_i
    h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    wj = jnp.exp(lac[:, -1:, :] - lac) * ig
    C_new = state.C * jnp.exp(lac[:, -1])[..., None, None] \
        + jnp.einsum("bjh,bjhd,bjhe->bhde", wj, k, v)
    n_new = state.n * jnp.exp(lac[:, -1])[..., None] \
        + jnp.einsum("bjh,bjhd->bhd", wj, k)
    return h, MLstmState(C=C_new, n=n_new)


def mlstm_forward(params, x, cfg, *, state=None, chunk: int = 128,
                  unroll_inner: bool = False):
    B, S, d = x.shape
    nh = cfg.n_heads
    di = PF_M * d
    dk = di // nh
    dt_ = x.dtype

    up = x @ params["up_proj"]
    xi, z = up[..., :di], up[..., di:]
    q = (xi @ params["wq"]).reshape(B, S, nh, dk).astype(jnp.float32)
    k = (xi @ params["wk"]).reshape(B, S, nh, dk).astype(jnp.float32)
    v = (xi @ params["wv"]).reshape(B, S, nh, dk).astype(jnp.float32)
    q = q / jnp.sqrt(float(dk))
    gates = (x @ params["w_gates"] + params["b_gates"]).astype(jnp.float32)
    ig = jnp.exp(jnp.clip(gates[..., :nh], -CLAMP, CLAMP))   # (B,S,nh)
    la = jax.nn.log_sigmoid(gates[..., nh:])                 # log forget decay

    s0 = state if state is not None else MLstmState(
        C=jnp.zeros((B, nh, dk, dk), jnp.float32),
        n=jnp.zeros((B, nh, dk), jnp.float32))

    if S <= chunk:
        h, s_new = _mlstm_chunk(q, k, v, ig, la, s0)
    else:
        assert S % chunk == 0
        nc = S // chunk

        def cs(t):
            return t.reshape((B, nc, chunk) + t.shape[2:]).swapaxes(0, 1)

        def body2(s, xs):
            qc, kc, vc, igc, lac = xs
            h_c, s2 = _mlstm_chunk(qc, kc, vc, igc, lac, s)
            return s2, h_c

        s_new, hs = lax.scan(body2, s0, (cs(q), cs(k), cs(v), cs(ig), cs(la)),
                             unroll=nc if unroll_inner else 1)
        h = hs.swapaxes(0, 1).reshape(B, S, nh, dk)

    h = h.reshape(B, S, di).astype(dt_)
    h = rms_norm(h, params["norm_w"], cfg.norm_eps)
    h = h * jax.nn.silu(z)
    return h @ params["down_proj"], s_new


def mlstm_init_state(cfg, batch):
    nh = cfg.n_heads
    dk = PF_M * cfg.d_model // nh
    return MLstmState(C=jnp.zeros((batch, nh, dk, dk), jnp.float32),
                      n=jnp.zeros((batch, nh, dk), jnp.float32))


# ----------------------------------------------------------------- sLSTM --

def slstm_spec(cfg):
    d = cfg.d_model
    nh = cfg.n_heads
    hd = d // nh
    ff = int(PF_S * d)
    return {
        "ln": P((d,), ("embed",), init="zeros"),
        "w_in": P((d, 4 * d), ("embed", None)),          # i,f,z,o projections
        "r": P((4, nh, hd, hd), (None, "heads", None, None)),
        "b": P((4 * d,), (None,), init="zeros"),
        "norm_w": P((d,), ("embed",), init="zeros"),
        "ff_up": P((d, 2 * ff), ("embed", "mlp")),
        "ff_down": P((ff, d), ("mlp", "embed")),
    }


def slstm_forward(params, x, cfg, *, state=None):
    """Sequential sLSTM. x: (B, S, d)."""
    B, S, d = x.shape
    nh = cfg.n_heads
    hd = d // nh
    dt_ = x.dtype

    xproj = (x @ params["w_in"] + params["b"]).astype(jnp.float32)
    xproj = xproj.reshape(B, S, 4, nh, hd)
    r = params["r"].astype(jnp.float32)

    s0 = state if state is not None else SLstmState(
        c=jnp.zeros((B, nh, hd), jnp.float32),
        n=jnp.zeros((B, nh, hd), jnp.float32),
        h=jnp.zeros((B, nh, hd), jnp.float32),
        m=jnp.zeros((B, nh, hd), jnp.float32))

    def step(s, xp):
        # xp: (B, 4, nh, hd); recurrent contribution from h_{t-1}
        rec = jnp.einsum("bhd,ghde->bghe", s.h, r)      # (B,4,nh,hd)
        g = xp + rec
        it, ft, zt, ot = g[:, 0], g[:, 1], g[:, 2], g[:, 3]
        m_new = jnp.maximum(ft + s.m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(ft + s.m - m_new)
        c_new = f_p * s.c + i_p * jnp.tanh(zt)
        n_new = f_p * s.n + i_p
        h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1.0)
        return SLstmState(c_new, n_new, h_new, m_new), h_new

    xs = xproj.swapaxes(0, 1)                           # (S, B, 4, nh, hd)
    s_new, hs = lax.scan(step, s0, xs)
    h = hs.swapaxes(0, 1).reshape(B, S, d).astype(dt_)
    h = rms_norm(h, params["norm_w"], cfg.norm_eps)
    up = h @ params["ff_up"]
    ff = up.shape[-1] // 2
    h = jax.nn.gelu(up[..., :ff]) * up[..., ff:]
    return h @ params["ff_down"], s_new


def slstm_init_state(cfg, batch):
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    z = jnp.zeros((batch, nh, hd), jnp.float32)
    return SLstmState(c=z, n=z, h=z, m=z)
