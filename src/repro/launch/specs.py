"""ShapeDtypeStruct stand-ins for every (arch x input-shape) dry-run cell.

No device allocation ever happens here — these feed jax.jit(...).lower().

Shape set (assigned):
  train_4k     seq 4096,  global_batch 256   -> train_step
  prefill_32k  seq 32768, global_batch 32    -> prefill_step
  decode_32k   ctx 32768, global_batch 128   -> serve_step (1 new token)
  long_500k    ctx 524288, global_batch 1    -> serve_step; ONLY for
               sub-quadratic archs (cfg.subquadratic) per the skip rule.

[audio]/[vlm] cells: the frontend is a stub — inputs are precomputed frame
(B, S, d) / patch (B, P, d) embeddings, exactly as input_specs() returns.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def cell_applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and not cfg.subquadratic:
        return False, ("skip: pure full-attention arch — 500k decode needs "
                       "sub-quadratic attention (DESIGN.md §5)")
    return True, ""


def input_specs(cfg: ModelConfig, shape: str) -> Dict[str, Any]:
    """Returns the kwargs tree of ShapeDtypeStructs for the step function."""
    info = SHAPES[shape]
    B, S = info["batch"], info["seq"]
    i32 = jnp.int32
    cdt = cfg.compute_dtype

    if info["kind"] == "train":
        batch: Dict[str, Any] = {}
        if cfg.embed_inputs:
            batch["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        else:
            batch["inputs_embeds"] = jax.ShapeDtypeStruct(
                (B, S, cfg.d_model), cdt)
        if cfg.num_prefix_embeds:
            batch["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_prefix_embeds, cfg.d_model), cdt)
        batch["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        return {"batch": batch}

    if info["kind"] == "prefill":
        kw: Dict[str, Any] = {"lengths": jax.ShapeDtypeStruct((B,), i32)}
        if cfg.embed_inputs:
            kw["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        else:
            kw["inputs_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                       cdt)
        if cfg.num_prefix_embeds:
            kw["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_prefix_embeds, cfg.d_model), cdt)
        return kw

    # decode: one new token with a KV cache of seq_len
    kw = {
        "cache": T.abstract_cache(cfg, B, S),
        "lengths": jax.ShapeDtypeStruct((B,), i32),
    }
    if cfg.embed_inputs:
        kw["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
    else:
        kw["inputs_embeds"] = jax.ShapeDtypeStruct((B, 1, cfg.d_model), cdt)
    return kw


def batch_logical_axes(batch_tree) -> Any:
    """Logical axes for the train/prefill/decode input trees."""
    def axes(path_leaf):
        name, leaf = path_leaf
        if name in ("tokens", "labels"):
            return ("batch", "seq")[:len(leaf.shape)]
        if name in ("inputs_embeds", "prefix_embeds"):
            return ("batch", "seq", "act_embed")
        if name == "lengths":
            return ("batch",)
        return tuple(None for _ in leaf.shape)

    return {k: (axes((k, v)) if not isinstance(v, dict)
                else {k2: axes((k2, v2)) for k2, v2 in v.items()})
            for k, v in batch_tree.items()}
