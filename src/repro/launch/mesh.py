"""Production mesh definitions.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (tests see one CPU device; only launch/dryrun.py
sets the 512-placeholder-device XLA flag before first jax init).

Topology: TPU v5e pods of 16x16 = 256 chips.  Single pod: (data=16,
model=16) — ICI on both axes.  Multi-pod: leading `pod` axis (size 2 here;
scales to N pods) mapped over DCN, used for data parallelism with optional
gradient compression (distributed/collectives.py).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (CPU tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"))
