"""Production mesh definitions.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (tests see one CPU device; only launch/dryrun.py
sets the 512-placeholder-device XLA flag before first jax init).

Topology: TPU v5e pods of 16x16 = 256 chips.  Single pod: (data=16,
model=16) — ICI on both axes.  Multi-pod: leading `pod` axis (size 2 here;
scales to N pods) mapped over DCN, used for data parallelism with optional
gradient compression (distributed/collectives.py).

Multi-replica serving adds a leading ``replica`` axis: each index along
it is one full serving cell — an independent SpinEngine whose LLM is
sharded over that slice's remaining (data, model) axes.  The replica
axis carries NO collectives (replicas never communicate; the router in
serving/router.py balances the request stream between them), so it maps
over DCN for free.  ``replica_submeshes`` carves the per-replica
sub-meshes; the existing rule tables in distributed/sharding.py apply
unchanged because the replica axis never appears inside a sub-mesh.
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False, replicas: int = 1):
    shape: Tuple[int, ...] = (2, 16, 16) if multi_pod else (16, 16)
    axes: Tuple[str, ...] = (("pod", "data", "model") if multi_pod
                             else ("data", "model"))
    if replicas > 1:
        shape = (replicas,) + shape
        axes = ("replica",) + axes
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1, replicas: int = 1):
    """Small mesh over whatever devices exist (CPU tests / examples)."""
    if replicas > 1:
        return jax.make_mesh((replicas, data, model),
                             ("replica", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def carve_replica_axis(devices: np.ndarray, axis_names: Tuple[str, ...]
                       ) -> Tuple[List[np.ndarray], Tuple[str, ...]]:
    """Split a mesh's device array along its ``replica`` axis: one device
    sub-array per replica, plus the axis names that remain.  Pure array
    logic (unit-testable without multi-device jax); without a replica
    axis the whole array is the single replica's."""
    if "replica" not in axis_names:
        return [devices], tuple(axis_names)
    ax = list(axis_names).index("replica")
    moved = np.moveaxis(np.asarray(devices), ax, 0)
    names = tuple(n for n in axis_names if n != "replica")
    return [moved[i] for i in range(moved.shape[0])], names


def replica_submeshes(mesh) -> List[jax.sharding.Mesh]:
    """One sub-mesh per index of the mesh's ``replica`` axis (the whole
    mesh if it has none).  Each sub-mesh keeps the remaining axes, so
    serve/train rule tables resolve against it exactly as on a
    single-replica mesh — replicas are full parameter copies, data
    parallel over the replica axis by construction."""
    parts, names = carve_replica_axis(np.asarray(mesh.devices),
                                      tuple(mesh.axis_names))
    if len(parts) == 1 and "replica" not in mesh.axis_names:
        return [mesh]
    return [jax.sharding.Mesh(p, names) for p in parts]


def elastic_replica_submeshes(mesh, replicas_max: int
                              ) -> List[jax.sharding.Mesh]:
    """Pre-carve the MAXIMUM fleet's sub-meshes for the elastic router.

    Device meshes cannot be re-carved while engines hold sharded arrays
    on them, so autoscaling provisions capacity the same way real fleets
    do: the full ``replicas_max`` device slice is reserved up front, one
    sub-mesh (and one standby engine) per slot, and the router's
    lifecycle states — not the mesh — decide which slots are serving.
    The *provisioning ledger* (FleetStats.provisioned_s) then charges
    only active sim-seconds, the honest cost an operator who can
    release idle slices back to the pool would pay.

    The mesh's replica axis must carry exactly ``replicas_max`` slots —
    a mismatch means the launch carved a different fleet than the
    router was configured for, which would mispair engines and device
    slices silently."""
    if replicas_max < 1:
        raise ValueError("replicas_max must be >= 1")
    subs = replica_submeshes(mesh)
    if len(subs) != replicas_max:
        raise ValueError(
            f"mesh carves {len(subs)} replica sub-meshes but the elastic "
            f"fleet needs replicas_max={replicas_max} — launch with "
            f"--replicas equal to --replicas-max")
    return subs
