"""SPIN serving launcher.

    python -m repro.launch.serve --dataset mix --requests 16 \
        --selector lbss --gamma 4 [--no-packed] [--no-pipeline] \
        [--arrival-rate 200] [--kv-budget 512] [--scheduler continuous] \
        [--kv-layout paged|dense] [--block-size 16] \
        [--replicas 2 --router-policy lot]

Builds the heterogeneous SSM zoo + LLM (reduced configs on CPU; the same
code paths drive full configs on a pod, where ``--mesh`` places the LLM on
the `model` axis via pjit and each SSM replica on a dedicated data slice —
see DESIGN.md §6), then drives the continuous-batching scheduler loop
until the request stream drains.  ``--arrival-rate`` turns the workload
into a streaming Poisson arrival process (requests/sec on the sim clock);
without it every request arrives at t=0.  ``--scheduler static`` keeps the
seed-style gang-scheduled cohort baseline for comparison.

``--replicas N`` serves the stream through N independent engine replicas
behind a router (serving/router.py): ``--capacity`` and ``--kv-budget``
are *aggregate* figures split evenly across replicas, so a replica-count
sweep compares at fixed total resources.  Every flag is documented with
its defaults and interactions in docs/SERVING.md (CI keeps the two in
sync — see tools/check_docs.py).
"""

from __future__ import annotations

import argparse
import dataclasses
import json

import jax

from repro.configs import spin_llama
from repro.core import decompose as D
from repro.core import spec_decode as sd
from repro.core.selector import (LBSS, EpsilonGreedy, GreedyPromptLength,
                                 SelectorConfig)
from repro.data.workloads import (bursty_arrivals, diurnal_arrivals,
                                  make_workload)
from repro.models import transformer as T
from repro.models.config import reduced
from repro.serving.engine import EngineConfig, SpinEngine
from repro.serving.router import (CLASS_KV_WEIGHTS, Router, RouterConfig,
                                  class_engine_config, parse_replica_classes)


def build_zoo(vocab: int, seed: int = 0, n_ssms: int = 3):
    """Reduced-scale LLM + heterogeneous SSM zoo (shape-faithful families
    of the paper's LLaMA 68M..1.4B lineup)."""
    key = jax.random.PRNGKey(seed)
    cfg_llm = reduced(spin_llama.LLAMA_7B, d_model=96, n_heads=4,
                      n_kv_heads=4, vocab_size=vocab, n_layers=4)
    llm = sd.Bundle(cfg_llm, T.init_params(cfg_llm, key))
    dims = [(32, 1), (48, 2), (64, 2), (96, 3), (96, 4)][:n_ssms]
    ssms = []
    for i, (d, L) in enumerate(dims):
        c = reduced(spin_llama.SSM_ZOO[min(i, 4)], d_model=d, n_heads=4,
                    n_kv_heads=4, vocab_size=vocab, n_layers=L)
        ssms.append(sd.Bundle(c, T.init_params(c, jax.random.PRNGKey(i + 1))))
    return llm, ssms


def make_selector(kind: str, n_ssms: int, cap: int, prompt_lens=None,
                  seed: int = 0, group_of=None):
    scfg = SelectorConfig(n_ssms=n_ssms, batch_limits=[cap] * n_ssms,
                          alpha=6, beta=2, seed=seed)
    if kind == "lbss":
        return LBSS(scfg, group_of=group_of)
    if kind == "eps":
        return EpsilonGreedy(scfg, eps=0.2)
    if kind == "greedy":
        return GreedyPromptLength(scfg, prompt_lens or {})
    raise ValueError(kind)


def split_evenly(total: int, n: int):
    """Split an aggregate resource into n near-equal shares (remainder
    to the first replicas) — used so ``--capacity`` and ``--kv-budget``
    stay *aggregate* figures under ``--replicas``.  Shares are zero when
    ``total < n``; callers must validate that every replica gets a
    usable share (serve.py errors out for both budgets)."""
    base, rem = divmod(int(total), n)
    return [base + (1 if i < rem else 0) for i in range(n)]


def split_weighted(total: int, weights):
    """Split an aggregate resource proportionally to integer weights
    (largest-remainder rounding, ties to the lower index) — the
    heterogeneous-fleet KV split: a ``decode`` replica holds
    long-resident contexts and takes a bigger share than a ``prefill``
    replica that turns its cache over per chunk."""
    wsum = sum(weights)
    raw = [int(total) * w / wsum for w in weights]
    shares = [int(x) for x in raw]
    rem = int(total) - sum(shares)
    order = sorted(range(len(weights)),
                   key=lambda i: (-(raw[i] - shares[i]), i))
    for i in order[:rem]:
        shares[i] += 1
    return shares


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="mix",
                    choices=["alpaca", "cp", "cip", "mix"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--selector", default="lbss",
                    choices=["lbss", "eps", "greedy"])
    ap.add_argument("--n-ssms", type=int, default=3)
    ap.add_argument("--gamma", type=int, default=4,
                    help="speculation depth: the uniform per-request depth "
                         "under --gamma-policy fixed, the cold-start "
                         "default under adaptive")
    ap.add_argument("--gamma-policy", default="fixed",
                    choices=["fixed", "adaptive"],
                    help="fixed: draft --gamma tokens for every request "
                         "every slot (seed behaviour, bit-identical); "
                         "adaptive: per-request expected-goodput depth in "
                         "[1, --gamma-max] from the selector's acceptance "
                         "estimates, load-capped under --token-budget")
    ap.add_argument("--gamma-max", type=int, default=None,
                    help="adaptive speculation-depth cap (KV margins and "
                         "admission reserve this worst case); default "
                         "2 * --gamma")
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--no-packed", action="store_true")
    ap.add_argument("--no-pipeline", action="store_true")
    ap.add_argument("--max-slots", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scale", type=float, default=0.3)
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="Poisson arrival rate (req/s, sim clock); "
                         "default: all requests arrive at t=0")
    ap.add_argument("--capacity", type=int, default=None,
                    help="LLM pool rows (default: --requests)")
    ap.add_argument("--kv-budget", type=int, default=None,
                    help="total KV cells before preemption kicks in "
                         "(paged layout: rounded down to whole blocks and "
                         "enforced as the physical block pool)")
    ap.add_argument("--scheduler", default="continuous",
                    choices=["continuous", "static"])
    ap.add_argument("--kv-layout", default="paged",
                    choices=["paged", "dense"],
                    help="KV memory layout: block-table paging (default) "
                         "or the legacy dense capacity x max_len grid")
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV cells per physical block (paged layout); "
                         "128 matches TPU tile granularity at full scale, "
                         "16 keeps reduced CPU runs snappy")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill: max prompt tokens ingested per "
                         "request per slot, interleaved with decode "
                         "(Sarathi-style); 0 = monolithic prefill-on-admit")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="per-slot LLM query-token budget shared between "
                         "decode slots (gamma+1 tokens each) and prefill "
                         "chunks; default: unthrottled")
    ap.add_argument("--spec-shape", default="linear",
                    choices=["linear", "tree"],
                    help="speculation shape: linear drafts one chain per "
                         "request; tree splits each granted depth across "
                         "up to --spec-branch branches (the drafter's "
                         "top-k first-step candidates), forks the paged "
                         "KV row copy-on-write per branch and verifies "
                         "the whole token tree in one packed pass (needs "
                         "--kv-layout paged and packed verification; "
                         "falls back to linear with a warning otherwise)")
    ap.add_argument("--spec-branch", type=int, default=2,
                    help="tree-speculation branching factor (only with "
                         "--spec-shape tree); 1 is bit-identical to "
                         "linear; gamma_max + branches must fit the "
                         "ancestor-mask node budget "
                         f"({D.max_tree_nodes()} nodes)")
    ap.add_argument("--fused-kernels", default="off",
                    choices=["on", "off"],
                    help="route the paged decode/verify hot path through "
                         "the fused single-launch Pallas kernels "
                         "(kernels/fused_decode.py, fused_verify.py), "
                         "tile shapes resolved from the autotune cache "
                         "(results/TUNE_cache.json, safe default on a "
                         "cold miss); off keeps the gather + "
                         "paged-attention path bit-identically (needs "
                         "--kv-layout paged; falls back with a warning "
                         "otherwise)")
    ap.add_argument("--kv-dtype", default="bf16",
                    choices=["bf16", "int8", "fp8"],
                    help="paged-KV block storage dtype: bf16 stores the "
                         "model's compute dtype (bit-identical default); "
                         "int8/fp8 store quantized blocks with per-(slot, "
                         "head) float32 scale sidecars — 2-4x more "
                         "resident contexts per --kv-budget, dequant "
                         "fused into the attention kernels (needs "
                         "--kv-layout paged; falls back to bf16 with a "
                         "warning otherwise)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="independent engine replicas behind the router "
                         "(serving/router.py); --capacity and --kv-budget "
                         "are aggregate and split evenly across replicas")
    ap.add_argument("--router-policy", default=None,
                    choices=["lot", "p2c", "slo"],
                    help="replica dispatch policy: lot = least outstanding "
                         "tokens (default), p2c = power-of-two-choices on "
                         "free KV blocks, slo = most cluster-level SLO "
                         "headroom (deadline slack net of backlog drain "
                         "time); passing this flag routes even a "
                         "single replica through the router (bit-identical "
                         "to the bare engine)")
    ap.add_argument("--slo-profile", default="off",
                    choices=["off", "strict", "lax", "interactive"],
                    help="stamp per-class SLO contracts "
                         "(TTFT deadline + per-token target, "
                         "data/workloads.py SLO_PROFILES) onto the "
                         "workload and make admission order, prefill "
                         "chunk sizing, adaptive speculation depth and "
                         "slo routing deadline-aware; off (default) "
                         "stamps nothing and is bit-identical to the "
                         "deadline-blind engine")
    ap.add_argument("--slo-scale", type=float, default=1.0,
                    help="multiply every --slo-profile deadline (>1 lax, "
                         "<1 strict) — one profile serves "
                         "differently-calibrated cost models")
    ap.add_argument("--arrival-pattern", default="poisson",
                    choices=["poisson", "diurnal", "bursty"],
                    help="shape of the --arrival-rate stream: poisson = "
                         "constant-rate (default); diurnal = sinusoidal "
                         "day/night curve between --arrival-rate (peak) "
                         "and a fifth of it (trough); bursty = quiet "
                         "baseline with periodic full-rate bursts — the "
                         "autoscaling workloads (data/workloads.py "
                         "diurnal_arrivals / bursty_arrivals); both need "
                         "--arrival-rate")
    ap.add_argument("--autoscale", default="off",
                    choices=["off", "target-occupancy"],
                    help="elastic fleet control (serving/router.py): off "
                         "(default) keeps every replica serving for the "
                         "whole run, bit-identical to the pre-elastic "
                         "router; target-occupancy scales the active set "
                         "between --replicas-min and --replicas-max "
                         "against mean KV occupancy, backlog and SLO "
                         "headroom, with drain-before-retire")
    ap.add_argument("--replicas-min", type=int, default=1,
                    help="smallest active fleet the autoscaler may drain "
                         "down to (only with --autoscale)")
    ap.add_argument("--replicas-max", type=int, default=None,
                    help="largest active fleet the autoscaler may grow to; "
                         "this many engines and mesh sub-slices are "
                         "pre-carved up front (idle ones cost nothing on "
                         "the provisioning ledger); default: --replicas")
    ap.add_argument("--steal", default="auto",
                    choices=["auto", "on", "off"],
                    help="work stealing of queued, not-yet-prefilled "
                         "requests from hot replicas to the least-loaded "
                         "one when re-prefilling there beats the expected "
                         "wait (no KV migrates); auto (default) = on "
                         "exactly when --autoscale is")
    ap.add_argument("--replica-classes", default="",
                    help="heterogeneous fleet spec, e.g. "
                         "'prefill:1,decode:3': per-class engine configs "
                         "(prefill-heavy: forced chunking + doubled "
                         "--token-budget + shallow adaptive speculation; "
                         "decode: KV-weighted share of --kv-budget) with "
                         "class-affine dispatch — long-prompt requests "
                         "prefer prefill replicas, long-output ones "
                         "decode replicas; empty (default) = homogeneous "
                         "fleet, bit-identical to no classes; the spec's "
                         "total must match --replicas when both are given")
    return ap


def main(argv=None):
    ap = build_parser()
    args = ap.parse_args(argv)
    # flag translation + cross-flag validation live in the configs'
    # from_args constructors (serving/engine.py et al.) — ONE place tests
    # and benchmarks share; this launcher only maps ValueError to the
    # argparse exit and validates the cluster-level (multi-config) splits
    try:
        base_ecfg = EngineConfig.from_args(args)
        rcfg = RouterConfig.from_args(args)
    except ValueError as e:
        ap.error(str(e))
    if args.arrival_rate is not None and args.arrival_rate <= 0:
        ap.error("--arrival-rate must be positive (omit it for "
                 "all-at-t=0 arrivals)")
    if args.capacity is not None and args.capacity <= 0:
        ap.error("--capacity must be positive")
    if args.replicas <= 0:
        ap.error("--replicas must be positive")
    if args.slo_scale <= 0:
        ap.error("--slo-scale must be positive")

    # fleet shape: --replica-classes may define the replica count on its
    # own (--replicas 1 default), and the elastic fleet pre-carves
    # --replicas-max engines up front (launch.mesh.elastic_replica_
    # submeshes on a pod) — standby engines cost nothing on the
    # provisioning ledger until the autoscaler activates them
    classes = parse_replica_classes(args.replica_classes)
    n_rep = args.replicas
    if classes:
        if args.replicas != 1 and len(classes) != args.replicas:
            ap.error(f"--replica-classes carves {len(classes)} replicas "
                     f"but --replicas says {args.replicas} — drop one "
                     "flag or make them agree")
        n_rep = len(classes)
    n_eng = args.replicas_max if args.replicas_max is not None else n_rep
    if n_eng < n_rep:
        ap.error(f"--replicas-max {n_eng} is below the fleet size "
                 f"{n_rep}")
    if classes and len(classes) != n_eng:
        ap.error(f"--replica-classes carves {len(classes)} replicas but "
                 f"the pre-carved fleet is {n_eng} (--replicas-max) — "
                 "give every slot a class")
    if args.replicas_min > n_eng:
        ap.error(f"--replicas-min {args.replicas_min} exceeds the "
                 f"pre-carved fleet of {n_eng}")
    if not classes:
        classes = ["general"] * n_eng

    arrival_rate, arrival_trace = args.arrival_rate, None
    if args.arrival_pattern != "poisson":
        if args.arrival_rate is None:
            ap.error("--arrival-pattern diurnal/bursty needs "
                     "--arrival-rate (the peak rate)")
        # span: the seconds a constant peak-rate stream would cover;
        # diurnal runs ~one day/night cycle over ~2x that, bursty fires
        # one burst per span
        span = args.requests / args.arrival_rate
        if args.arrival_pattern == "diurnal":
            arrival_trace = diurnal_arrivals(
                args.requests, rate_base=args.arrival_rate / 5.0,
                rate_peak=args.arrival_rate, period=2.0 * span,
                seed=args.seed ^ 0xD1A)
        else:
            arrival_trace = bursty_arrivals(
                args.requests, rate_base=args.arrival_rate / 5.0,
                rate_peak=args.arrival_rate, burst_every=span,
                burst_len=span / 4.0, seed=args.seed ^ 0xB5B)
        arrival_rate = None

    llm, ssms = build_zoo(args.vocab, args.seed, args.n_ssms)
    reqs = make_workload(args.dataset, args.requests, args.vocab,
                         seed=args.seed, scale=args.scale,
                         arrival_rate=arrival_rate,
                         arrival_trace=arrival_trace,
                         slo_profile=args.slo_profile,
                         slo_scale=args.slo_scale)
    capacity = base_ecfg.capacity
    if n_eng > capacity:
        ap.error(f"a fleet of {n_eng} exceeds the aggregate --capacity "
                 f"{capacity}: every replica needs at least one pool row")
    if (n_eng > 1 and args.kv_budget is not None
            and args.kv_budget < n_eng * args.block_size):
        ap.error(f"--kv-budget {args.kv_budget} is below one "
                 f"--block-size ({args.block_size}) block per replica: "
                 "a zero-block share degenerates that replica to "
                 "one-request-at-a-time service")

    def make_engine(cap: int, kv_budget, seed: int, cls: str) -> SpinEngine:
        sel = make_selector(args.selector, len(ssms), cap,
                            {r.rid: r.prompt_len for r in reqs}, seed,
                            group_of={r.rid: r.dataset for r in reqs})
        ecfg = dataclasses.replace(
            class_engine_config(base_ecfg, cls),
            capacity=cap, kv_budget=kv_budget, seed=seed)
        return SpinEngine(llm, ssms, sel, ecfg)

    if (n_eng > 1 or args.router_policy is not None
            or args.autoscale != "off"):
        # multi-replica path: aggregate capacity / KV budget split across
        # the pre-carved fleet (evenly, or KV-weighted by class); the
        # zoo's Bundles (weights + jit caches) are shared, pools and
        # selectors are per replica
        caps = split_evenly(capacity, n_eng)
        if args.kv_budget is None:
            kvs = [None] * n_eng
        elif any(c != "general" for c in classes):
            kvs = split_weighted(args.kv_budget,
                                 [CLASS_KV_WEIGHTS[c] for c in classes])
        else:
            kvs = split_evenly(args.kv_budget, n_eng)
        engines = [make_engine(caps[i], kvs[i], args.seed, classes[i])
                   for i in range(n_eng)]
        router = Router(engines, rcfg)
        router.submit(reqs)
        stats = router.run(max_slots=args.max_slots)
    else:
        eng = make_engine(capacity, args.kv_budget, args.seed, classes[0])
        eng.add_requests(reqs)
        stats = eng.run(max_slots=args.max_slots)
    print(json.dumps(stats, indent=2, default=str))
    return stats


if __name__ == "__main__":
    main()
