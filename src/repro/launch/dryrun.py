"""Multi-pod dry-run driver.

Proves the distribution config is coherent without hardware: for every
(architecture x input shape) cell, jit(step).lower(...).compile() must
succeed on the production meshes — 16x16 single pod AND 2x16x16 multi-pod
— and the compiled artifact yields memory_analysis() (fits?) and
cost_analysis() + HLO collective schedule (roofline terms).

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x22b --shape train_4k
  python -m repro.launch.dryrun --all --json results/dryrun.json
  python -m repro.launch.dryrun --arch ... --shape ... --roofline

Roofline accounting note: XLA's HloCostAnalysis counts a while-loop body
ONCE (verified in-tree), so the scanned-layers compile undercounts FLOPs by
~n_units.  --roofline therefore lowers two extra UNROLLED variants with 1
and 2 scan units (inner scans also unrolled): cost(U) = fixed + U*unit with
unit = c2 - c1, fixed = c1 - unit.  xlstm additionally extrapolates over
seq (its sLSTM time-scan cannot be unrolled at 4k+); see roofline_stats().
"""

# The VERY FIRST lines — before ANY other import, jax locks device count on
# first init.  Do NOT move or merge below the other imports.
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import SHAPES, cell_applicable, input_specs
from repro.models import transformer as T
from repro.optim import AdamW
from repro.optim.adamw import AdamWState

# TPU v5e constants (roofline)
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

COLLECTIVE_RE = re.compile(
    r"=\s+(\S+?)\[([0-9,]*)\]\S*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
               "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_wire_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum per-device wire bytes of every collective in (post-SPMD) HLO.
    Shapes in the text are per-device shards.  Ring cost model:
      all-reduce ~ 2x result bytes; all-gather ~ result bytes;
      reduce-scatter ~ operand ~ result x n; all-to-all / permute ~ result.
    (n-1)/n factors are absorbed (n >= 16 here)."""
    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        nbytes = DTYPE_BYTES.get(dtype, 4)
        if dims:
            for d in dims.split(","):
                if d:
                    nbytes *= int(d)
        if kind == "all-reduce":
            out[kind] += 2.0 * nbytes
        elif kind == "reduce-scatter":
            # result is the scattered shard; wire ~ full operand
            out[kind] += float(nbytes) * 16.0   # conservative: axis size
        else:
            out[kind] += float(nbytes)
    out["total"] = sum(out.values())
    return out


def _sharding_trees(cfg, mesh, rules, shape_kind, shape_info):
    ab_params = T.abstract_params(cfg)
    ax_params = T.logical_axes(cfg)
    sh_params = shd.sharding_tree(mesh, rules, ax_params, ab_params)
    return ab_params, ax_params, sh_params


def _batch_shardings(mesh, rules, batch):
    def one(name, leaf):
        if name in ("tokens", "labels"):
            axes = ("batch", "seq")[:len(leaf.shape)]
        elif name in ("inputs_embeds", "prefix_embeds"):
            axes = ("batch", "seq", "act_embed")
        elif name == "lengths":
            axes = ("batch",)
        else:
            axes = tuple(None for _ in leaf.shape)
        return jax.sharding.NamedSharding(
            mesh, shd.assign_spec(rules, axes, leaf.shape, mesh))
    return {k: one(k, v) for k, v in batch.items()}


def lower_cell(cfg, shape: str, mesh, rules, opts: T.Opts,
               donate: bool = True):
    """Build + lower the step function for one cell.  Returns (lowered,
    abstract_args)."""
    info = SHAPES[shape]
    kind = info["kind"]
    ab_params = T.abstract_params(cfg)
    ax_params = T.logical_axes(cfg)
    sh_params = shd.sharding_tree(mesh, rules, ax_params, ab_params)

    if kind == "train":
        optimizer = AdamW(lr=1e-4)
        ab_opt = optimizer.abstract_state(ab_params)
        f32_params = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), ab_params)
        sh_mu = shd.sharding_tree(mesh, rules, ax_params, f32_params)
        sh_opt = AdamWState(step=shd.replicated(mesh), mu=sh_mu, nu=sh_mu)
        batch = input_specs(cfg, shape)["batch"]
        sh_batch = _batch_shardings(mesh, rules, batch)
        step = T.make_train_step(cfg, optimizer, opts)
        jitted = jax.jit(
            step,
            in_shardings=(sh_params, sh_opt, sh_batch),
            out_shardings=(sh_params, sh_opt, None),
            donate_argnums=(0, 1) if donate else ())
        with mesh, shd.use_rules(mesh, rules):
            lowered = jitted.lower(ab_params, ab_opt, batch)
        return lowered

    if kind == "prefill":
        kw = input_specs(cfg, shape)
        keys = sorted(kw)
        S = info["seq"]

        def fn(params, *vals):
            kwargs = dict(zip(keys, vals))
            return T.prefill(params, cfg, max_len=S, opts=opts,
                             last_logits_only=True, **kwargs)

        sh_kw = _batch_shardings(mesh, rules, kw)
        ax_cache = T.cache_logical_axes(cfg, info["batch"], S)
        ab_cache = T.abstract_cache(cfg, info["batch"], S)
        sh_cache = shd.sharding_tree(mesh, rules, ax_cache, ab_cache)
        jitted = jax.jit(
            fn,
            in_shardings=(sh_params,) + tuple(sh_kw[k] for k in keys),
            out_shardings=(None, sh_cache))
        with mesh, shd.use_rules(mesh, rules):
            lowered = jitted.lower(ab_params, *[kw[k] for k in keys])
        return lowered

    # decode / serve_step
    kw = input_specs(cfg, shape)
    S, B = info["seq"], info["batch"]
    ab_cache = kw.pop("cache")
    keys = sorted(kw)
    ax_cache = T.cache_logical_axes(cfg, B, S)
    sh_cache = shd.sharding_tree(mesh, rules, ax_cache, ab_cache)
    sh_kw = _batch_shardings(mesh, rules, kw)

    def fn(params, cache, *vals):
        kwargs = dict(zip(keys, vals))
        return T.decode_step(params, cfg, cache, opts=opts, **kwargs)

    jitted = jax.jit(
        fn,
        in_shardings=(sh_params, sh_cache) + tuple(sh_kw[k] for k in keys),
        out_shardings=(None, sh_cache),
        donate_argnums=(1,) if donate else ())
    with mesh, shd.use_rules(mesh, rules):
        lowered = jitted.lower(ab_params, ab_cache, *[kw[k] for k in keys])
    return lowered


def stats_of(lowered, compiled) -> Dict[str, Any]:
    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    coll = collective_wire_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll["total"],
        "collectives": coll,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
    }


def _with_units(cfg, n_units: int, seq: Optional[int] = None):
    n_layers = len(cfg.unit) * n_units + len(cfg.tail)
    return dataclasses.replace(cfg, n_layers=n_layers)


def _cell_with_seq(shape_name, seq, batch=None):
    info = dict(SHAPES[shape_name])
    info["seq"] = seq
    if batch:
        info["batch"] = batch
    return info


def roofline_stats(cfg, shape: str, mesh, rules, base_opts: T.Opts
                   ) -> Dict[str, Any]:
    """While-body-corrected totals: cost(U) = fixed + U*unit from two
    unrolled lowerings (1 and 2 units).  For xlstm (sLSTM time scan cannot
    unroll at full seq) both terms are linearly extrapolated over seq from
    two medium lengths (both in the linear chunked regime)."""
    U = cfg.n_units
    opts = dataclasses.replace(base_opts, scan_layers=False,
                               unroll_inner=True)

    def counted(n_units, seq_override=None):
        c2 = _with_units(cfg, n_units)
        shp = shape
        if seq_override is not None:
            # temporarily patch the shape table
            old = SHAPES[shape]
            SHAPES[shape] = dict(old, seq=seq_override)
            try:
                lw = lower_cell(c2, shp, mesh, rules, opts, donate=False)
            finally:
                SHAPES[shape] = old
        else:
            lw = lower_cell(c2, shp, mesh, rules, opts, donate=False)
        comp = lw.compile()
        return stats_of(lw, comp)

    from repro.models.config import MAMBA2, MLSTM, SLSTM
    recurrent = {MAMBA2, MLSTM, SLSTM}
    has_inner_scan = bool(recurrent & (set(cfg.unit) | set(cfg.tail)))
    full_seq = SHAPES[shape]["seq"]
    # SSM-bearing stacks can't unroll their inner time scans at full seq
    # (sLSTM: 4096 sequential steps; mamba2/mlstm: hundreds of chunk
    # bodies).  Their cost is polynomial (<= quadratic via the hybrid's
    # attention) in T, so fit cost(T) = a + bT + cT^2 on three small seqs
    # (chunks unroll cheaply there) and evaluate at the full seq.
    needs_seq_fit = has_inner_scan and full_seq > 1024 \
        and SHAPES[shape]["kind"] != "decode"

    def combine(c1, c2, U):
        out = {}
        for key in ("flops", "bytes", "collective_bytes"):
            unit = c2[key] - c1[key]
            fixed = c1[key] - unit
            out[key] = fixed + U * unit
        return out

    if not needs_seq_fit:
        c1 = counted(1)
        c2 = counted(2)
        return combine(c1, c2, U)

    Ts = [256, 512, 1024]
    tots = []
    for Tseq in Ts:
        c1 = counted(1, Tseq)
        c2 = counted(2, Tseq)
        tots.append(combine(c1, c2, U))
    out = {}
    for key in ("flops", "bytes", "collective_bytes"):
        ys = [t[key] for t in tots]
        coeff = np.polyfit(np.array(Ts, float), np.array(ys, float), 2)
        out[key] = float(np.polyval(coeff, full_seq))
    return out


def roofline_terms(stats: Dict[str, float], n_chips: int) -> Dict[str, Any]:
    """XLA cost_analysis on an SPMD module reports PER-DEVICE numbers
    (verified in-tree: sharded matmul flops = global/n_devices), i.e. the
    spec's HLO_FLOPs/(chips x peak) == per_device_flops/peak."""
    t_comp = stats["flops"] / PEAK_FLOPS
    t_mem = stats["bytes"] / HBM_BW
    t_coll = stats["collective_bytes"] / ICI_BW
    dominant = max((("compute", t_comp), ("memory", t_mem),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    return {"t_compute_s": t_comp, "t_memory_s": t_mem,
            "t_collective_s": t_coll, "dominant": dominant,
            "global_flops": stats["flops"] * n_chips}


def run_cell(arch: str, shape: str, *, multi_pod: bool, roofline: bool,
             rules_kind: str = "auto", opts: Optional[T.Opts] = None,
             rules: Optional[dict] = None) -> Dict[str, Any]:
    cfg = registry.get(arch)
    ok, why = cell_applicable(cfg, shape)
    rec: Dict[str, Any] = {"arch": arch, "shape": shape,
                           "multi_pod": multi_pod}
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    kind = SHAPES[shape]["kind"]
    if rules is None:
        if rules_kind == "auto":
            rules = (shd.train_rules(multi_pod) if kind == "train"
                     else shd.serve_rules(multi_pod))
        else:
            rules = shd.RULE_VARIANTS[rules_kind](multi_pod)
    opts = opts or T.Opts()
    t0 = time.time()
    try:
        lowered = lower_cell(cfg, shape, mesh, rules, opts)
        compiled = lowered.compile()
        rec["status"] = "ok"
        rec.update(stats_of(lowered, compiled))
        rec["compile_s"] = time.time() - t0
        n_chips = int(np.prod(list(mesh.shape.values())))
        rec["n_chips"] = n_chips
        # MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); train has
        # fwd+bwd (3x fwd) so 6ND per token; inference fwd only -> 2ND.
        info = SHAPES[shape]
        tokens = info["batch"] * (info["seq"] if kind == "train" else 1)
        n_active = cfg.active_params_count()
        factor = 6.0 if kind == "train" else 2.0
        if kind == "prefill":
            tokens = info["batch"] * info["seq"]
        rec["model_flops"] = factor * n_active * tokens
        if roofline:
            rstats = roofline_stats(cfg, shape, mesh, rules, opts)
            rec["roofline_raw"] = rstats
            rec["roofline"] = roofline_terms(rstats, n_chips)
            rec["useful_flops_frac"] = (
                rec["model_flops"]
                / max(rstats["flops"] * n_chips, 1.0))
        del compiled, lowered
    except Exception as e:                                  # noqa: BLE001
        rec["status"] = "FAILED"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--roofline", action="store_true")
    ap.add_argument("--json", default=None)
    ap.add_argument("--remat", default="full",
                    choices=["full", "dots", "none"])
    args = ap.parse_args()

    opts = T.Opts(remat=args.remat)
    archs = registry.ASSIGNED if (args.all or not args.arch) \
        else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) \
        else [args.multi_pod]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                print(f"=== {arch} x {shape} x "
                      f"{'2x16x16' if mp else '16x16'} ===", flush=True)
                rec = run_cell(arch, shape, multi_pod=mp,
                               roofline=args.roofline and not mp,
                               opts=opts)
                show = {k: v for k, v in rec.items()
                        if k not in ("traceback", "collectives",
                                     "roofline_raw")}
                print(json.dumps(show, indent=1, default=str), flush=True)
                results.append(rec)
                if args.json:
                    os.makedirs(os.path.dirname(args.json) or ".",
                                exist_ok=True)
                    with open(args.json, "w") as f:
                        json.dump(results, f, indent=1, default=str)
    n_fail = sum(1 for r in results if r.get("status") == "FAILED")
    print(f"\n{len(results)} cells: "
          f"{sum(1 for r in results if r.get('status') == 'ok')} ok, "
          f"{sum(1 for r in results if r.get('status') == 'skipped')} "
          f"skipped, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
