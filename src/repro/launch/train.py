"""Fault-tolerant training launcher.

    python -m repro.launch.train --arch qwen2-0.5b --steps 200 \
        --reduced --ckpt-dir /tmp/ckpt [--resume auto] [--simulate-failures]

Production posture (exercised at CPU scale by tests/test_train_loop.py):
  * checkpoint every --ckpt-every steps (async, atomic, versioned);
  * --resume auto restores the latest checkpoint — the retry loop around
    run() gives crash-restart semantics (a real cluster wraps the same
    entry point in its job restarter);
  * elastic restore: checkpoints are mesh-agnostic (per-leaf unsharded
    npy) — restoring onto a different device count re-shards via
    CheckpointManager.restore(shardings=...);
  * deterministic data: the stream is indexed by step, so a restart
    replays exactly (no data-state to save);
  * --simulate-failures injects a crash mid-run to prove recovery.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import registry
from repro.data.pipeline import TokenStream
from repro.models import transformer as T
from repro.models.config import reduced
from repro.optim import AdamW, cosine_schedule


class SimulatedFailure(RuntimeError):
    pass


def run(args) -> dict:
    cfg = registry.get(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    stream = TokenStream(seed=args.seed, batch=args.batch,
                         seq_len=args.seq_len, vocab=cfg.vocab_size)
    optimizer = AdamW(lr=cosine_schedule(args.lr, args.warmup, args.steps))
    step_fn = jax.jit(T.make_train_step(cfg, optimizer,
                                        T.Opts(remat=args.remat)))
    mgr = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir else None

    start = 0
    params = opt_state = None
    if mgr and args.resume == "auto" and mgr.latest_step() is not None:
        template = (T.abstract_params(cfg),
                    optimizer.abstract_state(T.abstract_params(cfg)))
        (params, opt_state), start = mgr.restore(template)
        start += 1
        print(f"[train] resumed from step {start - 1}")
    if params is None:
        params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
        opt_state = optimizer.init(params)

    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        toks, labels = stream.batch_at(step)
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step, (params, opt_state), blocking=False)
        if args.simulate_failures and step == args.fail_at:
            raise SimulatedFailure(f"injected failure at step {step}")
        if step % 20 == 0:
            print(f"[train] step {step} loss {losses[-1]:.4f} "
                  f"({(time.time() - t0):.1f}s)", flush=True)
    if mgr:
        mgr.save(args.steps - 1, (params, opt_state), blocking=True)
        mgr.wait()
    return {"final_loss": losses[-1] if losses else float("nan"),
            "losses": losses, "resumed_from": start}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--remat", default="none",
                    choices=["full", "dots", "none"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="auto", choices=["auto", "none"])
    ap.add_argument("--simulate-failures", action="store_true")
    ap.add_argument("--fail-at", type=int, default=30)
    ap.add_argument("--max-restarts", type=int, default=3)
    args = ap.parse_args(argv)

    # crash-restart loop (the in-process analogue of a cluster restarter)
    for attempt in range(args.max_restarts + 1):
        try:
            out = run(args)
            print(f"[train] done: final loss {out['final_loss']:.4f} "
                  f"(resumed_from={out['resumed_from']})")
            return out
        except SimulatedFailure as e:
            print(f"[train] FAILURE: {e}; restarting "
                  f"({attempt + 1}/{args.max_restarts})")
            args.simulate_failures = False   # crash once, then recover
    raise RuntimeError("exceeded max restarts")


if __name__ == "__main__":
    main()
