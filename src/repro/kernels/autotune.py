"""Config autotuner for the fused speculative-step kernels.

The fused kernels (``kernels/fused_verify.py``, ``kernels/fused_decode.py``)
expose three tile knobs — query tile ``bq`` (verify only), KV sub-tile
``bk`` and prefetch ``depth``.  The right choice depends on the model's
attention geometry and the paging granularity, so this module benchmarks
the small candidate grid on synthetic pool shapes and caches the winner
per tune key::

    (kind | H x Kh x D | gamma_max | block_size | linear/tree | kv dtype
     | backend)

The kv dtype component keeps int8/fp8 winners (half the KV bytes per
tile, dequant multiply in the inner loop) from colliding with bf16
entries for the same geometry; keys written before the component existed
are migrated to ``kvbf16`` on load and malformed keys are dropped.

Winners persist in ``results/TUNE_cache.json``.  ``kernels/ops.py``
consults :func:`get_config` at dispatch when no explicit config is given;
the serving engine resolves its configs once at construction.  A cache
miss NEVER tunes implicitly (tuning runs kernels; dispatch must stay
cheap and deterministic) — it falls back to :data:`DEFAULT_CONFIG`, and
``CACHE_STATS`` records the miss so benchmarks can report coverage.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

CACHE_PATH = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "results", "TUNE_cache.json")
ROOFLINE_PATH = os.path.join(os.path.dirname(CACHE_PATH),
                             "dryrun_baseline.json")

# current key grammar (see tune_key); legacy = same minus the kv field
_KEY_FIELDS = (r"(verify|decode)", r"H\d+xKh\d+xD\d+", r"g\d+", r"bs\d+",
               r"(linear|tree)", r"kv\w+", r"\w+")
_KEY_RE = re.compile("^" + r"\|".join(_KEY_FIELDS) + "$")
_LEGACY_RE = re.compile(
    "^" + r"\|".join(_KEY_FIELDS[:5] + _KEY_FIELDS[6:]) + "$")

# consult/miss counters, reset-able by benchmarks and tests
CACHE_STATS: Dict[str, int] = {"hits": 0, "misses": 0}


@dataclasses.dataclass(frozen=True)
class FusedConfig:
    """Tile config of one fused kernel launch.  Frozen (hashable) so jit
    caches can key on it.  ``bk = 0`` means "one tile per physical block"
    (the kernels also fall back to that when bk does not divide bs)."""
    bq: int = 128
    bk: int = 0
    depth: int = 1


DEFAULT_CONFIG = FusedConfig()


def tune_key(kind: str, *, H: int, Kh: int, D: int, gamma_max: int,
             block_size: int, shape: str = "linear",
             kv_dtype: str = "bf16") -> str:
    """Cache key: kernel kind + model attention geometry + speculation
    depth cap + paging granularity + linear/tree + kv storage dtype +
    backend (tile trade-offs differ between compiled Mosaic and the CPU
    interpreter)."""
    return (f"{kind}|H{H}xKh{Kh}xD{D}|g{gamma_max}|bs{block_size}"
            f"|{shape}|kv{kv_dtype}|{jax.default_backend()}")


def _migrate_key(key: str) -> Optional[str]:
    """Current keys pass through; pre-kv-dtype keys (written by older
    tuners, necessarily bf16 pools) gain ``kvbf16``; anything else is
    corrupt and dropped (returns None)."""
    if _KEY_RE.match(key):
        return key
    if _LEGACY_RE.match(key):
        head, backend = key.rsplit("|", 1)
        return f"{head}|kvbf16|{backend}"
    return None


def load_cache(path: Optional[str] = None) -> dict:
    path = path or CACHE_PATH
    try:
        with open(path) as f:
            raw = json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}
    if not isinstance(raw, dict):
        return {}
    # current-format keys win over a legacy key migrating to the same slot
    cache = {k: v for k, v in raw.items()
             if _KEY_RE.match(k) and isinstance(v, dict)}
    for key, entry in raw.items():
        mig = _migrate_key(key)
        if mig is not None and mig != key and isinstance(entry, dict):
            cache.setdefault(mig, entry)
    return cache


def save_cache(cache: dict, path: Optional[str] = None) -> None:
    path = path or CACHE_PATH
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(cache, f, indent=2, sort_keys=True)


def lookup(key: str, path: Optional[str] = None) -> Optional[FusedConfig]:
    """Cached winner for ``key``, or None (counted in CACHE_STATS)."""
    entry = load_cache(path).get(key)
    if entry is None:
        CACHE_STATS["misses"] += 1
        return None
    CACHE_STATS["hits"] += 1
    return FusedConfig(bq=int(entry.get("bq", DEFAULT_CONFIG.bq)),
                       bk=int(entry.get("bk", DEFAULT_CONFIG.bk)),
                       depth=int(entry.get("depth", DEFAULT_CONFIG.depth)))


def get_config(kind: str, *, H: int, Kh: int, D: int, gamma_max: int = 0,
               block_size: int = 0, shape: str = "linear",
               kv_dtype: str = "bf16",
               path: Optional[str] = None) -> FusedConfig:
    """Dispatch-time lookup with the safe default fallback."""
    cfg = lookup(tune_key(kind, H=H, Kh=Kh, D=D, gamma_max=gamma_max,
                          block_size=block_size, shape=shape,
                          kv_dtype=kv_dtype), path)
    return cfg if cfg is not None else DEFAULT_CONFIG


def roofline_candidates(kind: str, block_size: int,
                        path: Optional[str] = None) -> List[FusedConfig]:
    """Extra grid points derived from the dry-run roofline records
    (``results/dryrun_baseline.json``, the table benchmarks/roofline.py
    renders).  Memory-bound arches reward deeper DMA pipelining and
    smaller KV sub-tiles (more overlap windows per block); compute-bound
    ones reward a wider query tile amortizing each streamed block over
    more rows.  Missing/empty file -> no extra candidates (the static
    grid stands alone)."""
    try:
        with open(path or ROOFLINE_PATH) as f:
            records = json.load(f)
    except (OSError, json.JSONDecodeError):
        return []
    doms = set()
    for rec in records if isinstance(records, list) else []:
        if not isinstance(rec, dict):
            continue
        rf = rec.get("roofline") or {}
        if rec.get("status", "ok") == "ok" and rf.get("dominant"):
            doms.add(rf["dominant"])
    out = []
    if "memory" in doms:
        bk = (block_size // 4
              if block_size % 4 == 0 and block_size // 4 >= 8 else 0)
        out += [FusedConfig(bq=DEFAULT_CONFIG.bq, bk=bk, depth=d)
                for d in (3, 4)]
    if ("compute" in doms or "collective" in doms) and kind == "verify":
        out.append(FusedConfig(bq=256, bk=0, depth=1))
    return out


def candidate_configs(kind: str, block_size: int,
                      roofline_path: Optional[str] = None) \
        -> List[FusedConfig]:
    """Small search grid: bq tiles at/below the common packed widths, bk
    halving down to 8 slots, depth 1 (pure pipelining) or 2 (explicit
    double-buffer), plus any roofline-derived points for this machine's
    dry-run profile.  Kept deliberately tiny — tuning runs kernels."""
    bks = [0]
    if block_size % 2 == 0 and block_size // 2 >= 8:
        bks.append(block_size // 2)
    bqs = [128, 32] if kind == "verify" else [0]
    out = []
    for bq in bqs:
        for bk in bks:
            for depth in (1, 2):
                out.append(FusedConfig(bq=bq or DEFAULT_CONFIG.bq, bk=bk,
                                       depth=depth))
    for cfg in roofline_candidates(kind, block_size, roofline_path):
        if cfg not in out:
            out.append(cfg)
    return out


def _median_us(fn, iters: int = 5, warmup: int = 1) -> float:
    ts = []
    for _ in range(iters + warmup):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts[warmup:]))


def _synthetic_pool(H, Kh, D, gamma_max, block_size, seed=0):
    """Tiny but representative paged state: 4 rows, 2 blocks each, the
    speculation window of the last row half-written."""
    rng = np.random.default_rng(seed)
    bs = block_size
    B, nb = 4, 2
    N = B * nb + 2                                     # + free blocks
    k_pool = jnp.asarray(rng.standard_normal((N, bs, Kh, D)), jnp.float32)
    v_pool = jnp.asarray(rng.standard_normal((N, bs, Kh, D)), jnp.float32)
    bt = np.full((B, nb), -1, np.int32)
    seg = np.full((N, bs), -1, np.int32)
    pos = np.zeros((N, bs), np.int32)
    ids, owner = [], []
    ctx = bs + max(2, bs // 2)                         # straddles 2 blocks
    for b in range(B):
        for lb in range(nb):
            blk = b * nb + lb
            bt[b, lb] = blk
            ids.append(blk)
            owner.append(b)
            lo = lb * bs
            n = int(np.clip(ctx - lo, 0, bs))
            seg[blk, :n] = 0
            pos[blk] = lo + np.arange(bs)
    m = 1 << (len(ids) - 1).bit_length()
    ids += [0] * (m - len(ids))
    owner += [-1] * (m - len(owner))
    W = max(1, gamma_max)
    lens = np.full(B, ctx, np.int64)
    return dict(k_pool=k_pool, v_pool=v_pool,
                pool_seg=jnp.asarray(seg), pool_pos=jnp.asarray(pos),
                bt=jnp.asarray(bt), ids=jnp.asarray(np.asarray(ids,
                                                               np.int32)),
                owner=jnp.asarray(np.asarray(owner, np.int32)),
                lens=lens, W=W, B=B, rng=rng)


def autotune(kind: str, *, H: int, Kh: int, D: int, gamma_max: int,
             block_size: int, shape: str = "linear",
             kv_dtype: str = "bf16",
             path: Optional[str] = None, seed: int = 0) -> FusedConfig:
    """Benchmark the candidate grid for one tune key, persist and return
    the winner.  Safe to re-run (overwrites the entry).  Quantized
    ``kv_dtype`` tunes against int8/fp8 synthetic pools with scale
    sidecars, so the winner reflects the dequant inner loop."""
    from repro.kernels import quant
    from repro.kernels.fused_decode import fused_paged_decode
    from repro.kernels.fused_verify import fused_paged_verify

    syn = _synthetic_pool(H, Kh, D, gamma_max, block_size, seed)
    B, W, rng = syn["B"], syn["W"], syn["rng"]
    interpret = jax.default_backend() != "tpu"
    k_scale = v_scale = None
    qdt = quant.storage_dtype(kv_dtype)
    if qdt is not None:
        syn["k_pool"], k_scale = quant.quantize(syn["k_pool"], qdt)
        syn["v_pool"], v_scale = quant.quantize(syn["v_pool"], qdt)

    if kind == "verify":
        Tq = B * (W + 1)
        q = jnp.asarray(rng.standard_normal((Tq, H, D)), jnp.float32)
        q_seg = jnp.repeat(jnp.arange(B, dtype=jnp.int32), W + 1)
        q_pos = jnp.asarray(
            np.concatenate([syn["lens"][b] + np.arange(W + 1)
                            for b in range(B)]).astype(np.int32))
        anc = (jnp.full((Tq,), -1, jnp.int32) if shape == "tree" else None)
        node = (jnp.full((syn["ids"].shape[0], block_size), -1, jnp.int32)
                if shape == "tree" else None)

        def run(cfg):
            return fused_paged_verify(
                q, syn["k_pool"], syn["v_pool"], syn["pool_seg"],
                syn["pool_pos"], q_seg, q_pos, syn["ids"], syn["owner"],
                anc, node, k_scale, v_scale,
                bq=cfg.bq, bk=cfg.bk, depth=cfg.depth,
                interpret=interpret)
    elif kind == "decode":
        T = W + 1
        q = jnp.asarray(rng.standard_normal((B, T, H, D)), jnp.float32)
        q_seg = jnp.zeros((B, T), jnp.int32)
        q_pos = jnp.asarray(syn["lens"][:, None]
                            + np.arange(T)[None], jnp.int32)

        def run(cfg):
            return fused_paged_decode(
                q, syn["k_pool"], syn["v_pool"], syn["pool_seg"],
                syn["pool_pos"], q_seg, q_pos, syn["bt"],
                k_scale, v_scale,
                bk=cfg.bk, depth=cfg.depth, interpret=interpret)
    else:
        raise ValueError(f"unknown kernel kind {kind!r}")

    best, best_us = None, float("inf")
    for cfg in candidate_configs(kind, block_size):
        us = _median_us(lambda: run(cfg))
        if us < best_us:
            best, best_us = cfg, us
    key = tune_key(kind, H=H, Kh=Kh, D=D, gamma_max=gamma_max,
                   block_size=block_size, shape=shape, kv_dtype=kv_dtype)
    cache = load_cache(path)
    cache[key] = {"bq": best.bq, "bk": best.bk, "depth": best.depth,
                  "us": round(best_us, 1),
                  "candidates": len(candidate_configs(kind, block_size))}
    save_cache(cache, path)
    return best
