"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to auto: False on TPU (compiled Mosaic), True
elsewhere (kernel body executed in Python on CPU — how this repo validates
TPU kernels without TPU hardware)."""

from __future__ import annotations

import jax

from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.paged_attention import (
    paged_decode_attention as _paged_decode,
    paged_verify_attention as _paged_verify)
from repro.kernels.verify_attention import verify_attention as _verify


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def verify_attention(q, k, v, q_seg, q_pos, kv_seg, kv_pos, *,
                     bq: int = 128, bk: int = 128, interpret=None):
    if interpret is None:
        interpret = _auto_interpret()
    return _verify(q, k, v, q_seg, q_pos, kv_seg, kv_pos, bq=bq, bk=bk,
                   interpret=interpret)


def flash_attention(q, k, v, *, window: int = 0, bq: int = 128,
                    bk: int = 128, interpret=None):
    if interpret is None:
        interpret = _auto_interpret()
    return _flash(q, k, v, window=window, bq=bq, bk=bk, interpret=interpret)


def decode_attention(q, k, v, lengths, *, bk=None, interpret=None):
    if interpret is None:
        interpret = _auto_interpret()
    return _decode(q, k, v, lengths, bk=bk, interpret=interpret)


def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths, *,
                           interpret=None):
    if interpret is None:
        interpret = _auto_interpret()
    return _paged_decode(q, k_pool, v_pool, block_tables, lengths,
                         interpret=interpret)


def paged_verify_attention(q, k_pool, v_pool, pool_seg, pool_pos,
                           q_seg, q_pos, block_ids, block_owner, *,
                           bq: int = 128, interpret=None):
    if interpret is None:
        interpret = _auto_interpret()
    return _paged_verify(q, k_pool, v_pool, pool_seg, pool_pos,
                         q_seg, q_pos, block_ids, block_owner,
                         bq=bq, interpret=interpret)
