"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to auto: False on TPU (compiled Mosaic), True
elsewhere (kernel body executed in Python on CPU — how this repo validates
TPU kernels without TPU hardware)."""

from __future__ import annotations

import jax

from repro.kernels import autotune, quant
from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.fused_decode import fused_paged_decode as _fused_decode
from repro.kernels.fused_verify import fused_paged_verify as _fused_verify
from repro.kernels.paged_attention import (
    paged_decode_attention as _paged_decode,
    paged_verify_attention as _paged_verify)
from repro.kernels.verify_attention import verify_attention as _verify


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def verify_attention(q, k, v, q_seg, q_pos, kv_seg, kv_pos, *,
                     bq: int = 128, bk: int = 128, interpret=None):
    if interpret is None:
        interpret = _auto_interpret()
    return _verify(q, k, v, q_seg, q_pos, kv_seg, kv_pos, bq=bq, bk=bk,
                   interpret=interpret)


def flash_attention(q, k, v, *, window: int = 0, bq: int = 128,
                    bk: int = 128, interpret=None):
    if interpret is None:
        interpret = _auto_interpret()
    return _flash(q, k, v, window=window, bq=bq, bk=bk, interpret=interpret)


def decode_attention(q, k, v, lengths, *, bk=None, interpret=None):
    if interpret is None:
        interpret = _auto_interpret()
    return _decode(q, k, v, lengths, bk=bk, interpret=interpret)


def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths,
                           k_scale=None, v_scale=None, *,
                           interpret=None):
    if interpret is None:
        interpret = _auto_interpret()
    return _paged_decode(q, k_pool, v_pool, block_tables, lengths,
                         k_scale, v_scale, interpret=interpret)


def paged_verify_attention(q, k_pool, v_pool, pool_seg, pool_pos,
                           q_seg, q_pos, block_ids, block_owner,
                           k_scale=None, v_scale=None, *,
                           bq: int = 128, interpret=None):
    if interpret is None:
        interpret = _auto_interpret()
    return _paged_verify(q, k_pool, v_pool, pool_seg, pool_pos,
                         q_seg, q_pos, block_ids, block_owner,
                         k_scale=k_scale, v_scale=v_scale,
                         bq=bq, interpret=interpret)


# ------------------------------------------------- fused (autotuned) path --

def _resolve_config(kind, q, k_pool, gamma_max, shape, config):
    """Dispatch-time autotune-cache lookup: explicit config wins, else the
    cached winner for this (arch, gamma_max, block_size, shape) key, else
    the safe default (autotune.DEFAULT_CONFIG — never implicit tuning)."""
    if config is not None:
        return config
    return autotune.get_config(
        kind, H=q.shape[-2], Kh=k_pool.shape[2], D=q.shape[-1],
        gamma_max=gamma_max, block_size=k_pool.shape[1], shape=shape,
        kv_dtype=quant.dtype_name(k_pool.dtype))


def fused_paged_verify(q, k_pool, v_pool, pool_seg, pool_pos,
                       q_seg, q_pos, block_ids, block_owner,
                       q_anc=None, block_node=None,
                       k_scale=None, v_scale=None, *,
                       config=None, gamma_max: int = 0, interpret=None):
    """Single-launch packed verification (kernels/fused_verify.py): KV
    streams straight from the pool, no gathered copy.  ``config`` (a
    ``autotune.FusedConfig``) pins the tile shapes; None consults the
    autotune cache with the default fallback."""
    if interpret is None:
        interpret = _auto_interpret()
    shape = "tree" if block_node is not None else "linear"
    cfg = _resolve_config("verify", q, k_pool, gamma_max, shape, config)
    return _fused_verify(q, k_pool, v_pool, pool_seg, pool_pos,
                         q_seg, q_pos, block_ids, block_owner,
                         q_anc, block_node, k_scale, v_scale,
                         bq=cfg.bq, bk=cfg.bk,
                         depth=cfg.depth, interpret=interpret)


def fused_paged_decode(q, k_pool, v_pool, pool_seg, pool_pos,
                       q_seg, q_pos, block_tables,
                       k_scale=None, v_scale=None, *,
                       config=None, gamma_max: int = 0, interpret=None):
    """Single-launch multi-token paged decode (kernels/fused_decode.py)
    with block-table prefetch double-buffered against tile compute."""
    if interpret is None:
        interpret = _auto_interpret()
    cfg = _resolve_config("decode", q, k_pool, gamma_max, "linear", config)
    return _fused_decode(q, k_pool, v_pool, pool_seg, pool_pos,
                         q_seg, q_pos, block_tables, k_scale, v_scale,
                         bk=cfg.bk, depth=cfg.depth, interpret=interpret)
