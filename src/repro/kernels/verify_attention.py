"""SPIN packed verification attention — the paper's §V-A compute kernel,
TPU-native.

All requests' KV fragments live in ONE flattened packed buffer (Tkv tokens,
any interleaving) tagged with (segment, position); the query buffer (Tq =
sum over requests of gamma+1 verification tokens) is tagged the same way.
The kernel computes flash attention where token j contributes to query i iff

    seg_j == seg_i  (Eq. 13 indicator I_{j,S})  and  pos_j <= pos_i (causal)

so the softmax denominator spans exactly the packed fragments of the query's
request — no padding tokens enter the computation, and whole KV blocks whose
segment range cannot intersect the query block's are SKIPPED (the dominant
saving: compute tracks the packed size, not the padded size).

TPU mapping:
  grid = (Tq/BQ, Tkv/BK); KV is the sequential (arbitrary) axis.
  Blocks: q (BQ, H, D) and kv (BK, Kh, D) tiles in VMEM; seg/pos vectors in
  SMEM.  BQ=BK=128 and D a multiple of 128 keeps the MXU fed and the
  working set (q + k + v + acc tiles, f32) around
  128*(H+2*Kh+H)*D*4 bytes << 16 MiB VMEM for every assigned arch.
  Running (m, l, acc) live in VMEM scratch across the KV axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG = -1e30


def _kernel(q_seg_ref, q_pos_ref, q_anc_ref,                # scalar-ish
            kv_seg_ref, kv_pos_ref, kv_node_ref,
            q_ref, k_ref, v_ref,                            # VMEM tiles
            o_ref,                                          # output tile
            m_ref, l_ref, acc_ref,                          # VMEM scratch
            *, nk: int, scale: float):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_seg = q_seg_ref[...]                  # (BQ,)
    q_pos = q_pos_ref[...]
    q_anc = q_anc_ref[...]                  # (BQ,) ancestor bitmask
    kv_seg = kv_seg_ref[...]                # (BK,)
    kv_pos = kv_pos_ref[...]
    kv_node = kv_node_ref[...]              # (BK,) tree-node tag

    # Block-level skip: segment ranges disjoint OR the whole KV block is in
    # the future of every query OR all slots empty.  Padding slots carry
    # seg = -1 and never match (q_seg >= 0 for real queries).
    kv_valid = kv_seg >= 0
    kv_seg_lo = jnp.min(jnp.where(kv_valid, kv_seg, jnp.iinfo(jnp.int32).max))
    kv_seg_hi = jnp.max(kv_seg)             # -1 if all padding
    q_lo, q_hi = jnp.min(q_seg), jnp.max(q_seg)
    overlap = (kv_seg_hi >= q_lo) & (kv_seg_lo <= q_hi)
    not_future = jnp.min(jnp.where(kv_valid, kv_pos,
                                   jnp.iinfo(jnp.int32).max)) <= jnp.max(q_pos)

    @pl.when(overlap & not_future)
    def _compute():
        q = q_ref[...].astype(jnp.float32) * scale      # (BQ, H, D)
        k = k_ref[...].astype(jnp.float32)              # (BK, Kh, D)
        v = v_ref[...].astype(jnp.float32)
        BQ, H, D = q.shape
        BK, Kh, _ = k.shape
        G = H // Kh
        qg = q.reshape(BQ, Kh, G, D)
        s = jax.lax.dot_general(
            qg.transpose(1, 2, 0, 3).reshape(Kh, G * BQ, D),
            k.transpose(1, 2, 0),
            (((2,), (1,)), ((0,), (0,))))               # (Kh, G*BQ, BK)
        s = s.reshape(Kh, G, BQ, BK).transpose(2, 0, 1, 3)  # (BQ,Kh,G,BK)
        mask = (q_seg[:, None] == kv_seg[None, :]) \
            & (kv_seg[None, :] >= 0) \
            & (kv_pos[None, :] <= q_pos[:, None])       # (BQ, BK)
        # tree-topology term: committed slots (node -1) always attendable,
        # dead slots (node -2) never, node-tagged slots only along the
        # query's own root-to-leaf path (ancestor bitmask)
        nd = kv_node[None, :]
        on_path = ((q_anc[:, None] >> jnp.clip(nd, 0, 31)) & 1).astype(bool)
        mask &= jnp.where(nd == -1, True, jnp.where(nd < -1, False, on_path))
        s = jnp.where(mask[:, None, None, :], s, NEG)

        m_prev = m_ref[...].reshape(BQ, Kh, G)
        l_prev = l_ref[...].reshape(BQ, Kh, G)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        # guard: rows with everything masked keep m finite
        m_safe = jnp.maximum(m_new, -1e29)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[:, None, None, :], p, 0.0)
        corr = jnp.where(jnp.isfinite(m_prev),
                         jnp.exp(m_prev - m_safe), 0.0)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.transpose(1, 2, 0, 3).reshape(Kh, G * BQ, BK),
            v.transpose(1, 0, 2),
            (((2,), (1,)), ((0,), (0,))))               # (Kh, G*BQ, D)
        pv = pv.reshape(Kh, G, BQ, D).transpose(2, 0, 1, 3)
        acc_prev = acc_ref[...].reshape(BQ, Kh, G, D)
        acc_new = acc_prev * corr[..., None] + pv
        m_ref[...] = m_new.reshape(BQ, Kh * G)
        l_ref[...] = l_new.reshape(BQ, Kh * G)
        acc_ref[...] = acc_new.reshape(BQ, Kh * G, D)

    @pl.when(j == nk - 1)
    def _finish():
        l = l_ref[...]
        o = acc_ref[...] / jnp.maximum(l, 1e-30)[..., None]
        o = jnp.where((l > 0)[..., None], o, 0.0)
        o_ref[...] = o.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "interpret"))
def verify_attention(q, k, v, q_seg, q_pos, kv_seg, kv_pos,
                     q_anc=None, kv_node=None, *,
                     bq: int = 128, bk: int = 128,
                     interpret: bool = False):
    """q: (Tq, H, D); k,v: (Tkv, Kh, D); segs/pos int32.  Returns (Tq,H,D).

    Optional ``q_anc`` (Tq,) / ``kv_node`` (Tkv,) add the tree-speculation
    topology term (ancestor bitmask vs per-slot node tag); omitted they
    default to -1 everywhere, which reduces the mask to the linear Eq. 13
    form exactly.  Inputs are padded to block multiples here (padding
    queries get seg=-1 and produce zeros)."""
    Tq, H, D = q.shape
    Tkv, Kh, _ = k.shape
    scale = 1.0 / np.sqrt(D)

    if q_anc is None:
        q_anc = jnp.full((Tq,), -1, jnp.int32)
    if kv_node is None:
        kv_node = jnp.full((Tkv,), -1, jnp.int32)

    Tq_p = int(np.ceil(Tq / bq) * bq)
    Tkv_p = int(np.ceil(Tkv / bk) * bk)
    qp = jnp.pad(q, ((0, Tq_p - Tq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, Tkv_p - Tkv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, Tkv_p - Tkv), (0, 0), (0, 0)))
    def pad_i32(x, n):
        return jnp.pad(x.astype(jnp.int32), (0, n), constant_values=-1)
    q_seg_p = pad_i32(q_seg, Tq_p - Tq)
    q_pos_p = pad_i32(q_pos, Tq_p - Tq)
    q_anc_p = pad_i32(q_anc, Tq_p - Tq)
    kv_seg_p = pad_i32(kv_seg, Tkv_p - Tkv)
    kv_pos_p = pad_i32(kv_pos, Tkv_p - Tkv)
    kv_node_p = pad_i32(kv_node, Tkv_p - Tkv)

    nq, nk = Tq_p // bq, Tkv_p // bk
    grid = (nq, nk)

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bq,), lambda i, j: (i,)),
            pl.BlockSpec((bk,), lambda i, j: (j,)),
            pl.BlockSpec((bk,), lambda i, j: (j,)),
            pl.BlockSpec((bk,), lambda i, j: (j,)),
            pl.BlockSpec((bq, H, D), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((bk, Kh, D), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((bk, Kh, D), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, H, D), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Tq_p, H, D), q.dtype),
        scratch_shapes=[
            _vmem((bq, H), jnp.float32),      # running max m
            _vmem((bq, H), jnp.float32),      # running sum l
            _vmem((bq, H, D), jnp.float32),   # accumulator
        ],
        interpret=interpret,
    )(q_seg_p, q_pos_p, q_anc_p, kv_seg_p, kv_pos_p, kv_node_p, qp, kp, vp)
    return out[:Tq]


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
