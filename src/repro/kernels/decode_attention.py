"""GQA decode attention: one query token per row vs a long KV cache.

This is the memory-bound hot loop of serving (arithmetic intensity ~ 2
flops/byte): the kernel's job is to stream K/V HBM->VMEM in large tiles
exactly once.  grid = (B, S/BK); per-row running softmax in VMEM scratch;
slots >= length masked (cache tail).

Block sizing: BK=512 streams (2*BK*Kh*D) bytes per step; with Kh=8, D=128
bf16 that is 2 MiB/tile -> comfortably double-buffered in 16 MiB VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, nk: int, bk: int, scale: float):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0]
    kv_pos = j * bk + jax.lax.iota(jnp.int32, bk)

    @pl.when(j * bk < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale       # (H, D)
        k = k_ref[0].astype(jnp.float32)               # (BK, Kh, D)
        v = v_ref[0].astype(jnp.float32)
        H, D = q.shape
        BK, Kh, _ = k.shape
        G = H // Kh
        qg = q.reshape(Kh, G, D)
        s = jnp.einsum("kgd,skd->kgs", qg, k)          # (Kh, G, BK)
        mask = kv_pos < length
        s = jnp.where(mask[None, None, :], s, NEG)
        m_prev = m_ref[...].reshape(Kh, G)
        l_prev = l_ref[...].reshape(Kh, G)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        m_safe = jnp.maximum(m_new, -1e29)
        p = jnp.where(mask[None, None, :], jnp.exp(s - m_safe[..., None]),
                      0.0)
        corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("kgs,skd->kgd", p, v)
        acc_ref[...] = (acc_ref[...].reshape(Kh, G, D) * corr[..., None]
                        + pv).reshape(Kh * G, D)
        m_ref[...] = m_new.reshape(1, Kh * G)
        l_ref[...] = l_new.reshape(1, Kh * G)

    @pl.when(j == nk - 1)
    def _finish():
        l = l_ref[...].reshape(-1)
        o = acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
        o_ref[0, ...] = jnp.where((l > 0)[:, None], o, 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention(q, k, v, lengths, *, bk=None,
                     interpret: bool = False):
    """q: (B, H, D); k, v: (B, S, Kh, D); lengths: (B,).  Returns (B,H,D).

    An explicit ``bk`` must divide S: caches are allocated at
    block-aligned max_len (see serving/pool.py), so re-padding K/V here
    would copy the entire cache on EVERY decode step just to round the
    tail tile — the exact per-step HBM traffic this kernel exists to
    avoid.  ``bk=None`` picks the largest tile <= 512 that divides S.
    """
    B, H, D = q.shape
    S, Kh = k.shape[1], k.shape[2]
    if bk is None:
        bk = min(512, S)
        while S % bk:
            bk //= 2
    elif S % bk:
        raise ValueError(
            f"KV length {S} is not a multiple of bk={bk}; allocate the "
            f"cache block-aligned (or pick bk dividing S) instead of "
            f"paying a full-cache pad copy per step")
    scale = 1.0 / np.sqrt(D)
    kp, vp = k, v
    nk = S // bk

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, bk=bk, scale=scale),
        grid=(B, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, j: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, H, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bk, Kh, D), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, bk, Kh, D), lambda b, j: (b, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, D), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, H), jnp.float32),
            pltpu.VMEM((1, H), jnp.float32),
            pltpu.VMEM((H, D), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), q, kp, vp)
    return out
