"""Quantized paged-KV block helpers (ISSUE 8).

SPIN's verification phase re-scores every draft token with the LLM, so
precision spent on speculative KV state is pure capacity overhead — the
binding constraint in every serving benchmark is *blocks*, not FLOPs.
These helpers implement the storage half of ``--kv-dtype``:

* K/V block pools store ``int8`` (symmetric, qmax 127) or fp8
  ``float8_e4m3fn`` (qmax 448) instead of the compute dtype;
* a float32 *scale sidecar* of shape ``(num_blocks, block_size, Kh)``
  rides inside each attention cache entry next to ``k``/``v`` — indexed
  by the same block table, copied by the same CoW whole-block copy, freed
  by the same refcount drop.  Scales are per (slot-in-block, kv head):
  per-slot granularity means appending into a partially filled block
  never requantizes earlier slots (a true per-block amax would have to),
  and per-head granularity keeps heads with small activations from being
  crushed by a loud sibling head.

Quantize-on-write happens at the two scatter sites (``serving/pool.py``
monolithic insert, ``serving/paged._write_kv`` decode/verify/chunk
appends); dequantize happens *inside* the Pallas kernels
(``scale * int8`` on the streamed tile, under the online softmax) or
post-gather on the XLA fallback path — a dense dequantized copy of the
pool is never materialized.

``"bf16"`` (the default ``--kv-dtype``) means "store the model's compute
dtype" — no scale leaves exist and every byte layout is identical to the
unquantized engine, which is what makes the default bit-identical by
construction.
"""

from __future__ import annotations

import jax.numpy as jnp

# kv-dtype name -> (storage dtype, symmetric quantization range)
KV_DTYPES = {
    "int8": (jnp.int8, 127.0),
    "fp8": (jnp.float8_e4m3fn, 448.0),
}
KV_DTYPE_NAMES = ("bf16",) + tuple(KV_DTYPES)


def is_quantized(name: str) -> bool:
    return name in KV_DTYPES


def storage_dtype(name: str):
    """Pool leaf dtype for a kv-dtype name; None = compute dtype."""
    if name in KV_DTYPES:
        return KV_DTYPES[name][0]
    if name == "bf16":
        return None
    raise ValueError(
        f"kv_dtype must be one of {'/'.join(KV_DTYPE_NAMES)}, got {name!r}")


def dtype_name(dt) -> str:
    """kv-dtype name of a pool leaf dtype (autotune cache keys, stats)."""
    dt = jnp.dtype(dt)
    for name, (qdt, _) in KV_DTYPES.items():
        if dt == jnp.dtype(qdt):
            return name
    return "bf16"


def qmax_of(dt) -> float:
    dt = jnp.dtype(dt)
    for qdt, qmax in KV_DTYPES.values():
        if dt == jnp.dtype(qdt):
            return qmax
    raise ValueError(f"{dt} is not a quantized KV dtype")


def quantize(x, qdt):
    """Symmetric per-last-axis quantization: ``x (..., D)`` ->
    ``(q (..., D) in qdt, scale (...) float32)`` with
    ``scale = amax / qmax`` so ``scale * q ~= x``.  All-zero rows get
    scale 0 and quantize to exact zeros."""
    qdt = jnp.dtype(qdt)
    qmax = qmax_of(qdt)
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1) / qmax
    q = xf / jnp.where(scale > 0, scale, 1.0)[..., None]
    q = jnp.clip(q, -qmax, qmax)
    if qdt == jnp.dtype(jnp.int8):
        q = jnp.round(q)
    return q.astype(qdt), scale


def dequantize(q, scale, dtype=jnp.float32):
    """``scale * q`` with the scale broadcast over the trailing D axis."""
    return (q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)) \
        .astype(dtype)
