"""Fused paged decode kernel — per-row queries stream their block table.

The XLA decode path gathers each row's live blocks into a
``(B, nb_max * bs)`` copy and re-reads it through ``layers.attention``
(two HBM round-trips over the live KV per layer).  This kernel reads the
pool exactly once: the grid walks ``(row, kv tile)``, the KV BlockSpec
index map resolves logical sub-block -> physical through the
SMEM-prefetched block table before the DMA is issued, and segment /
position masking + online softmax run inline on each tile.

Unlike ``paged_attention.paged_decode_attention`` (single query token,
contiguous-prefix validity) this kernel carries the serving engine's full
decode shape: ``T`` query tokens per row (draft steps T=1, catch-up
T=W+1, chunked-prefill appends at the bucketed chunk width) with per-token
``q_seg``/``q_pos`` (seg -1 = bucket padding) and per-slot pool
``seg``/``pos`` validity — the exact semantics of
``serving/paged.make_paged_decode_override``, minus the gather copy.

Tile knobs (searched by ``kernels/autotune.py``): ``bk`` sub-tiles each
physical block (pool viewed as ``(N * f, bk, Kh, D)``), ``depth`` fetches
that many KV tiles per grid step so their DMAs double-buffer against the
previous tiles' attention compute.  Rows shorter than the longest row
clamp trailing steps to their last live sub-block — the revisit elides
the DMA and ``pl.when`` skips the compute, removing the per-step revisit
stalls of a padded dense walk.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _fused_decode_kernel(bt_ref, nlive_ref, q_seg_ref, q_pos_ref, q_ref,
                         *refs, nsteps: int, depth: int, scale: float,
                         quantized: bool = False):
    group = 6 if quantized else 4
    tiles = refs[:group * depth]
    o_ref, m_ref, l_ref, acc_ref = refs[group * depth:]
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_seg = q_seg_ref[0]                    # (T,)
    q_pos = q_pos_ref[0]

    def _tile(i, pos_ref, seg_ref, k_ref, v_ref, *sc_refs):
        t = j * depth + i

        @pl.when(t < nlive_ref[b])
        def _compute():
            q = q_ref[0].astype(jnp.float32) * scale        # (T, H, D)
            k = k_ref[0].astype(jnp.float32)                # (bk, Kh, D)
            v = v_ref[0].astype(jnp.float32)
            if quantized:
                ks_ref, vs_ref = sc_refs
                k = k * ks_ref[0][..., None]
                v = v * vs_ref[0][..., None]
            T, H, D = q.shape
            bk, Kh, _ = k.shape
            G = H // Kh
            kv_seg = seg_ref[0]             # (bk,) -1 = invalidated slot
            kv_pos = pos_ref[0]
            qg = q.reshape(T, Kh, G, D)
            s = jax.lax.dot_general(
                qg.transpose(1, 2, 0, 3).reshape(Kh, G * T, D),
                k.transpose(1, 2, 0),
                (((2,), (1,)), ((0,), (0,))))               # (Kh, G*T, bk)
            s = s.reshape(Kh, G, T, bk).transpose(2, 0, 1, 3)
            mask = (q_seg[:, None] == kv_seg[None, :]) \
                & (kv_seg[None, :] >= 0) \
                & (kv_pos[None, :] <= q_pos[:, None])       # (T, bk)
            s = jnp.where(mask[:, None, None, :], s, NEG)

            m_prev = m_ref[...].reshape(T, Kh, G)
            l_prev = l_ref[...].reshape(T, Kh, G)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            m_safe = jnp.maximum(m_new, -1e29)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask[:, None, None, :], p, 0.0)
            corr = jnp.where(jnp.isfinite(m_prev),
                             jnp.exp(m_prev - m_safe), 0.0)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            pv = jax.lax.dot_general(
                p.transpose(1, 2, 0, 3).reshape(Kh, G * T, bk),
                v.transpose(1, 0, 2),
                (((2,), (1,)), ((0,), (0,))))               # (Kh, G*T, D)
            pv = pv.reshape(Kh, G, T, D).transpose(2, 0, 1, 3)
            acc_ref[...] = (acc_ref[...].reshape(T, Kh, G, D)
                            * corr[..., None] + pv).reshape(T, Kh * G, D)
            m_ref[...] = m_new.reshape(T, Kh * G)
            l_ref[...] = l_new.reshape(T, Kh * G)

    for i in range(depth):
        _tile(i, *tiles[group * i:group * (i + 1)])

    @pl.when(j == nsteps - 1)
    def _finish():
        l = l_ref[...]
        o = acc_ref[...] / jnp.maximum(l, 1e-30)[..., None]
        o = jnp.where((l > 0)[..., None], o, 0.0)
        o_ref[0, ...] = o.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "depth", "interpret"))
def fused_paged_decode(q, k_pool, v_pool, pool_seg, pool_pos,
                       q_seg, q_pos, block_tables,
                       k_scale=None, v_scale=None, *,
                       bk: int = 0, depth: int = 1,
                       interpret: bool = False):
    """Multi-token paged decode streaming each row's blocks from the pool.

    q: (B, T, H, D); pools: (N, bs, Kh, D); pool_seg/pool_pos: (N, bs)
    per-slot validity (-1 = not attendable) and absolute position;
    q_seg/q_pos: (B, T) per-query segment (-1 = bucket padding, output
    ignored) and position; block_tables: (B, NB) physical block per
    logical block, -1 = unallocated (prefix-allocated per row).  Returns
    (B, T, H, D).  ``bk``/``depth`` as in ``fused_paged_verify``.

    k_scale/v_scale: optional (N, bs, Kh) float32 sidecars for quantized
    pools — each KV tile is dequantized in-register (``scale * q``) right
    after its DMA, under the same online softmax.
    """
    B, T, H, D = q.shape
    N, bs, Kh, _ = k_pool.shape
    NB = block_tables.shape[1]
    if bk <= 0 or bs % bk:
        bk = bs
    depth = max(1, int(depth))
    f = bs // bk
    scale = 1.0 / np.sqrt(D)

    quantized = k_scale is not None
    kp = k_pool.reshape(N * f, bk, Kh, D)
    vp = v_pool.reshape(N * f, bk, Kh, D)
    seg_p = pool_seg.astype(jnp.int32).reshape(N * f, bk)
    pos_p = pool_pos.astype(jnp.int32).reshape(N * f, bk)
    if quantized:
        ksp = k_scale.reshape(N * f, bk, Kh)
        vsp = v_scale.reshape(N * f, bk, Kh)

    bt = block_tables.astype(jnp.int32)
    bt_sub = (jnp.maximum(bt, 0)[:, :, None] * f
              + jnp.arange(f)).reshape(B, NB * f)
    # rows allocate blocks as a prefix, so the live sub-block count is
    # exact; rows with no blocks (idle pool rows) have nlive = 0 and every
    # tile skipped -> zero output, matching the XLA gather's full mask
    nlive = (jnp.sum(bt >= 0, axis=1) * f).astype(jnp.int32)

    nsteps = -(-(NB * f) // depth)
    pad_t = nsteps * depth - NB * f
    bt_sub = jnp.pad(bt_sub, ((0, 0), (0, pad_t)))

    def clamp(b, j, i, nl):
        return jnp.minimum(j * depth + i, jnp.maximum(nl[b], 1) - 1)

    def kv_map(i):
        return lambda b, j, bt_s, nl: \
            (bt_s[b, clamp(b, j, i, nl)], 0, 0, 0)

    def slot_map(i):
        return lambda b, j, bt_s, nl: (bt_s[b, clamp(b, j, i, nl)], 0)

    def sc_map(i):
        return lambda b, j, bt_s, nl: (bt_s[b, clamp(b, j, i, nl)], 0, 0)

    tile_specs = []
    tile_args = []
    for i in range(depth):
        tile_specs += [pl.BlockSpec((1, bk), slot_map(i)),
                       pl.BlockSpec((1, bk), slot_map(i)),
                       pl.BlockSpec((1, bk, Kh, D), kv_map(i)),
                       pl.BlockSpec((1, bk, Kh, D), kv_map(i))]
        tile_args += [pos_p, seg_p, kp, vp]
        if quantized:
            tile_specs += [pl.BlockSpec((1, bk, Kh), sc_map(i)),
                           pl.BlockSpec((1, bk, Kh), sc_map(i))]
            tile_args += [ksp, vsp]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nsteps),
        in_specs=[
            pl.BlockSpec((1, T), lambda b, j, bt_s, nl: (b, 0)),
            pl.BlockSpec((1, T), lambda b, j, bt_s, nl: (b, 0)),
            pl.BlockSpec((1, T, H, D), lambda b, j, bt_s, nl: (b, 0, 0, 0)),
        ] + tile_specs,
        out_specs=pl.BlockSpec((1, T, H, D),
                               lambda b, j, bt_s, nl: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((T, H), jnp.float32),
            pltpu.VMEM((T, H), jnp.float32),
            pltpu.VMEM((T, H, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_fused_decode_kernel, nsteps=nsteps, depth=depth,
                          scale=scale, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, T, H, D), q.dtype),
        interpret=interpret,
    )(bt_sub, nlive, q_seg.astype(jnp.int32), q_pos.astype(jnp.int32),
      q, *tile_args)
