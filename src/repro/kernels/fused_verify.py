"""Fused paged verification kernel — one launch for the whole packed pass.

The serving engine's XLA path verifies a cohort in two HBM round-trips per
attention layer: an ``(M * bs,)`` gather materializes the live blocks as a
flat packed copy, then ``layers.attention`` reads that copy back.  This
kernel fuses the two: KV blocks stream **directly from the pool** through
the SMEM-prefetched block-id list (``PrefetchScalarGridSpec``), the
segment/position and tree ancestor-bitmask mask terms apply inline on each
tile, and an online softmax accumulates across tiles — the gathered copy
is never written, and per-layer launches drop from two to one.

On top of ``kernels/paged_attention.paged_verify_attention`` this kernel
adds the autotunable knobs searched by ``kernels/autotune.py``:

``bq``     query tile (rows of the packed query axis per grid step);
``bk``     KV sub-tile — the pool is viewed as ``(N * f, bk, Kh, D)`` with
           ``f = bs // bk`` (a reshape, not a copy), so one physical block
           becomes ``f`` independently schedulable tiles;
``depth``  KV tiles fetched per grid step: the BlockSpec machinery issues
           the ``depth`` DMAs of step ``j+1`` while step ``j`` computes,
           i.e. block-table prefetch is double-buffered ``depth`` tiles
           ahead of the attention math.

Trailing grid steps (the power-of-two padding of ``block_ids``) clamp
their index map to the last *live* sub-block, so the revisit elides the
DMA (same trick as ``paged_decode_attention``) and ``pl.when`` skips the
compute — padding never costs a block read.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _fused_verify_kernel(ids_ref, owner_ref, nlive_ref,
                         q_seg_ref, q_pos_ref, q_anc_ref, q_ref, *refs,
                         nsteps: int, depth: int, scale: float,
                         quantized: bool = False):
    group = 7 if quantized else 5
    tiles = refs[:group * depth]
    o_ref, m_ref, l_ref, acc_ref = refs[group * depth:]
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_seg = q_seg_ref[...]                  # (BQ,)
    q_pos = q_pos_ref[...]
    q_anc = q_anc_ref[...]                  # (BQ,) ancestor bitmask
    q_lo, q_hi = jnp.min(q_seg), jnp.max(q_seg)
    q_pmax = jnp.max(q_pos)

    def _tile(i, pos_ref, seg_ref, node_ref, k_ref, v_ref, *sc_refs):
        t = j * depth + i
        owner = owner_ref[t]                # segment owning sub-block t
        kv_pos = pos_ref[0]                 # (bk,)
        kv_node = node_ref[0]               # (bk,) tree-node tag
        # a pool slot is attendable iff its block is live (owner >= 0) and
        # the slot itself holds committed/accepted KV (pool seg >= 0)
        kv_seg = jnp.where(seg_ref[0] >= 0, owner, -1)
        not_future = jnp.min(jnp.where(kv_seg >= 0, kv_pos,
                                       jnp.iinfo(jnp.int32).max)) <= q_pmax

        @pl.when((t < nlive_ref[0]) & (owner >= q_lo) & (owner <= q_hi)
                 & (owner >= 0) & not_future)
        def _compute():
            q = q_ref[...].astype(jnp.float32) * scale      # (BQ, H, D)
            k = k_ref[0].astype(jnp.float32)                # (bk, Kh, D)
            v = v_ref[0].astype(jnp.float32)
            if quantized:
                ks_ref, vs_ref = sc_refs
                k = k * ks_ref[0][..., None]
                v = v * vs_ref[0][..., None]
            BQ, H, D = q.shape
            bk, Kh, _ = k.shape
            G = H // Kh
            qg = q.reshape(BQ, Kh, G, D)
            s = jax.lax.dot_general(
                qg.transpose(1, 2, 0, 3).reshape(Kh, G * BQ, D),
                k.transpose(1, 2, 0),
                (((2,), (1,)), ((0,), (0,))))               # (Kh, G*BQ, bk)
            s = s.reshape(Kh, G, BQ, bk).transpose(2, 0, 1, 3)
            mask = (q_seg[:, None] == kv_seg[None, :]) \
                & (kv_seg[None, :] >= 0) \
                & (kv_pos[None, :] <= q_pos[:, None])       # (BQ, bk)
            # tree-topology term (see kernels/verify_attention.py): -1 =
            # committed (always attendable), -2 = dead CoW duplicate
            # (never), n >= 0 = attendable iff bit n of the ancestor mask
            nd = kv_node[None, :]
            on_path = ((q_anc[:, None] >> jnp.clip(nd, 0, 31)) & 1) \
                .astype(bool)
            mask &= jnp.where(nd == -1, True,
                              jnp.where(nd < -1, False, on_path))
            s = jnp.where(mask[:, None, None, :], s, NEG)

            m_prev = m_ref[...].reshape(BQ, Kh, G)
            l_prev = l_ref[...].reshape(BQ, Kh, G)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            m_safe = jnp.maximum(m_new, -1e29)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask[:, None, None, :], p, 0.0)
            corr = jnp.where(jnp.isfinite(m_prev),
                             jnp.exp(m_prev - m_safe), 0.0)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            pv = jax.lax.dot_general(
                p.transpose(1, 2, 0, 3).reshape(Kh, G * BQ, bk),
                v.transpose(1, 0, 2),
                (((2,), (1,)), ((0,), (0,))))               # (Kh, G*BQ, D)
            pv = pv.reshape(Kh, G, BQ, D).transpose(2, 0, 1, 3)
            acc_ref[...] = (acc_ref[...].reshape(BQ, Kh, G, D)
                            * corr[..., None] + pv).reshape(BQ, Kh * G, D)
            m_ref[...] = m_new.reshape(BQ, Kh * G)
            l_ref[...] = l_new.reshape(BQ, Kh * G)

    for i in range(depth):
        _tile(i, *tiles[group * i:group * (i + 1)])

    @pl.when(j == nsteps - 1)
    def _finish():
        l = l_ref[...]
        o = acc_ref[...] / jnp.maximum(l, 1e-30)[..., None]
        o = jnp.where((l > 0)[..., None], o, 0.0)
        o_ref[...] = o.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bq", "bk", "depth", "interpret"))
def fused_paged_verify(q, k_pool, v_pool, pool_seg, pool_pos,
                       q_seg, q_pos, block_ids, block_owner,
                       q_anc=None, block_node=None,
                       k_scale=None, v_scale=None, *,
                       bq: int = 128, bk: int = 0, depth: int = 1,
                       interpret: bool = False):
    """Single-launch packed verification streaming KV from the pool.

    Same contract as ``paged_attention.paged_verify_attention`` — q:
    (Tq, H, D); pools: (N, bs, Kh, D); pool_seg/pool_pos: (N, bs);
    q_seg/q_pos: (Tq,); block_ids/block_owner: (M,) live physical blocks
    and their owning segments (-1 owner = padding entry); optional
    q_anc (Tq,) / block_node (M, bs) tree topology.  Returns (Tq, H, D).

    ``bq``/``bk``/``depth`` are the autotuned tile knobs (module
    docstring); ``bk`` in (0, non-divisor of bs) falls back to ``bs``.

    k_scale/v_scale: optional (N, bs, Kh) float32 sidecars for quantized
    pools — KV tiles stream as int8/fp8 and are dequantized in-register
    (``scale * q``) before the mask/softmax math.
    """
    Tq, H, D = q.shape
    N, bs, Kh, _ = k_pool.shape
    M = block_ids.shape[0]
    if bk <= 0 or bs % bk:
        bk = bs
    depth = max(1, int(depth))
    f = bs // bk
    scale = 1.0 / np.sqrt(D)

    if q_anc is None:
        q_anc = jnp.full((Tq,), -1, jnp.int32)
    if block_node is None:
        block_node = jnp.full((M, bs), -1, jnp.int32)

    # sub-tile view of the pool — a reshape of contiguous memory, no copy
    quantized = k_scale is not None
    kp = k_pool.reshape(N * f, bk, Kh, D)
    vp = v_pool.reshape(N * f, bk, Kh, D)
    seg_p = pool_seg.astype(jnp.int32).reshape(N * f, bk)
    pos_p = pool_pos.astype(jnp.int32).reshape(N * f, bk)
    node_p = block_node.astype(jnp.int32).reshape(M * f, bk)
    if quantized:
        ksp = k_scale.reshape(N * f, bk, Kh)
        vsp = v_scale.reshape(N * f, bk, Kh)

    ids = jnp.maximum(block_ids.astype(jnp.int32), 0)
    owner = block_owner.astype(jnp.int32)
    ids_sub = (ids[:, None] * f + jnp.arange(f)).reshape(M * f)
    owner_sub = jnp.repeat(owner, f)
    # live sub-blocks end at the last owner >= 0 entry (owner gaps inside
    # the live prefix, if any, stay untouched — only *trailing* padding
    # folds into revisits)
    last_live = jnp.max(jnp.where(owner >= 0,
                                  jnp.arange(M, dtype=jnp.int32), -1))
    nlive = ((last_live + 1) * f).reshape(1)

    nsteps = -(-(M * f) // depth)
    pad_t = nsteps * depth - M * f
    ids_sub = jnp.pad(ids_sub, (0, pad_t))
    owner_sub = jnp.pad(owner_sub, (0, pad_t), constant_values=-1)

    Tq_p = int(np.ceil(Tq / bq) * bq)
    qp = jnp.pad(q, ((0, Tq_p - Tq), (0, 0), (0, 0)))

    def pad_i32(x, n):
        return jnp.pad(x.astype(jnp.int32), (0, n), constant_values=-1)
    q_seg_p = pad_i32(q_seg, Tq_p - Tq)
    q_pos_p = pad_i32(q_pos, Tq_p - Tq)
    q_anc_p = pad_i32(q_anc, Tq_p - Tq)

    def clamp(j, i, nl):
        # trailing steps revisit the last live sub-block: DMA elided,
        # compute skipped in-kernel via t < nlive
        return jnp.minimum(j * depth + i, jnp.maximum(nl[0], 1) - 1)

    def kv_map(i):
        return lambda qi, j, ids_s, ow, nl: (ids_s[clamp(j, i, nl)], 0, 0, 0)

    def slot_map(i):
        return lambda qi, j, ids_s, ow, nl: (ids_s[clamp(j, i, nl)], 0)

    def node_map(i):
        # block_node is in *gathered* order, aligned with block_ids
        return lambda qi, j, ids_s, ow, nl: (clamp(j, i, nl), 0)

    def q_map(qi, j, ids_s, ow, nl):
        return (qi,)

    def sc_map(i):
        return lambda qi, j, ids_s, ow, nl: (ids_s[clamp(j, i, nl)], 0, 0)

    tile_specs = []
    tile_args = []
    for i in range(depth):
        tile_specs += [pl.BlockSpec((1, bk), slot_map(i)),
                       pl.BlockSpec((1, bk), slot_map(i)),
                       pl.BlockSpec((1, bk), node_map(i)),
                       pl.BlockSpec((1, bk, Kh, D), kv_map(i)),
                       pl.BlockSpec((1, bk, Kh, D), kv_map(i))]
        tile_args += [pos_p, seg_p, node_p, kp, vp]
        if quantized:
            tile_specs += [pl.BlockSpec((1, bk, Kh), sc_map(i)),
                           pl.BlockSpec((1, bk, Kh), sc_map(i))]
            tile_args += [ksp, vsp]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(Tq_p // bq, nsteps),
        in_specs=[
            pl.BlockSpec((bq,), q_map),
            pl.BlockSpec((bq,), q_map),
            pl.BlockSpec((bq,), q_map),
            pl.BlockSpec((bq, H, D), lambda qi, j, ids_s, ow, nl:
                         (qi, 0, 0)),
        ] + tile_specs,
        out_specs=pl.BlockSpec((bq, H, D), lambda qi, j, ids_s, ow, nl:
                               (qi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, H), jnp.float32),
            pltpu.VMEM((bq, H), jnp.float32),
            pltpu.VMEM((bq, H, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_fused_verify_kernel, nsteps=nsteps, depth=depth,
                          scale=scale, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Tq_p, H, D), q.dtype),
        interpret=interpret,
    )(ids_sub, owner_sub, nlive, q_seg_p, q_pos_p, q_anc_p, qp, *tile_args)
    return out[:Tq]
