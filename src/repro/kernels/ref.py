"""Pure-jnp oracles for every Pallas kernel (the ground truth the kernels
are asserted against across shape/dtype sweeps)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import quant

NEG = -1e30


def _maybe_dequant(pool, scale, idx):
    """Gather pool blocks by ``idx``; dequantize against the (same-indexed)
    scale sidecar when one is provided (quantized-pool oracles)."""
    g = pool[idx]
    if scale is None:
        return g
    return quant.dequantize(g, scale[idx])


def tree_mask_term(q_anc, kv_node):
    """Topology-aware tree-speculation mask term (SpecInfer-style).

    ``q_anc``: per-query int32 ancestor bitmask — bit ``n`` set iff tree
    node ``n`` is an ancestor of (or is) the query's own node; -1 (all
    bits) for non-tree queries.  ``kv_node``: per-KV-slot int32 node tag —
    -1 for committed context (always attendable, subject to the causal /
    segment terms), -2 for dead slots (duplicate committed cells inside a
    CoW branch copy: never attendable), ``n >= 0`` for a slot written by
    tree node ``n`` (attendable only along the query's root-to-node path).
    Shapes broadcast: q_anc (..., Tq, 1) x kv_node (..., 1, Tkv).
    """
    on_path = ((q_anc >> jnp.clip(kv_node, 0, 31)) & 1).astype(bool)
    return jnp.where(kv_node == -1, True,
                     jnp.where(kv_node < -1, False, on_path))


def verify_attention_ref(q, k, v, q_seg, q_pos, kv_seg, kv_pos,
                         q_anc=None, kv_node=None):
    """SPIN packed verification attention — direct Eq. (13).

    q: (Tq, H, D); k, v: (Tkv, Kh, D); segs/pos: int32 1-D.
    a_{i,j} = F(q_i,k_j) * I[seg_j == seg_i] / sum_j' F(q_i,k_j') I[...]
    with causal masking kv_pos <= q_pos and empty slots seg == -1.
    Optional ``q_anc`` (Tq,) / ``kv_node`` (Tkv,) add the tree-topology
    term (see ``tree_mask_term``) for single-pass token-tree verification.
    """
    Tq, H, Dh = q.shape
    Kh = k.shape[1]
    G = H // Kh
    qf = q.astype(jnp.float32).reshape(Tq, Kh, G, Dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("qkgd,skd->qkgs", qf, kf) / np.sqrt(Dh)
    mask = (q_seg[:, None] == kv_seg[None, :]) \
        & (kv_seg[None, :] >= 0) \
        & (kv_pos[None, :] <= q_pos[:, None])
    if kv_node is not None:
        mask &= tree_mask_term(q_anc[:, None], kv_node[None, :])
    s = jnp.where(mask[:, None, None, :], s, NEG)
    m = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), -1e29)
    p = jnp.exp(s - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-30)
    o = jnp.einsum("qkgs,skd->qkgd", p, vf)
    # rows with no valid kv -> zero output
    any_valid = jnp.any(mask, axis=-1)
    o = jnp.where(any_valid[:, None, None, None], o, 0.0)
    return o.reshape(Tq, H, Dh).astype(q.dtype)


def mha_ref(q, k, v, *, causal=True, window=0):
    """Plain (optionally sliding-window) causal attention.
    q: (B, S, H, D); k, v: (B, S, Kh, D)."""
    B, S, H, Dh = q.shape
    Kh = k.shape[2]
    G = H // Kh
    qf = q.astype(jnp.float32).reshape(B, S, Kh, G, Dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32)) \
        / np.sqrt(Dh)
    i = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= i[None, :] <= i[:, None]
    if window:
        mask &= i[None, :] > (i[:, None] - window)
    s = jnp.where(mask[None, None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, Dh).astype(q.dtype)


def paged_decode_ref(q, k_pool, v_pool, block_tables, lengths,
                     k_scale=None, v_scale=None):
    """Gather each row's block list into a dense view, then plain decode.
    q: (B, H, D); pools: (N, bs, Kh, D); block_tables: (B, NB) (< 0 =
    unallocated); lengths: (B,).  Optional (N, bs, Kh) scale sidecars
    dequantize int8/fp8 pools post-gather."""
    B = q.shape[0]
    N, bs = k_pool.shape[0], k_pool.shape[1]
    bt = jnp.maximum(block_tables, 0)
    k = _maybe_dequant(k_pool, k_scale, bt).reshape(
        B, -1, *k_pool.shape[2:])
    v = _maybe_dequant(v_pool, v_scale, bt).reshape(
        B, -1, *v_pool.shape[2:])
    return decode_ref(q, k, v, lengths)


def paged_verify_ref(q, k_pool, v_pool, pool_seg, pool_pos,
                     q_seg, q_pos, block_ids, block_owner,
                     q_anc=None, block_node=None,
                     k_scale=None, v_scale=None):
    """Gather the live blocks into a flat packed view, then Eq. (13).
    ``block_node`` (M, bs) carries per-slot tree-node tags aligned with
    ``block_ids`` (see ``tree_mask_term``); optional (N, bs, Kh) scale
    sidecars dequantize int8/fp8 pools post-gather."""
    ids = jnp.maximum(block_ids, 0)
    bs = k_pool.shape[1]
    k = _maybe_dequant(k_pool, k_scale, ids).reshape(-1, *k_pool.shape[2:])
    v = _maybe_dequant(v_pool, v_scale, ids).reshape(-1, *v_pool.shape[2:])
    slot_seg = pool_seg[ids].reshape(-1)
    kv_pos = pool_pos[ids].reshape(-1)
    owner = jnp.repeat(block_owner, bs)
    kv_seg = jnp.where((slot_seg >= 0) & (owner >= 0), owner, -1)
    kv_node = None if block_node is None else block_node.reshape(-1)
    return verify_attention_ref(q, k, v, q_seg, q_pos, kv_seg, kv_pos,
                                q_anc, kv_node)


def paged_seq_decode_ref(q, k_pool, v_pool, pool_seg, pool_pos,
                         q_seg, q_pos, block_tables,
                         k_scale=None, v_scale=None):
    """Oracle for ``kernels/fused_decode.fused_paged_decode``: gather each
    row's block list dense, then segment/position-masked attention.

    q: (B, T, H, D); pools: (N, bs, Kh, D); pool_seg/pool_pos: (N, bs);
    q_seg/q_pos: (B, T) (seg -1 = padding query -> zero output);
    block_tables: (B, NB), -1 = unallocated (slots masked); optional
    (N, bs, Kh) scale sidecars dequantize int8/fp8 pools post-gather."""
    B, T, H, Dh = q.shape
    bs, Kh = k_pool.shape[1], k_pool.shape[2]
    G = H // Kh
    g = jnp.maximum(block_tables, 0)
    k = _maybe_dequant(k_pool, k_scale, g) \
        .reshape(B, -1, Kh, Dh).astype(jnp.float32)
    v = _maybe_dequant(v_pool, v_scale, g) \
        .reshape(B, -1, Kh, Dh).astype(jnp.float32)
    seg = pool_seg[g].reshape(B, -1)
    kv_pos = pool_pos[g].reshape(B, -1)
    live = jnp.repeat(block_tables >= 0, bs, axis=1)
    kv_seg = jnp.where(live & (seg >= 0), seg, -1)
    qf = q.astype(jnp.float32).reshape(B, T, Kh, G, Dh)
    s = jnp.einsum("btkgd,bskd->btkgs", qf, k) / np.sqrt(Dh)
    mask = (q_seg[:, :, None] == kv_seg[:, None, :]) \
        & (kv_seg[:, None, :] >= 0) \
        & (kv_pos[:, None, :] <= q_pos[:, :, None])
    s = jnp.where(mask[:, :, None, None, :], s, NEG)
    m = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), -1e29)
    p = jnp.exp(s - m)
    p = jnp.where(mask[:, :, None, None, :], p, 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("btkgs,bskd->btkgd", p / jnp.maximum(denom, 1e-30), v)
    any_valid = jnp.any(mask, axis=-1)
    o = jnp.where(any_valid[:, :, None, None, None], o, 0.0)
    return o.reshape(B, T, H, Dh).astype(q.dtype)


def decode_ref(q, k, v, lengths):
    """GQA decode: one query token per row against a long KV cache.
    q: (B, H, D); k, v: (B, S, Kh, D); lengths: (B,) valid KV prefix."""
    B, H, Dh = q.shape
    S, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    qf = q.astype(jnp.float32).reshape(B, Kh, G, Dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k.astype(jnp.float32)) \
        / np.sqrt(Dh)
    mask = jnp.arange(S)[None, :] < lengths[:, None]
    s = jnp.where(mask[:, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Dh).astype(q.dtype)
