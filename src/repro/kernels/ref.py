"""Pure-jnp oracles for every Pallas kernel (the ground truth the kernels
are asserted against across shape/dtype sweeps)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG = -1e30


def verify_attention_ref(q, k, v, q_seg, q_pos, kv_seg, kv_pos):
    """SPIN packed verification attention — direct Eq. (13).

    q: (Tq, H, D); k, v: (Tkv, Kh, D); segs/pos: int32 1-D.
    a_{i,j} = F(q_i,k_j) * I[seg_j == seg_i] / sum_j' F(q_i,k_j') I[...]
    with causal masking kv_pos <= q_pos and empty slots seg == -1.
    """
    Tq, H, Dh = q.shape
    Kh = k.shape[1]
    G = H // Kh
    qf = q.astype(jnp.float32).reshape(Tq, Kh, G, Dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("qkgd,skd->qkgs", qf, kf) / np.sqrt(Dh)
    mask = (q_seg[:, None] == kv_seg[None, :]) \
        & (kv_seg[None, :] >= 0) \
        & (kv_pos[None, :] <= q_pos[:, None])
    s = jnp.where(mask[:, None, None, :], s, NEG)
    m = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), -1e29)
    p = jnp.exp(s - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-30)
    o = jnp.einsum("qkgs,skd->qkgd", p, vf)
    # rows with no valid kv -> zero output
    any_valid = jnp.any(mask, axis=-1)
    o = jnp.where(any_valid[:, None, None, None], o, 0.0)
    return o.reshape(Tq, H, Dh).astype(q.dtype)


def mha_ref(q, k, v, *, causal=True, window=0):
    """Plain (optionally sliding-window) causal attention.
    q: (B, S, H, D); k, v: (B, S, Kh, D)."""
    B, S, H, Dh = q.shape
    Kh = k.shape[2]
    G = H // Kh
    qf = q.astype(jnp.float32).reshape(B, S, Kh, G, Dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32)) \
        / np.sqrt(Dh)
    i = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= i[None, :] <= i[:, None]
    if window:
        mask &= i[None, :] > (i[:, None] - window)
    s = jnp.where(mask[None, None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, Dh).astype(q.dtype)


def paged_decode_ref(q, k_pool, v_pool, block_tables, lengths):
    """Gather each row's block list into a dense view, then plain decode.
    q: (B, H, D); pools: (N, bs, Kh, D); block_tables: (B, NB) (< 0 =
    unallocated); lengths: (B,)."""
    B = q.shape[0]
    N, bs = k_pool.shape[0], k_pool.shape[1]
    bt = jnp.maximum(block_tables, 0)
    k = k_pool[bt].reshape(B, -1, *k_pool.shape[2:])
    v = v_pool[bt].reshape(B, -1, *v_pool.shape[2:])
    return decode_ref(q, k, v, lengths)


def paged_verify_ref(q, k_pool, v_pool, pool_seg, pool_pos,
                     q_seg, q_pos, block_ids, block_owner):
    """Gather the live blocks into a flat packed view, then Eq. (13)."""
    ids = jnp.maximum(block_ids, 0)
    bs = k_pool.shape[1]
    k = k_pool[ids].reshape(-1, *k_pool.shape[2:])
    v = v_pool[ids].reshape(-1, *v_pool.shape[2:])
    slot_seg = pool_seg[ids].reshape(-1)
    kv_pos = pool_pos[ids].reshape(-1)
    owner = jnp.repeat(block_owner, bs)
    kv_seg = jnp.where((slot_seg >= 0) & (owner >= 0), owner, -1)
    return verify_attention_ref(q, k, v, q_seg, q_pos, kv_seg, kv_pos)


def decode_ref(q, k, v, lengths):
    """GQA decode: one query token per row against a long KV cache.
    q: (B, H, D); k, v: (B, S, Kh, D); lengths: (B,) valid KV prefix."""
    B, H, Dh = q.shape
    S, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    qf = q.astype(jnp.float32).reshape(B, Kh, G, Dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k.astype(jnp.float32)) \
        / np.sqrt(Dh)
    mask = jnp.arange(S)[None, :] < lengths[:, None]
    s = jnp.where(mask[:, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Dh).astype(q.dtype)
