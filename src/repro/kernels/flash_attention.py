"""Causal (optionally sliding-window) flash attention for prefill.

grid = (B, S/BQ, S/BK): KV is the sequential axis; future blocks (j*BK >
(i+1)*BQ) and blocks entirely outside the sliding window are skipped — for
SWA the per-query-block work is O(window), giving the sub-quadratic prefill
mixtral's long_500k cell relies on.  Running (m, l, acc) in VMEM scratch;
q/k/v tiles in VMEM, f32 accumulation, MXU-shaped dots (BQ=BK=128, D=128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, nk: int, bq: int, bk: int, scale: float, window: int):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = i * bq + jax.lax.iota(jnp.int32, bq)
    kv_pos = j * bk + jax.lax.iota(jnp.int32, bk)
    causal_possible = j * bk <= (i + 1) * bq - 1
    in_window = True if not window else \
        (j + 1) * bk - 1 > i * bq - window

    @pl.when(causal_possible & in_window)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale       # (BQ, H, D)
        k = k_ref[0].astype(jnp.float32)               # (BK, Kh, D)
        v = v_ref[0].astype(jnp.float32)
        BQ, H, D = q.shape
        BK, Kh, _ = k.shape
        G = H // Kh
        qg = q.reshape(BQ, Kh, G, D)
        s = jax.lax.dot_general(
            qg.transpose(1, 2, 0, 3).reshape(Kh, G * BQ, D),
            k.transpose(1, 2, 0),
            (((2,), (1,)), ((0,), (0,)))).reshape(Kh, G, BQ, BK)
        s = s.transpose(2, 0, 1, 3)                    # (BQ, Kh, G, BK)
        mask = kv_pos[None, :] <= q_pos[:, None]
        if window:
            mask &= kv_pos[None, :] > (q_pos[:, None] - window)
        s = jnp.where(mask[:, None, None, :], s, NEG)

        m_prev = m_ref[...].reshape(BQ, Kh, G)
        l_prev = l_ref[...].reshape(BQ, Kh, G)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        m_safe = jnp.maximum(m_new, -1e29)
        p = jnp.where(mask[:, None, None, :],
                      jnp.exp(s - m_safe[..., None]), 0.0)
        corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.transpose(1, 2, 0, 3).reshape(Kh, G * BQ, BK),
            v.transpose(1, 0, 2),
            (((2,), (1,)), ((0,), (0,)))).reshape(Kh, G, BQ, D)
        pv = pv.transpose(2, 0, 1, 3)
        acc_ref[...] = (acc_ref[...].reshape(BQ, Kh, G, D) * corr[..., None]
                        + pv).reshape(BQ, Kh * G, D)
        m_ref[...] = m_new.reshape(BQ, Kh * G)
        l_ref[...] = l_new.reshape(BQ, Kh * G)

    @pl.when(j == nk - 1)
    def _finish():
        l = l_ref[...]
        o = acc_ref[...] / jnp.maximum(l, 1e-30)[..., None]
        o_ref[0, ...] = jnp.where((l > 0)[..., None], o,
                                  0.0).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, window: int = 0, bq: int = 128,
                    bk: int = 128, interpret: bool = False):
    """q: (B, S, H, D); k, v: (B, S, Kh, D).  Causal; optional SWA."""
    B, S, H, D = q.shape
    Kh = k.shape[2]
    scale = 1.0 / np.sqrt(D)
    S_p = int(np.ceil(S / max(bq, bk)) * max(bq, bk))
    qp = jnp.pad(q, ((0, 0), (0, S_p - S), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, S_p - S), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, S_p - S), (0, 0), (0, 0)))
    nq, nk = S_p // bq, S_p // bk

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, bq=bq, bk=bk, scale=scale,
                          window=window),
        grid=(B, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, H, D), lambda b, i, j: (b, i, 0, 0)),
            pl.BlockSpec((1, bk, Kh, D), lambda b, i, j: (b, j, 0, 0)),
            pl.BlockSpec((1, bk, Kh, D), lambda b, i, j: (b, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, H, D), lambda b, i, j: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S_p, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, H), jnp.float32),
            pltpu.VMEM((bq, H), jnp.float32),
            pltpu.VMEM((bq, H, D), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :S]
