"""Paged KV attention kernels — decode and packed-verify over a block pool.

The serving engine's paged layout stores KV in a per-model *block pool*
``(num_blocks, block_size, Kh, D)``; each request owns an ordered list of
physical blocks (its *block table*).  Both kernels here address the pool
through block tables prefetched into SMEM (``PrefetchScalarGridSpec``), so
the index map of the KV BlockSpec resolves a *logical* block to a
*physical* one before the DMA is issued — the kernels stream exactly the
live blocks of the batch, never the free pool and never padding up to a
``bk`` multiple of the dense cache length.

``paged_decode_attention``
  grid = (B, NB_max): one query token per row vs its block list.  The KV
  index map clamps the logical block index to the row's live block count,
  so trailing grid steps revisit the last live block and Pallas elides the
  DMA (revisited block => no new copy); compute is skipped via ``pl.when``.

``paged_verify_attention``
  grid = (Tq/bq, M): SPIN packed verification (Eq. 13 segment-restricted
  softmax) where the packed KV is the concatenation of the *live* blocks of
  all requests being verified, gathered fragment-by-fragment straight from
  the pool — no flat packed KV copy is ever materialized.  ``block_ids``
  lists the M live physical blocks (any order / fragmentation);
  ``block_owner`` carries the owning request's segment id per block, so a
  whole KV tile is skipped when its owner cannot match the query tile.

  **Chunked prefill reuses this kernel.**  A prompt chunk is a span of
  queries at positions ``pos..pos+n-1`` attending the owning row's prior
  context blocks plus itself causally — exactly the verify shape with the
  chunk's tokens as the query segment (q_pos = chunk positions, block
  list = the row's blocks).  The serving engine's XLA path goes through
  the same formulation (serving/paged.decode_step_paged with a (1, nb)
  row table); no dedicated chunk-prefill kernel exists on purpose.

Block sizing: one KV tile is (block_size, Kh, D).  With block_size=128,
Kh=8, D=128 bf16 that is 512 KiB/tile — comfortably double-buffered in
16 MiB VMEM; block_size=16 remains correct (CPU/test shapes) but
under-utilizes the MXU on real hardware.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


# ----------------------------------------------------------------- decode --

def _decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, *refs,
                   nb: int, bs: int, scale: float, quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = refs
    else:
        o_ref, m_ref, l_ref, acc_ref = refs
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    kv_pos = j * bs + jax.lax.iota(jnp.int32, bs)

    @pl.when(j * bs < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale       # (H, D)
        k = k_ref[0].astype(jnp.float32)               # (bs, Kh, D)
        v = v_ref[0].astype(jnp.float32)
        if quantized:                                  # scale * int8 inline
            k = k * ks_ref[0][..., None]               # (bs, Kh, 1)
            v = v * vs_ref[0][..., None]
        H, D = q.shape
        Kh = k.shape[1]
        G = H // Kh
        qg = q.reshape(Kh, G, D)
        s = jnp.einsum("kgd,skd->kgs", qg, k)          # (Kh, G, bs)
        mask = kv_pos < length
        s = jnp.where(mask[None, None, :], s, NEG)
        m_prev = m_ref[...].reshape(Kh, G)
        l_prev = l_ref[...].reshape(Kh, G)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        m_safe = jnp.maximum(m_new, -1e29)
        p = jnp.where(mask[None, None, :], jnp.exp(s - m_safe[..., None]),
                      0.0)
        corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("kgs,skd->kgd", p, v)
        acc_ref[...] = (acc_ref[...].reshape(Kh, G, D) * corr[..., None]
                        + pv).reshape(Kh * G, D)
        m_ref[...] = m_new.reshape(1, Kh * G)
        l_ref[...] = l_new.reshape(1, Kh * G)

    @pl.when(j == nb - 1)
    def _finish():
        l = l_ref[...].reshape(-1)
        o = acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
        o_ref[0, ...] = jnp.where((l > 0)[:, None], o, 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths,
                           k_scale=None, v_scale=None, *,
                           interpret: bool = False):
    """q: (B, H, D); k_pool, v_pool: (N, bs, Kh, D);
    block_tables: (B, NB) int32 physical block per logical block (< 0 =
    unallocated); lengths: (B,) live KV prefix per row.  Returns (B, H, D).

    Optional ``k_scale``/``v_scale`` (N, bs, Kh) float32 dequantization
    sidecars for int8/fp8 pools (kernels/quant.py): the kernel streams
    the quantized blocks plus their scale tiles through the same
    physical-block index map and applies ``scale * int8`` inline, under
    the online softmax — no dequantized pool copy.

    Requires ``lengths[b] <= allocated_blocks(b) * bs`` — the pool
    allocator's append-a-block invariant.
    """
    B, H, D = q.shape
    N, bs, Kh, _ = k_pool.shape
    NB = block_tables.shape[1]
    scale = 1.0 / np.sqrt(D)
    bt = block_tables.astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)
    quantized = k_scale is not None

    def kv_map(b, j, bt_ref, len_ref):
        # clamp to the row's last live block: trailing grid steps revisit
        # it (no fresh DMA) and pl.when skips their compute.
        live = jnp.maximum(pl.cdiv(len_ref[b], bs) - 1, 0)
        jj = jnp.minimum(j, live)
        return (jnp.maximum(bt_ref[b, jj], 0), 0, 0, 0)

    def sc_map(b, j, bt_ref, len_ref):
        return kv_map(b, j, bt_ref, len_ref)[:3]

    in_specs = [
        pl.BlockSpec((1, H, D), lambda b, j, bt, ln: (b, 0, 0)),
        pl.BlockSpec((1, bs, Kh, D), kv_map),
        pl.BlockSpec((1, bs, Kh, D), kv_map),
    ]
    args = [q, k_pool, v_pool]
    if quantized:
        in_specs += [pl.BlockSpec((1, bs, Kh), sc_map),
                     pl.BlockSpec((1, bs, Kh), sc_map)]
        args += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, NB),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, H, D), lambda b, j, bt, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, H), jnp.float32),
            pltpu.VMEM((1, H), jnp.float32),
            pltpu.VMEM((H, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_decode_kernel, nb=NB, bs=bs, scale=scale,
                          quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )(bt, lengths, *args)


# ----------------------------------------------------------------- verify --

def _verify_kernel(ids_ref, owner_ref, nlive_ref, q_seg_ref, q_pos_ref,
                   q_anc_ref, pos_ref, seg_ref, node_ref, q_ref, k_ref,
                   v_ref, *refs, nb: int, scale: float, quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = refs
    else:
        o_ref, m_ref, l_ref, acc_ref = refs
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_seg = q_seg_ref[...]                  # (BQ,)
    q_pos = q_pos_ref[...]
    q_anc = q_anc_ref[...]                  # (BQ,) ancestor bitmask
    owner = owner_ref[j]                    # scalar: segment owning block j
    kv_pos = pos_ref[0]                     # (bs,)
    kv_node = node_ref[0]                   # (bs,) tree-node tag
    # a pool slot is attendable iff its block is live (owner >= 0) and the
    # slot itself holds committed/accepted KV (pool seg >= 0)
    kv_seg = jnp.where(seg_ref[0] >= 0, owner, -1)

    q_lo, q_hi = jnp.min(q_seg), jnp.max(q_seg)
    not_future = jnp.min(jnp.where(kv_seg >= 0, kv_pos,
                                   jnp.iinfo(jnp.int32).max)) <= jnp.max(q_pos)

    @pl.when((owner >= q_lo) & (owner <= q_hi) & (owner >= 0) & not_future)
    def _compute():
        q = q_ref[...].astype(jnp.float32) * scale      # (BQ, H, D)
        k = k_ref[0].astype(jnp.float32)                # (bs, Kh, D)
        v = v_ref[0].astype(jnp.float32)
        if quantized:                                   # scale * int8 inline
            k = k * ks_ref[0][..., None]                # (bs, Kh, 1)
            v = v * vs_ref[0][..., None]
        BQ, H, D = q.shape
        bs, Kh, _ = k.shape
        G = H // Kh
        qg = q.reshape(BQ, Kh, G, D)
        s = jax.lax.dot_general(
            qg.transpose(1, 2, 0, 3).reshape(Kh, G * BQ, D),
            k.transpose(1, 2, 0),
            (((2,), (1,)), ((0,), (0,))))               # (Kh, G*BQ, bs)
        s = s.reshape(Kh, G, BQ, bs).transpose(2, 0, 1, 3)  # (BQ,Kh,G,bs)
        mask = (q_seg[:, None] == kv_seg[None, :]) \
            & (kv_seg[None, :] >= 0) \
            & (kv_pos[None, :] <= q_pos[:, None])       # (BQ, bs)
        # tree-topology term (see kernels/verify_attention.py): -1 =
        # committed (always attendable), -2 = dead CoW duplicate (never),
        # n >= 0 = attendable iff bit n of the query's ancestor mask
        nd = kv_node[None, :]
        on_path = ((q_anc[:, None] >> jnp.clip(nd, 0, 31)) & 1).astype(bool)
        mask &= jnp.where(nd == -1, True, jnp.where(nd < -1, False, on_path))
        s = jnp.where(mask[:, None, None, :], s, NEG)

        m_prev = m_ref[...].reshape(BQ, Kh, G)
        l_prev = l_ref[...].reshape(BQ, Kh, G)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        m_safe = jnp.maximum(m_new, -1e29)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[:, None, None, :], p, 0.0)
        corr = jnp.where(jnp.isfinite(m_prev),
                         jnp.exp(m_prev - m_safe), 0.0)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.transpose(1, 2, 0, 3).reshape(Kh, G * BQ, bs),
            v.transpose(1, 0, 2),
            (((2,), (1,)), ((0,), (0,))))               # (Kh, G*BQ, D)
        pv = pv.reshape(Kh, G, BQ, D).transpose(2, 0, 1, 3)
        acc_ref[...] = (acc_ref[...].reshape(BQ, Kh, G, D)
                        * corr[..., None] + pv).reshape(BQ, Kh * G, D)
        m_ref[...] = m_new.reshape(BQ, Kh * G)
        l_ref[...] = l_new.reshape(BQ, Kh * G)

    @pl.when(j == nb - 1)
    def _finish():
        l = l_ref[...]
        o = acc_ref[...] / jnp.maximum(l, 1e-30)[..., None]
        o = jnp.where((l > 0)[..., None], o, 0.0)
        o_ref[...] = o.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def paged_verify_attention(q, k_pool, v_pool, pool_seg, pool_pos,
                           q_seg, q_pos, block_ids, block_owner,
                           q_anc=None, block_node=None,
                           k_scale=None, v_scale=None, *,
                           bq: int = 128, interpret: bool = False):
    """Packed verification over live pool blocks (paper Eq. 13, paged).

    q: (Tq, H, D) — all requests' verification tokens flattened;
    k_pool, v_pool: (N, bs, Kh, D); pool_seg, pool_pos: (N, bs) per-slot
    validity (-1 = empty) and absolute position;
    q_seg, q_pos: (Tq,) request segment / position per query;
    block_ids: (M,) physical ids of the live blocks (any order);
    block_owner: (M,) request segment owning each listed block (-1 = padding
    entry: the block is skipped).  Optional tree-speculation topology:
    q_anc (Tq,) ancestor bitmask per query, block_node (M, bs) per-slot
    node tags aligned with block_ids (-1 committed, -2 dead, n >= 0 tree
    node).  Optional ``k_scale``/``v_scale`` (N, bs, Kh) float32 sidecars
    dequantize int8/fp8 pools in-kernel (``scale * int8`` on the streamed
    tile — see ``paged_decode_attention``).  Returns (Tq, H, D).
    """
    Tq, H, D = q.shape
    N, bs, Kh, _ = k_pool.shape
    M = block_ids.shape[0]
    scale = 1.0 / np.sqrt(D)

    if q_anc is None:
        q_anc = jnp.full((Tq,), -1, jnp.int32)
    if block_node is None:
        block_node = jnp.full((M, bs), -1, jnp.int32)

    Tq_p = int(np.ceil(Tq / bq) * bq)
    qp = jnp.pad(q, ((0, Tq_p - Tq), (0, 0), (0, 0)))
    def pad_i32(x, n):
        return jnp.pad(x.astype(jnp.int32), (0, n), constant_values=-1)
    q_seg_p = pad_i32(q_seg, Tq_p - Tq)
    q_pos_p = pad_i32(q_pos, Tq_p - Tq)
    q_anc_p = pad_i32(q_anc, Tq_p - Tq)
    ids = jnp.maximum(block_ids.astype(jnp.int32), 0)
    owner = block_owner.astype(jnp.int32)
    # trailing grid steps (power-of-two padding of block_ids) clamp to the
    # last live fragment the way paged_decode_attention clamps to the last
    # live block: the revisit elides the DMA instead of re-reading padding
    # blocks, and the kernel's owner < 0 guard already skips their compute.
    # Interior owner gaps (none today) are deliberately left unclamped.
    last_live = jnp.max(jnp.where(owner >= 0,
                                  jnp.arange(M, dtype=jnp.int32), -1))
    nlive = jnp.maximum(last_live + 1, 1).reshape(1)

    def _jc(j, nl):
        return jnp.minimum(j, nl[0] - 1)

    def blk(i, j, ids, ow, nl):
        return (ids[_jc(j, nl)], 0)

    quantized = k_scale is not None
    in_specs = [
        pl.BlockSpec((bq,), lambda i, j, ids, ow, nl: (i,)),
        pl.BlockSpec((bq,), lambda i, j, ids, ow, nl: (i,)),
        pl.BlockSpec((bq,), lambda i, j, ids, ow, nl: (i,)),
        pl.BlockSpec((1, bs), blk),
        pl.BlockSpec((1, bs), blk),
        # block_node is in *gathered* order, aligned with block_ids
        pl.BlockSpec((1, bs), lambda i, j, ids, ow, nl:
                     (_jc(j, nl), 0)),
        pl.BlockSpec((bq, H, D), lambda i, j, ids, ow, nl: (i, 0, 0)),
        pl.BlockSpec((1, bs, Kh, D), lambda i, j, ids, ow, nl:
                     (ids[_jc(j, nl)], 0, 0, 0)),
        pl.BlockSpec((1, bs, Kh, D), lambda i, j, ids, ow, nl:
                     (ids[_jc(j, nl)], 0, 0, 0)),
    ]
    args = [qp, k_pool, v_pool]
    if quantized:
        in_specs += [pl.BlockSpec((1, bs, Kh), lambda i, j, ids, ow, nl:
                                  (ids[_jc(j, nl)], 0, 0)),
                     pl.BlockSpec((1, bs, Kh), lambda i, j, ids, ow, nl:
                                  (ids[_jc(j, nl)], 0, 0))]
        args += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(Tq_p // bq, M),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bq, H, D),
                               lambda i, j, ids, ow, nl: (i, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, H), jnp.float32),
            pltpu.VMEM((bq, H), jnp.float32),
            pltpu.VMEM((bq, H, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_verify_kernel, nb=M, scale=scale,
                          quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Tq_p, H, D), q.dtype),
        interpret=interpret,
    )(ids, owner, nlive, q_seg_p, q_pos_p, q_anc_p,
      pool_pos.astype(jnp.int32), pool_seg.astype(jnp.int32),
      block_node.astype(jnp.int32), *args)
    return out[:Tq]
