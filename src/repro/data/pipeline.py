"""Training data pipeline.

The corpus is a seeded synthetic language with structure at TWO scales:

  * an order-1 backbone  t1[prev]           — learnable by any tiny model;
  * an order-3 backbone  t3[hash(prev,prev2,prev3)] — needs capacity.

Each token follows the order-3 process with prob = request difficulty, else
the order-1 process.  This gives exactly the capacity-dependent
predictability SPIN's heterogeneous SSMs exploit (paper Fig. 2/3): small
distilled SSMs match the LLM on easy (order-1-dominated) requests; hard
requests need the larger SSMs.  See tests/test_substrates.py.

Deterministic by (seed, step, host): each host reads a disjoint shard, so
restarts resume from the step counter alone — no data-state checkpoint.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np

N_CTX2 = 131
N_CTX3 = 521


def _backbone(rng: np.random.Generator, vocab: int):
    """(t1, t2, t3) transition tables — order-1 / order-2 / order-3
    structure (values avoid the mode-marker tokens).  Capacity ladder:
    tiny models learn t1, mid models add t2, only large models fit t3."""
    t1 = rng.integers(3, vocab, size=(vocab,))
    t2 = rng.integers(3, vocab, size=(N_CTX2,))
    t3 = rng.integers(3, vocab, size=(N_CTX3,))
    return t1, t2, t3


def _h2(a: int, b: int) -> int:
    return (a * 31 + b * 7) % N_CTX2


def _h3(a: int, b: int, c: int) -> int:
    return (a * 131 + b * 31 + c * 7) % N_CTX3


def mode_of(difficulty: float) -> int:
    """1 = easy (order-1), 2 = medium (order-2), 3 = hard (order-3)."""
    return 1 if difficulty < 0.33 else (2 if difficulty < 0.66 else 3)


def synthetic_sequence(rng: np.random.Generator, length: int, vocab: int,
                       tables, difficulty: float) -> np.ndarray:
    """Three request modes of increasing structural order; token 0 is the
    MODE MARKER so the mode is observable in-context.  Within a mode the
    greedy continuation is DETERMINISTIC (table chain + 2% noise floor), so
    draft acceptance measures whether a model has the capacity to learn
    that mode's table: tiny models learn t1 only, mid-size add t2 (131
    hashed contexts), only large models fit t3 (521 contexts) — the
    capacity-dependent Fig. 2/3 effect."""
    t1, t2, t3 = tables
    mode = mode_of(difficulty)
    seq = np.empty(length, np.int64)
    seq[1:3] = rng.integers(3, vocab, 2)
    seq[0] = mode
    noise = rng.random(length) < 0.02
    for t in range(3, length):
        if noise[t]:
            seq[t] = rng.integers(3, vocab)
        elif mode == 1:
            seq[t] = t1[int(seq[t - 1])]
        elif mode == 2:
            seq[t] = t2[_h2(int(seq[t - 1]), int(seq[t - 2]))]
        else:
            seq[t] = t3[_h3(int(seq[t - 1]), int(seq[t - 2]),
                            int(seq[t - 3]))]
    return seq


def synthetic_corpus_batch(seed: int, step: int, batch: int, seq_len: int,
                           vocab: int, difficulty: float = 0.35,
                           host_id: int = 0, num_hosts: int = 1):
    """(tokens, labels) int32 arrays for one training step.  Per-sequence
    difficulty is drawn uniform in [0, 2*difficulty] so the corpus teaches
    both scales of structure."""
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step * num_hosts + host_id]))
    tables = _backbone(np.random.default_rng(seed), vocab)
    # trimodal: easy / medium / hard sequences in equal parts
    toks = np.stack([
        synthetic_sequence(rng, seq_len + 1, vocab, tables,
                           difficulty=float(rng.choice([0.1, 0.5, 0.9])))
        for _ in range(batch)])
    return toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32)


@dataclasses.dataclass
class TokenStream:
    """Stateless-resumable training stream (step index is the only state)."""
    seed: int
    batch: int
    seq_len: int
    vocab: int
    difficulty: float = 0.35
    host_id: int = 0
    num_hosts: int = 1

    def batch_at(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        return synthetic_corpus_batch(
            self.seed, step, self.batch, self.seq_len, self.vocab,
            self.difficulty, self.host_id, self.num_hosts)

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
