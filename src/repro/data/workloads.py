"""Serving workloads mirroring the paper's three datasets.

Alpaca / ChatGPT-Prompts (CP) / Chatbot-Instruction-Prompts (CIP) differ in
request difficulty and prompt-length distributions (paper §II-B / §VI-A):
Alpaca is the hardest (large SSMs win), CP the easiest (small SSMs win),
CIP in between; Mix combines all three.  We reproduce those *distributions*
synthetically with an explicit per-request difficulty knob that controls
how predictable the continuation is (see data/pipeline.py).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

from repro.data.pipeline import _backbone, synthetic_sequence


@dataclasses.dataclass
class Dataset:
    name: str
    difficulty_mean: float
    difficulty_std: float
    prompt_len_range: tuple
    output_len_range: tuple


DATASETS: Dict[str, Dataset] = {
    # hardest: long, information-dense instructions (hard mode)
    "alpaca": Dataset("alpaca", 0.85, 0.05, (24, 96), (24, 96)),
    # easiest: short, repetitive chat prompts (easy mode)
    "cp": Dataset("cp", 0.05, 0.03, (8, 32), (16, 48)),
    # intermediate: mix of modes
    "cip": Dataset("cip", 0.45, 0.35, (16, 64), (16, 64)),
}


@dataclasses.dataclass(frozen=True)
class SLO:
    """Per-request latency contract (SpecServe/AdaSpec-style serving).

    ``ttft_deadline`` is the seconds-from-arrival budget for the FIRST
    output token; every subsequent token is due ``tpot_target`` seconds
    after the previous one's deadline, so token ``j`` (0-indexed) of a
    request is due at ``arrival + ttft_deadline + j * tpot_target`` on
    the sim clock.  Frozen on purpose: the contract is immutable once a
    request enters the system — schedulers read it, nothing rewrites it.
    """
    ttft_deadline: float       # seconds from arrival to first token
    tpot_target: float         # seconds per subsequent token

    def __post_init__(self):
        if self.ttft_deadline <= 0 or self.tpot_target <= 0:
            raise ValueError("SLO deadlines must be positive "
                             f"(got {self!r})")

    def token_deadline(self, arrival: float, j: int) -> float:
        """Absolute sim-clock deadline of output token ``j`` (0-indexed)."""
        return arrival + self.ttft_deadline + j * self.tpot_target


# Per-class SLO profiles: a profile maps each dataset class to the
# contract its traffic buys.  Values are sim-clock seconds sized for the
# reduced CPU zoo (single-engine service runs at roughly 300 tok/s with
# TTFTs in the tens of milliseconds — see results/BENCH_baseline.json);
# ``assign_slos(scale=)`` rescales everything for other regimes.
# "interactive" marks chat-shaped traffic (cp) strict and batch-shaped
# traffic (alpaca) lax — the mixed strict/lax workload the SLO benchmarks
# serve; "strict"/"lax" apply one contract uniformly.
SLO_PROFILES: Dict[str, Dict[str, SLO]] = {
    "strict": {
        "alpaca": SLO(ttft_deadline=0.050, tpot_target=0.006),
        "cp": SLO(ttft_deadline=0.050, tpot_target=0.006),
        "cip": SLO(ttft_deadline=0.050, tpot_target=0.006),
    },
    "lax": {
        "alpaca": SLO(ttft_deadline=1.0, tpot_target=0.060),
        "cp": SLO(ttft_deadline=1.0, tpot_target=0.060),
        "cip": SLO(ttft_deadline=1.0, tpot_target=0.060),
    },
    "interactive": {
        "alpaca": SLO(ttft_deadline=1.0, tpot_target=0.060),
        "cp": SLO(ttft_deadline=0.050, tpot_target=0.006),
        "cip": SLO(ttft_deadline=0.150, tpot_target=0.015),
    },
}


def assign_slos(reqs: List["Request"], profile: str, *,
                scale: float = 1.0) -> List["Request"]:
    """Stamp per-class SLO contracts onto requests, in place.

    ``profile`` is a key of :data:`SLO_PROFILES` or ``"off"`` (stamp
    nothing — every request keeps ``slo=None`` and the serving stack is
    bit-identical to deadline-blind operation).  ``scale`` multiplies
    every deadline, so one profile serves differently-calibrated cost
    models."""
    if profile == "off":
        return reqs
    try:
        classes = SLO_PROFILES[profile]
    except KeyError:
        raise ValueError(
            f"unknown SLO profile {profile!r} (expected 'off' or one of "
            f"{'/'.join(sorted(SLO_PROFILES))})") from None
    if scale <= 0:
        raise ValueError("SLO scale must be positive")
    for r in reqs:
        base = classes[r.dataset]
        r.slo = SLO(ttft_deadline=base.ttft_deadline * scale,
                    tpot_target=base.tpot_target * scale)
    return reqs


@dataclasses.dataclass
class Request:
    rid: int
    dataset: str
    difficulty: float
    prompt: np.ndarray          # (P,) int32
    max_new: int
    arrival: float = 0.0        # sim-clock arrival timestamp (serving)
    # scheduling class: lower value = more urgent (nice-level semantics).
    # The default 0 everywhere reproduces plain FIFO-by-arrival exactly.
    priority: int = 0
    # latency contract (None = no deadline: the scheduler, gamma
    # controller and router treat the request exactly as before SLOs
    # existed — the `--slo-profile off` bit-identity contract)
    slo: Optional[SLO] = None
    # runtime state
    emitted: Optional[List[int]] = None
    done: bool = False
    preemptions: int = 0
    finish_time: Optional[float] = None
    # chunked prefill: context tokens already ingested into the KV pool
    # (reset to 0 on preemption — partial prefill is discarded with the
    # freed blocks)
    prefill_pos: int = 0
    # sim-clock time the first output token was committed (TTFT source)
    first_token_time: Optional[float] = None
    # sim-clock commit time of every emitted token (parallel to
    # ``emitted``), the deadline-attainment source: token j met its SLO
    # iff token_times[j] <= slo.token_deadline(arrival, j)
    token_times: Optional[List[float]] = None

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def latency(self) -> Optional[float]:
        """End-to-end latency (arrival -> finish) on the sim clock."""
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival

    def next_deadline(self) -> float:
        """Absolute sim-clock deadline of the NEXT token this request
        owes (its TTFT deadline until the first token commits, then the
        running TPOT schedule); +inf without an SLO, so deadline-sorted
        orderings degrade to the deadline-free ranking exactly."""
        if self.slo is None:
            return math.inf
        return self.slo.token_deadline(self.arrival, len(self.emitted or []))


def poisson_arrivals(n: int, rate: float, seed: int = 0,
                     start: float = 0.0) -> np.ndarray:
    """Arrival timestamps of a Poisson process with ``rate`` requests/sec
    (exponential inter-arrival gaps), the standard open-loop serving
    workload model."""
    if rate <= 0:
        raise ValueError("arrival rate must be positive")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, n)
    return start + np.cumsum(gaps)


def _thinned_arrivals(n: int, rate_fn, rate_max: float, seed: int,
                      start: float) -> np.ndarray:
    """First ``n`` arrivals of an inhomogeneous Poisson process with
    instantaneous rate ``rate_fn(t) <= rate_max``, by Lewis-Shedler
    thinning: draw candidate arrivals at the constant envelope rate
    ``rate_max``, keep each with probability ``rate_fn(t) / rate_max``.
    Exact (not binned), and deterministic from the seed."""
    if rate_max <= 0:
        raise ValueError("peak arrival rate must be positive")
    rng = np.random.default_rng(seed)
    out = np.empty(n, np.float64)
    t, k = float(start), 0
    while k < n:
        t += rng.exponential(1.0 / rate_max)
        if rng.random() * rate_max <= rate_fn(t):
            out[k] = t
            k += 1
    return out


def diurnal_arrivals(n: int, *, rate_base: float, rate_peak: float,
                     period: float, seed: int = 0,
                     start: float = 0.0) -> np.ndarray:
    """Arrival timestamps under a sinusoidal day/night load curve: the
    instantaneous rate swings between ``rate_base`` (trough) and
    ``rate_peak`` (peak) with the given period, starting at the trough —
    the canonical autoscaling workload (a static fleet sized for the
    peak idles through every trough; an elastic one follows the curve).
    """
    if not 0 < rate_base <= rate_peak:
        raise ValueError("need 0 < rate_base <= rate_peak")
    if period <= 0:
        raise ValueError("period must be positive")
    mid = 0.5 * (rate_base + rate_peak)
    amp = 0.5 * (rate_peak - rate_base)

    def rate(t):
        # -cos: t=0 is the trough, t=period/2 the peak
        return mid - amp * math.cos(2.0 * math.pi * (t - start) / period)

    return _thinned_arrivals(n, rate, rate_peak, seed, start)


def bursty_arrivals(n: int, *, rate_base: float, rate_peak: float,
                    burst_every: float, burst_len: float, seed: int = 0,
                    start: float = 0.0) -> np.ndarray:
    """Arrival timestamps under a square-wave load: quiet ``rate_base``
    traffic with a ``rate_peak`` burst of length ``burst_len`` every
    ``burst_every`` seconds (the first burst starts one full quiet gap
    in).  Stresses scale-up latency and work stealing: a burst lands on
    whatever fleet the trough left behind."""
    if not 0 < rate_base <= rate_peak:
        raise ValueError("need 0 < rate_base <= rate_peak")
    if burst_every <= 0 or not 0 < burst_len <= burst_every:
        raise ValueError("need 0 < burst_len <= burst_every")

    def rate(t):
        phase = (t - start) % burst_every
        return rate_peak if phase >= burst_every - burst_len else rate_base

    return _thinned_arrivals(n, rate, rate_peak, seed, start)


def assign_arrivals(reqs: List[Request], *, rate: Optional[float] = None,
                    trace: Optional[np.ndarray] = None,
                    seed: int = 0) -> List[Request]:
    """Stamp arrival timestamps onto requests, in place.

    Exactly one of ``rate`` (Poisson process) or ``trace`` (explicit
    timestamps, e.g. replayed from a production log) must be given.
    ``trace`` shorter than the workload raises; extra entries are ignored.
    """
    if (rate is None) == (trace is None):
        raise ValueError("pass exactly one of rate= or trace=")
    if trace is None:
        times = poisson_arrivals(len(reqs), rate, seed)
    else:
        times = np.asarray(trace, np.float64)
        if len(times) < len(reqs):
            raise ValueError(
                f"trace has {len(times)} timestamps for {len(reqs)} requests")
    for r, t in zip(reqs, times):
        r.arrival = float(t)
    return reqs


def make_workload(name: str, n_requests: int, vocab: int, seed: int = 0,
                  scale: float = 1.0,
                  arrival_rate: Optional[float] = None,
                  arrival_trace: Optional[np.ndarray] = None,
                  slo_profile: str = "off",
                  slo_scale: float = 1.0) -> List[Request]:
    """name in {alpaca, cp, cip, mix}.  ``scale`` shrinks lengths for CPU
    tests.  ``arrival_rate`` (Poisson, req/s) or ``arrival_trace``
    (explicit timestamps) stamp streaming arrival times for the
    continuous-batching scheduler; default is everything-at-t=0.
    ``slo_profile`` stamps per-class latency contracts (see
    :func:`assign_slos`); the default ``"off"`` stamps none."""
    rng = np.random.default_rng(seed)
    table = _backbone(np.random.default_rng(seed ^ 0x5EED), vocab)
    if name == "mix":
        names = rng.choice(list(DATASETS), size=n_requests)
    else:
        names = [name] * n_requests
    out = []
    for i, ds_name in enumerate(names):
        ds = DATASETS[str(ds_name)]
        diff = float(np.clip(
            rng.normal(ds.difficulty_mean, ds.difficulty_std), 0.0, 0.9))
        plen = int(max(4, rng.integers(*ds.prompt_len_range) * scale))
        olen = int(max(4, rng.integers(*ds.output_len_range) * scale))
        prompt = synthetic_sequence(rng, plen, vocab, table, diff)
        out.append(Request(rid=i, dataset=str(ds_name), difficulty=diff,
                           prompt=prompt.astype(np.int32), max_new=olen,
                           emitted=[]))
    if arrival_rate is not None or arrival_trace is not None:
        assign_arrivals(out, rate=arrival_rate, trace=arrival_trace,
                        seed=seed ^ 0xA55)
    assign_slos(out, slo_profile, scale=slo_scale)
    return out
