"""Serving workloads mirroring the paper's three datasets.

Alpaca / ChatGPT-Prompts (CP) / Chatbot-Instruction-Prompts (CIP) differ in
request difficulty and prompt-length distributions (paper §II-B / §VI-A):
Alpaca is the hardest (large SSMs win), CP the easiest (small SSMs win),
CIP in between; Mix combines all three.  We reproduce those *distributions*
synthetically with an explicit per-request difficulty knob that controls
how predictable the continuation is (see data/pipeline.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.data.pipeline import _backbone, synthetic_sequence


@dataclasses.dataclass
class Dataset:
    name: str
    difficulty_mean: float
    difficulty_std: float
    prompt_len_range: tuple
    output_len_range: tuple


DATASETS: Dict[str, Dataset] = {
    # hardest: long, information-dense instructions (hard mode)
    "alpaca": Dataset("alpaca", 0.85, 0.05, (24, 96), (24, 96)),
    # easiest: short, repetitive chat prompts (easy mode)
    "cp": Dataset("cp", 0.05, 0.03, (8, 32), (16, 48)),
    # intermediate: mix of modes
    "cip": Dataset("cip", 0.45, 0.35, (16, 64), (16, 64)),
}


@dataclasses.dataclass
class Request:
    rid: int
    dataset: str
    difficulty: float
    prompt: np.ndarray          # (P,) int32
    max_new: int
    # runtime state
    emitted: Optional[List[int]] = None
    done: bool = False

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)


def make_workload(name: str, n_requests: int, vocab: int, seed: int = 0,
                  scale: float = 1.0) -> List[Request]:
    """name in {alpaca, cp, cip, mix}.  ``scale`` shrinks lengths for CPU
    tests."""
    rng = np.random.default_rng(seed)
    table = _backbone(np.random.default_rng(seed ^ 0x5EED), vocab)
    if name == "mix":
        names = rng.choice(list(DATASETS), size=n_requests)
    else:
        names = [name] * n_requests
    out = []
    for i, ds_name in enumerate(names):
        ds = DATASETS[str(ds_name)]
        diff = float(np.clip(
            rng.normal(ds.difficulty_mean, ds.difficulty_std), 0.0, 0.9))
        plen = int(max(4, rng.integers(*ds.prompt_len_range) * scale))
        olen = int(max(4, rng.integers(*ds.output_len_range) * scale))
        prompt = synthetic_sequence(rng, plen, vocab, table, diff)
        out.append(Request(rid=i, dataset=str(ds_name), difficulty=diff,
                           prompt=prompt.astype(np.int32), max_new=olen,
                           emitted=[]))
    return out
