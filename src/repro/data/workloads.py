"""Serving workloads mirroring the paper's three datasets.

Alpaca / ChatGPT-Prompts (CP) / Chatbot-Instruction-Prompts (CIP) differ in
request difficulty and prompt-length distributions (paper §II-B / §VI-A):
Alpaca is the hardest (large SSMs win), CP the easiest (small SSMs win),
CIP in between; Mix combines all three.  We reproduce those *distributions*
synthetically with an explicit per-request difficulty knob that controls
how predictable the continuation is (see data/pipeline.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.data.pipeline import _backbone, synthetic_sequence


@dataclasses.dataclass
class Dataset:
    name: str
    difficulty_mean: float
    difficulty_std: float
    prompt_len_range: tuple
    output_len_range: tuple


DATASETS: Dict[str, Dataset] = {
    # hardest: long, information-dense instructions (hard mode)
    "alpaca": Dataset("alpaca", 0.85, 0.05, (24, 96), (24, 96)),
    # easiest: short, repetitive chat prompts (easy mode)
    "cp": Dataset("cp", 0.05, 0.03, (8, 32), (16, 48)),
    # intermediate: mix of modes
    "cip": Dataset("cip", 0.45, 0.35, (16, 64), (16, 64)),
}


@dataclasses.dataclass
class Request:
    rid: int
    dataset: str
    difficulty: float
    prompt: np.ndarray          # (P,) int32
    max_new: int
    arrival: float = 0.0        # sim-clock arrival timestamp (serving)
    # scheduling class: lower value = more urgent (nice-level semantics).
    # The default 0 everywhere reproduces plain FIFO-by-arrival exactly.
    priority: int = 0
    # runtime state
    emitted: Optional[List[int]] = None
    done: bool = False
    preemptions: int = 0
    finish_time: Optional[float] = None
    # chunked prefill: context tokens already ingested into the KV pool
    # (reset to 0 on preemption — partial prefill is discarded with the
    # freed blocks)
    prefill_pos: int = 0
    # sim-clock time the first output token was committed (TTFT source)
    first_token_time: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def latency(self) -> Optional[float]:
        """End-to-end latency (arrival -> finish) on the sim clock."""
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival


def poisson_arrivals(n: int, rate: float, seed: int = 0,
                     start: float = 0.0) -> np.ndarray:
    """Arrival timestamps of a Poisson process with ``rate`` requests/sec
    (exponential inter-arrival gaps), the standard open-loop serving
    workload model."""
    if rate <= 0:
        raise ValueError("arrival rate must be positive")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, n)
    return start + np.cumsum(gaps)


def assign_arrivals(reqs: List[Request], *, rate: Optional[float] = None,
                    trace: Optional[np.ndarray] = None,
                    seed: int = 0) -> List[Request]:
    """Stamp arrival timestamps onto requests, in place.

    Exactly one of ``rate`` (Poisson process) or ``trace`` (explicit
    timestamps, e.g. replayed from a production log) must be given.
    ``trace`` shorter than the workload raises; extra entries are ignored.
    """
    if (rate is None) == (trace is None):
        raise ValueError("pass exactly one of rate= or trace=")
    if trace is None:
        times = poisson_arrivals(len(reqs), rate, seed)
    else:
        times = np.asarray(trace, np.float64)
        if len(times) < len(reqs):
            raise ValueError(
                f"trace has {len(times)} timestamps for {len(reqs)} requests")
    for r, t in zip(reqs, times):
        r.arrival = float(t)
    return reqs


def make_workload(name: str, n_requests: int, vocab: int, seed: int = 0,
                  scale: float = 1.0,
                  arrival_rate: Optional[float] = None,
                  arrival_trace: Optional[np.ndarray] = None
                  ) -> List[Request]:
    """name in {alpaca, cp, cip, mix}.  ``scale`` shrinks lengths for CPU
    tests.  ``arrival_rate`` (Poisson, req/s) or ``arrival_trace``
    (explicit timestamps) stamp streaming arrival times for the
    continuous-batching scheduler; default is everything-at-t=0."""
    rng = np.random.default_rng(seed)
    table = _backbone(np.random.default_rng(seed ^ 0x5EED), vocab)
    if name == "mix":
        names = rng.choice(list(DATASETS), size=n_requests)
    else:
        names = [name] * n_requests
    out = []
    for i, ds_name in enumerate(names):
        ds = DATASETS[str(ds_name)]
        diff = float(np.clip(
            rng.normal(ds.difficulty_mean, ds.difficulty_std), 0.0, 0.9))
        plen = int(max(4, rng.integers(*ds.prompt_len_range) * scale))
        olen = int(max(4, rng.integers(*ds.output_len_range) * scale))
        prompt = synthetic_sequence(rng, plen, vocab, table, diff)
        out.append(Request(rid=i, dataset=str(ds_name), difficulty=diff,
                           prompt=prompt.astype(np.int32), max_new=olen,
                           emitted=[]))
    if arrival_rate is not None or arrival_trace is not None:
        assign_arrivals(out, rate=arrival_rate, trace=arrival_trace,
                        seed=seed ^ 0xA55)
    return out
