from repro.data.workloads import Dataset, Request, make_workload
from repro.data.pipeline import TokenStream, synthetic_corpus_batch

__all__ = ["Dataset", "Request", "make_workload", "TokenStream",
           "synthetic_corpus_batch"]
