"""Per-model KV-cache pools.

Two layouts share one interface (``has/insert/evict/rows/lengths/...``):

``PagedCachePool`` (default)
    KV lives in a physical *block pool* ``(num_blocks, block_size, Kh, D)``
    with a free-block list; each request owns an ordered block table.  The
    scheduler's KV budget *is* ``num_blocks`` — an enforced physical
    invariant, not a model.  Admission scatters the prefilled KV into
    exactly the prompt's blocks (O(prompt blocks), independent of pool
    capacity); decode growth appends one block at a time; eviction /
    preemption returns blocks to the free list in O(1) — no cache traffic.
    Rollback of rejected drafts trims the tail block in place (a
    ``gamma``-wide seg scatter).  Blocks carry copy-on-write refcounts:
    ``fork`` aliases a whole row in O(row blocks) with zero cache traffic,
    ``cow_prepare`` copies only the shared blocks a write is about to
    touch, and ``evict`` returns a block to the free list only when its
    last reference drops — the substrate for tree speculation, where every
    draft branch forks the main row and loses or wins in O(branches).
    ``kv_dtype`` in {bf16, int8, fp8} selects the block storage
    precision: quantized pools (kernels/quant.py) keep per-(slot, head)
    float32 scale sidecars inside each attention entry, written by the
    same scatters, copied by the same CoW block copy, and freed by the
    same refcount drop as the blocks they scale.
    Attention-only models (recurrent state is
    O(1)/request and stays dense); see ``serving/paged.py`` for how the
    model forward addresses the pool.

``DenseCachePool`` (legacy baseline)
    A fixed ``capacity x max_len`` batched grid; requests are inserted by
    functionally rewriting the whole tree (O(pool)) and every row
    physically reserves ``max_len`` cells whether used or not.  Kept as the
    baseline ``benchmarks/bench_paged.py`` measures against and as the
    layout for recurrent-state / sliding-window models.

Block-accounting invariant (property-tested in tests/test_paged.py):
``free_blocks + sum(blocks allocated to live rows) == num_blocks`` after
every admit/evict/preempt/ensure sequence — blocks can never leak.
"""

from __future__ import annotations

import functools
import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import quant
from repro.models import transformer as T


# ------------------------------------------------------------ dense layout --

def _row_set(pool_tree, row: int, one_tree):
    """Write a batch-1 cache into pool row `row`.  'scan' subtree leaves are
    (U, B, ...): batch axis 1; tail leaves are (B, ...): axis 0."""
    def go(pool_leaf, one_leaf, axis):
        idx = [slice(None)] * pool_leaf.ndim
        idx[axis] = row
        src_idx = [slice(None)] * one_leaf.ndim
        src_idx[axis] = 0
        return pool_leaf.at[tuple(idx)].set(one_leaf[tuple(src_idx)])

    out = {}
    for key, sub in pool_tree.items():
        axis = 1 if key == "scan" else 0
        out[key] = jax.tree.map(lambda p, o: go(p, o, axis), sub,
                                one_tree[key])
    return out


def _row_get(pool_tree, row: int):
    """Gather pool row `row` into a batch-1 cache (the inverse of
    ``_row_set``).  `row` is traced — one jitted trace serves every row.
    Used by the chunked-prefill append path: the engine runs a chunk's
    decode over the gathered row and scatters the result back."""
    def go(leaf, axis):
        idx = [slice(None)] * leaf.ndim
        idx[axis] = row
        return jnp.expand_dims(leaf[tuple(idx)], axis)

    out = {}
    for key, sub in pool_tree.items():
        axis = 1 if key == "scan" else 0
        out[key] = jax.tree.map(lambda l: go(l, axis), sub)
    return out


def _rows_invalidate(pool_tree, rows):
    """Mark attention slots of the given rows empty (seg=-1).  ``rows`` is
    a *traced* int array — one jitted trace serves every eviction batch;
    out-of-range entries (the fixed-width padding) are dropped by the
    scatter."""
    rows = jnp.asarray(rows)

    def fix(entry, stacked):
        if not (isinstance(entry, dict) and "seg" in entry):
            return entry
        out = dict(entry)
        if stacked:
            out["seg"] = entry["seg"].at[:, rows].set(-1)
        else:
            out["seg"] = entry["seg"].at[rows].set(-1)
        return out

    out = {}
    for key, sub in pool_tree.items():
        if key == "scan":
            out[key] = {k: fix(v, True) for k, v in sub.items()}
        else:
            out[key] = fix(sub, False)
    return out


class DenseCachePool:
    """Static (capacity, max_len) batched cache with request->row slots."""

    def __init__(self, cfg, capacity: int, max_len: int):
        self.cfg = cfg
        self.capacity = capacity
        self.max_len = max_len
        self.cache = T.init_cache(cfg, capacity, max_len)
        self.lengths = np.zeros(capacity, np.int64)
        self.last_token = np.zeros(capacity, np.int64)
        self.row_of: Dict[int, int] = {}
        self._free = list(range(capacity))
        self._row_set = jax.jit(_row_set)   # row is traced: no per-row retrace
        self._row_gather = jax.jit(_row_get)
        self._rows_inval = jax.jit(_rows_invalidate)

    def has(self, rid: int) -> bool:
        return rid in self.row_of

    @property
    def free_rows(self) -> int:
        return len(self._free)

    def can_admit(self, length: int) -> bool:
        return bool(self._free)

    def insert(self, rid: int, one_cache, length: int, last_token: int):
        row = self._free.pop()
        self.cache = self._row_set(self.cache, row, one_cache)
        self.row_of[rid] = row
        self.lengths[row] = length
        self.last_token[row] = last_token
        return row

    def insert_empty(self, rid: int) -> int:
        """Grant a row with no KV yet (chunked prefill: context arrives in
        append-chunk writes).  The row's slots are already seg-invalidated
        (fresh pool init / ``evict``), so nothing stale is attendable."""
        row = self._free.pop()
        self.row_of[rid] = row
        self.lengths[row] = 0
        self.last_token[row] = 0
        return row

    def row_cache(self, rid: int):
        """Batch-1 view of the request's row (gather, O(max_len))."""
        return self._row_gather(self.cache, self.row_of[rid])

    def write_row(self, rid: int, one_cache):
        """Scatter an updated batch-1 row back (append-chunk commit)."""
        self.cache = self._row_set(self.cache, self.row_of[rid], one_cache)

    def invalidate_rows(self, rows: List[int]):
        """Batched row invalidation: ONE jitted call for any number of rows
        (fixed width = capacity, padded with an out-of-range sentinel), so
        evicting k rows costs one tree update instead of k retraced ones."""
        if not rows:
            return
        arr = np.full(self.capacity, self.capacity, np.int32)
        arr[:len(rows)] = rows[:self.capacity]
        self.cache = self._rows_inval(self.cache, jnp.asarray(arr))

    def evict(self, rid: int):
        row = self.row_of.pop(rid)
        self.invalidate_rows([row])
        self.lengths[row] = 0
        self._free.append(row)

    def rows(self, rids) -> np.ndarray:
        return np.array([self.row_of[r] for r in rids], np.int32)


# backward-compat name (PR1 engine/docs referred to the dense pool as
# CachePool); the engine now picks the layout explicitly.
CachePool = DenseCachePool


# ------------------------------------------------------------ paged layout --

def _src_seq_len(one_cache) -> int:
    """Sequence capacity of a batch-1 dense cache (pool_dims sees it as
    one 'block' of that many slots)."""
    from repro.serving.paged import pool_dims
    return pool_dims(one_cache)[1]


def _map_attn_entries(pool_tree, fn):
    out = {}
    for key, sub in pool_tree.items():
        if key == "scan":
            out[key] = {k: fn(v, True, k) for k, v in sub.items()}
        else:
            out[key] = fn(sub, False, key)
    return out


def _blocks_write(pool_tree, one_tree, ids, *, nb: int, bs: int):
    """Scatter the first ``nb`` blocks of a batch-1 dense cache into the
    pool blocks ``ids`` (traced; out-of-range entries dropped).  Cost is
    O(nb * bs) regardless of pool size.  Quantized pools (entries carry
    ``k_scale``/``v_scale`` sidecars — kernels/quant.py) quantize K/V
    on the way in and scatter the scales into the same blocks."""
    def go(entry, stacked, name):
        src_e = one_tree["scan"][name] if stacked else one_tree[name]
        quantized = "k_scale" in entry

        def blocks(o):
            if stacked:                  # o: (U,1,S,...) -> (U,nb,bs,...)
                src = o[:, 0, :nb * bs]
                return src.reshape(src.shape[0], nb, bs, *src.shape[2:])
            return o[0, :nb * bs].reshape(nb, bs, *o.shape[2:])

        def put(p, src):
            if stacked:                  # p: (U,N,bs,...)
                return p.at[:, ids].set(src.astype(p.dtype))
            return p.at[ids].set(src.astype(p.dtype))

        out = dict(entry)
        for leaf in ("k", "v", "pos", "seg"):
            src = blocks(src_e[leaf])
            if quantized and leaf in ("k", "v"):
                q, sc = quant.quantize(src, entry[leaf].dtype)
                out[leaf] = put(entry[leaf], q)
                out[leaf + "_scale"] = put(entry[leaf + "_scale"], sc)
            else:
                out[leaf] = put(entry[leaf], src)
        return out
    return _map_attn_entries(pool_tree, go)


def _blocks_invalidate(pool_tree, ids):
    """seg = -1 over whole physical blocks (freshly re-allocated blocks may
    hold a prior owner's slots — they must never be attendable)."""
    def go(entry, stacked, name):
        out = dict(entry)
        if stacked:
            out["seg"] = entry["seg"].at[:, ids].set(-1)
        else:
            out["seg"] = entry["seg"].at[ids].set(-1)
        return out
    return _map_attn_entries(pool_tree, go)


def _blocks_copy(pool_tree, src, dst):
    """Copy whole physical blocks ``src[i] -> dst[i]`` (ALL leaves — K/V,
    pos/seg, and any quantization scale sidecars — all slots) — the
    copy-on-write materialisation.  Traced id vectors; padding entries
    carry an out-of-range dst and are dropped by the scatter (their src
    is clamped to a valid block by the gather)."""
    def go(entry, stacked, name):
        out = {}
        for leaf, p in entry.items():
            if stacked:
                out[leaf] = p.at[:, dst].set(p[:, src])
            else:
                out[leaf] = p.at[dst].set(p[src])
        return out
    return _map_attn_entries(pool_tree, go)


def _span_invalidate(pool_tree, table, new_lengths, upper, *, bs: int,
                     W: int, num_blocks: int):
    """Per-row seg=-1 for positions [new_lengths, upper) — the rejected-
    draft rollback.  W is the static span bound (gamma or gamma+1); rows
    whose table has no block there (idle rows) resolve out-of-range and the
    scatter drops them.  Trims the tail block in place: O(rows * W)."""
    cap, nbmax = table.shape
    p = new_lengths[:, None] + jnp.arange(W, dtype=jnp.int32)   # (cap, W)
    lb = p // bs
    phys = jnp.take_along_axis(table, jnp.clip(lb, 0, nbmax - 1), axis=1)
    ok = (p < upper[:, None]) & (lb < nbmax) & (phys >= 0)
    flat = jnp.where(ok, phys * bs + p % bs, num_blocks * bs).reshape(-1)

    def go(entry, stacked, name):
        seg = entry["seg"]
        out = dict(entry)
        if stacked:
            U = seg.shape[0]
            out["seg"] = seg.reshape(U, -1).at[:, flat].set(-1) \
                            .reshape(seg.shape)
        else:
            out["seg"] = seg.reshape(-1).at[flat].set(-1).reshape(seg.shape)
        return out
    return _map_attn_entries(pool_tree, go)


def _pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


class PagedCachePool:
    """Block-table paged KV pool (module docstring has the full contract)."""

    def __init__(self, cfg, capacity: int, max_len: int,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 kv_dtype: str = "bf16"):
        bs = int(block_size)
        if bs <= 0:
            raise ValueError("block_size must be positive")
        quant.storage_dtype(kv_dtype)                # validate the name
        self.cfg = cfg
        self.capacity = capacity
        self.block_size = bs
        self.kv_dtype = kv_dtype
        self.blocks_per_row = max(1, math.ceil(max_len / bs))
        self.max_len = self.blocks_per_row * bs      # block-aligned
        if num_blocks is None:
            num_blocks = capacity * self.blocks_per_row
        # floor: one full row must always fit (empty-pool admission of an
        # oversized request is unconditional — no deadlock)
        self.num_blocks = max(int(num_blocks), self.blocks_per_row)
        self.cache = T.init_paged_cache(cfg, self.num_blocks, bs,
                                        kv_dtype=kv_dtype)
        self.lengths = np.zeros(capacity, np.int64)
        self.last_token = np.zeros(capacity, np.int64)
        self.row_of: Dict[int, int] = {}
        self._free_rows = list(range(capacity))
        self._free_blocks = list(range(self.num_blocks))
        self._table = np.full((capacity, self.blocks_per_row), -1, np.int32)
        self._nb = np.zeros(capacity, np.int32)      # allocated blocks/row
        self._ref = np.zeros(self.num_blocks, np.int32)  # CoW refcounts
        self._jit: Dict[tuple, object] = {}          # (kind, statics) -> fn

    # --------------------------------------------------------- accounting --
    def has(self, rid: int) -> bool:
        return rid in self.row_of

    @property
    def free_rows(self) -> int:
        return len(self._free_rows)

    @property
    def free_blocks(self) -> int:
        return len(self._free_blocks)

    @property
    def allocated_blocks(self) -> int:
        # UNIQUE live blocks (a CoW-shared block counts once) so that
        # ``free_blocks + allocated_blocks == num_blocks`` stays an
        # identity under forking; fork-free this equals ``_nb.sum()``.
        return int(np.count_nonzero(self._ref))

    def ref_count(self, rid: int, block_index: int) -> int:
        """Refcount of the row's ``block_index``-th block (CoW probes)."""
        return int(self._ref[int(self._table[self.row_of[rid], block_index])])

    def shared_span(self, rid: int, start: int, end: int) -> bool:
        """True iff any block covering cells [start, end) is CoW-shared."""
        row = self.row_of[rid]
        bs = self.block_size
        lo = max(0, int(start)) // bs
        hi = min(int(self._nb[row]), math.ceil(max(int(end), 0) / bs))
        return any(self._ref[int(self._table[row, bi])] > 1
                   for bi in range(lo, hi))

    def bytes_per_block(self) -> int:
        """Physical bytes one block occupies across every layer's entry —
        K/V at the storage dtype, pos/seg, and quantization scale
        sidecars when present.  The currency for fixed-byte-budget
        comparisons across ``kv_dtype`` settings (benchmarks/
        bench_quant.py): at the same byte budget an int8 pool affords
        roughly 2x the blocks of a bf16 one (4x vs float32)."""
        total = sum(leaf.size * leaf.dtype.itemsize
                    for leaf in jax.tree.leaves(self.cache))
        return total // self.num_blocks

    def bytes_per_token(self) -> int:
        """Physical bytes of KV state per cached token (all layers)."""
        return self.bytes_per_block() // self.block_size

    def blocks_needed(self, length: int) -> int:
        return min(self.blocks_per_row,
                   max(1, math.ceil(max(int(length), 1) / self.block_size)))

    def can_admit(self, length: int) -> bool:
        return (bool(self._free_rows)
                and len(self._free_blocks) >= self.blocks_needed(length))

    def allocated_cells(self, rid: int) -> int:
        return int(self._nb[self.row_of[rid]]) * self.block_size

    def prefill_len(self, src_len: int) -> int:
        """Block-aligned cache length the engine should prefill with before
        ``insert`` — covers the (16-aligned) token row buffer so nothing is
        clamped, while keeping admission O(prompt blocks)."""
        bs = self.block_size
        return math.ceil(src_len / bs) * bs

    def rows(self, rids) -> np.ndarray:
        return np.array([self.row_of[r] for r in rids], np.int32)

    # ---------------------------------------------------------- lifecycle --
    def _fn(self, kind: str, **statics):
        key = (kind,) + tuple(sorted(statics.items()))
        if key not in self._jit:
            base = {"write": _blocks_write, "inval": _blocks_invalidate,
                    "span": _span_invalidate, "copy": _blocks_copy}[kind]
            fn = functools.partial(base, **statics) if statics else base
            # donate the pool tree: the scatter updates the block pool IN
            # PLACE instead of copying it — this is what makes admission
            # O(prompt blocks) instead of O(pool).  Callers always
            # reassign self.cache from the result, so the consumed buffer
            # is never reused.
            self._jit[key] = jax.jit(fn, donate_argnums=0)
        return self._jit[key]

    def _alloc(self, n: int) -> List[int]:
        if n > len(self._free_blocks):
            raise RuntimeError(
                f"paged pool out of blocks: need {n}, "
                f"free {len(self._free_blocks)}/{self.num_blocks} — the "
                f"scheduler's block accounting should have preempted first")
        ids = [self._free_blocks.pop() for _ in range(n)]
        for b in ids:
            self._ref[b] = 1
        return ids

    def insert(self, rid: int, one_cache, length: int, last_token: int):
        """Admit a prefilled batch-1 cache: allocate the prompt's blocks and
        scatter K/V into exactly those — O(prompt blocks), not O(pool)."""
        nb = self.blocks_needed(length)
        S = _src_seq_len(one_cache)
        if S < nb * self.block_size:
            raise ValueError(
                f"prefilled cache covers {S} slots < {nb} blocks x "
                f"{self.block_size}; prefill with max_len=pool.prefill_len()")
        ids = self._alloc(nb)
        self.cache = self._fn("write", nb=nb, bs=self.block_size)(
            self.cache, one_cache, jnp.asarray(ids, jnp.int32))
        row = self._free_rows.pop()
        self.row_of[rid] = row
        self._table[row, :nb] = ids
        self._nb[row] = nb
        self.lengths[row] = length
        self.last_token[row] = last_token
        return row

    def insert_empty(self, rid: int) -> int:
        """Grant a row that owns no blocks yet (chunked prefill: blocks are
        allocated chunk-by-chunk via ``ensure`` as context is appended)."""
        row = self._free_rows.pop()
        self.row_of[rid] = row
        self._nb[row] = 0
        self.lengths[row] = 0
        self.last_token[row] = 0
        return row

    def row_table(self, rid: int) -> jnp.ndarray:
        """(1, nb) block table of one row, power-of-two bucketed, for
        append-chunk writes through the paged decode override — chunk
        queries attend exactly this row's live blocks."""
        row = self.row_of[rid]
        nb = min(self.blocks_per_row, _pow2(max(1, int(self._nb[row]))))
        return jnp.asarray(self._table[row:row + 1, :nb])

    def ensure(self, rid: int, need_len: int):
        """Append blocks until the row covers ``need_len`` cells (the
        decode-growth path: usually one block, amortized zero)."""
        self.ensure_rows({rid: need_len})

    def ensure_rows(self, needs: Dict[int, int]):
        """Batched growth for one slot: allocate every row's missing
        blocks, then seg-invalidate all of them in ONE jitted call
        (re-allocated blocks may hold a prior owner's slots) — one
        dispatch per pool per slot, not one per grown row."""
        deltas = {}
        for rid, need_len in needs.items():
            row = self.row_of[rid]
            need = self.blocks_needed(need_len)
            if need > int(self._nb[row]):
                deltas[rid] = need
        total = sum(need - int(self._nb[self.row_of[rid]])
                    for rid, need in deltas.items())
        if not total:
            return
        if total > len(self._free_blocks):     # check before mutating
            raise RuntimeError(
                f"paged pool out of blocks: need {total}, "
                f"free {len(self._free_blocks)}/{self.num_blocks} — the "
                f"scheduler's block accounting should have preempted first")
        new_ids: List[int] = []
        for rid, need in deltas.items():
            row = self.row_of[rid]
            have = int(self._nb[row])
            ids = self._alloc(need - have)
            self._table[row, have:need] = ids
            self._nb[row] = need
            new_ids.extend(ids)
        m = _pow2(len(new_ids))               # bucket: bounded retraces
        arr = np.full(m, self.num_blocks, np.int32)
        arr[:len(new_ids)] = new_ids
        self.cache = self._fn("inval")(self.cache, jnp.asarray(arr))

    def fork(self, rid: int, new_rid: int) -> int:
        """Copy-on-write fork: grant ``new_rid`` a row whose block table
        ALIASES every block of ``rid`` — refcounts are bumped, no cache
        traffic moves.  Writes into the shared span must be preceded by
        ``cow_prepare`` (the write paths stay oblivious to sharing)."""
        if new_rid in self.row_of:
            raise ValueError(f"fork target rid {new_rid} already live")
        if not self._free_rows:
            raise RuntimeError("paged pool out of rows for fork")
        src = self.row_of[rid]
        row = self._free_rows.pop()
        nb = int(self._nb[src])
        self._table[row, :nb] = self._table[src, :nb]
        self._nb[row] = nb
        self.lengths[row] = self.lengths[src]
        self.last_token[row] = self.last_token[src]
        self.row_of[new_rid] = row
        for b in self._table[src, :nb]:
            self._ref[int(b)] += 1
        return row

    def cow_prepare(self, rid: int, start: int, end: int) -> int:
        """Make the blocks covering cells [start, end) exclusive to
        ``rid``: every CoW-shared block (ref > 1) in the span is copied
        into a freshly allocated block (one jitted whole-block copy for
        the batch), the row's table repointed, and the original's
        refcount dropped.  Returns the number of blocks copied."""
        row = self.row_of[rid]
        bs = self.block_size
        lo = max(0, int(start)) // bs
        hi = min(int(self._nb[row]), math.ceil(max(int(end), 0) / bs))
        src: List[int] = []
        dst: List[int] = []
        for bi in range(lo, hi):
            blk = int(self._table[row, bi])
            if self._ref[blk] > 1:
                new = self._alloc(1)[0]
                self._ref[blk] -= 1       # ref > 1, so never frees here
                self._table[row, bi] = new
                src.append(blk)
                dst.append(new)
        if src:
            m = _pow2(len(src))           # bucket: bounded retraces
            s = np.zeros(m, np.int32)
            d = np.full(m, self.num_blocks, np.int32)
            s[:len(src)] = src
            d[:len(dst)] = dst
            self.cache = self._fn("copy")(
                self.cache, jnp.asarray(s), jnp.asarray(d))
        return len(src)

    def rename(self, rid: int, new_rid: int):
        """Re-key a live row (winner-branch adoption after tree verify:
        the surviving fork takes over the original request id)."""
        if new_rid in self.row_of:
            raise ValueError(f"rename target rid {new_rid} already live")
        self.row_of[new_rid] = self.row_of.pop(rid)

    def evict(self, rid: int):
        """Free the row and drop one reference per block; blocks return
        to the free list only at refcount zero (CoW siblings keep shared
        blocks alive) — O(row blocks), no cache traffic (stale blocks are
        unreachable without a table entry and re-invalidated on
        re-allocation)."""
        row = self.row_of.pop(rid)
        nb = int(self._nb[row])
        for b in self._table[row, :nb]:
            b = int(b)
            self._ref[b] -= 1
            if self._ref[b] == 0:
                self._free_blocks.append(b)
        self._table[row, :nb] = -1
        self._nb[row] = 0
        self.lengths[row] = 0
        self._free_rows.append(row)

    def invalidate_span(self, new_lengths, upper, W: int):
        """Rollback rejected drafts: seg=-1 for positions
        [new_lengths, upper) per row (W = static span bound)."""
        table = jnp.asarray(self._table)
        self.cache = self._fn("span", bs=self.block_size, W=int(W),
                              num_blocks=self.num_blocks)(
            self.cache, table, jnp.asarray(new_lengths, jnp.int32),
            jnp.asarray(upper, jnp.int32))

    # ------------------------------------------------------------- views --
    def block_table_array(self) -> Tuple[jnp.ndarray, int]:
        """(capacity, nb_max) device block table, nb_max bucketed to the
        next power of two of the longest row's allocation — attention
        gathers scale with live context, and retraces stay O(log)."""
        nb_max = min(self.blocks_per_row,
                     _pow2(int(self._nb.max()) if len(self._nb) else 1))
        return jnp.asarray(self._table[:, :nb_max]), nb_max

    def live_blocks(self) -> Tuple[np.ndarray, np.ndarray]:
        """(block_ids, owner_rows) over all live rows, padded to a power-of
        -two length with (0, -1) entries (owner -1 = skip).  CoW-shared
        blocks are listed ONCE, under the first row encountered — listing
        a physical block twice would double its slots in the packed
        softmax denominator.  (Forks only share within one request, and
        all of a request's rows map to the same verify segment, so the
        first-seen owner is always segment-correct.)"""
        ids: List[int] = []
        owner: List[int] = []
        seen = set()
        for rid, row in self.row_of.items():
            nb = int(self._nb[row])
            for b in self._table[row, :nb]:
                b = int(b)
                if b in seen:
                    continue
                seen.add(b)
                ids.append(b)
                owner.append(row)
        m = _pow2(max(1, len(ids)))
        ids += [0] * (m - len(ids))
        owner += [-1] * (m - len(owner))
        return (np.asarray(ids, np.int32), np.asarray(owner, np.int32))
