"""Per-model KV-cache pools with request->row slot maps.

A pool owns a fixed-capacity batched cache (static shapes: jit-friendly,
TPU-friendly) for one model instance.  Requests are inserted by prefilling
a single row and scattering it into the pool; rows of finished/absent
requests are invalidated so stale K/V can never be attended.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T


def _row_set(pool_tree, row: int, one_tree):
    """Write a batch-1 cache into pool row `row`.  'scan' subtree leaves are
    (U, B, ...): batch axis 1; tail leaves are (B, ...): axis 0."""
    def go(pool_leaf, one_leaf, axis):
        idx = [slice(None)] * pool_leaf.ndim
        idx[axis] = row
        src_idx = [slice(None)] * one_leaf.ndim
        src_idx[axis] = 0
        return pool_leaf.at[tuple(idx)].set(one_leaf[tuple(src_idx)])

    out = {}
    for key, sub in pool_tree.items():
        axis = 1 if key == "scan" else 0
        out[key] = jax.tree.map(lambda p, o: go(p, o, axis), sub,
                                one_tree[key])
    return out


def _rows_invalidate(pool_tree, rows: List[int]):
    """Mark attention slots of given rows empty (seg=-1)."""
    if not rows:
        return pool_tree
    rows = jnp.asarray(rows)

    def fix(entry, stacked):
        if not (isinstance(entry, dict) and "seg" in entry):
            return entry
        out = dict(entry)
        if stacked:
            out["seg"] = entry["seg"].at[:, rows].set(-1)
        else:
            out["seg"] = entry["seg"].at[rows].set(-1)
        return out

    out = {}
    for key, sub in pool_tree.items():
        if key == "scan":
            out[key] = {k: fix(v, True) for k, v in sub.items()}
        else:
            out[key] = fix(sub, False)
    return out


class CachePool:
    def __init__(self, cfg, capacity: int, max_len: int):
        self.cfg = cfg
        self.capacity = capacity
        self.max_len = max_len
        self.cache = T.init_cache(cfg, capacity, max_len)
        self.lengths = np.zeros(capacity, np.int64)
        self.last_token = np.zeros(capacity, np.int64)
        self.row_of: Dict[int, int] = {}
        self._free = list(range(capacity))
        self._row_set = jax.jit(_row_set)   # row is traced: no per-row retrace

    def has(self, rid: int) -> bool:
        return rid in self.row_of

    @property
    def free_rows(self) -> int:
        return len(self._free)

    def insert(self, rid: int, one_cache, length: int, last_token: int):
        row = self._free.pop()
        self.cache = self._row_set(self.cache, row, one_cache)
        self.row_of[rid] = row
        self.lengths[row] = length
        self.last_token[row] = last_token
        return row

    def evict(self, rid: int):
        row = self.row_of.pop(rid)
        self.cache = _rows_invalidate(self.cache, [row])
        self.lengths[row] = 0
        self._free.append(row)

    def rows(self, rids) -> np.ndarray:
        return np.array([self.row_of[r] for r in rids], np.int32)
