from repro.serving.engine import EngineConfig, SpinEngine
from repro.serving.pool import DenseCachePool, PagedCachePool
from repro.serving.router import Router, RouterConfig
from repro.serving.scheduler import (ContinuousScheduler, Decision,
                                     SchedulerConfig)

__all__ = ["EngineConfig", "SpinEngine", "ContinuousScheduler",
           "Decision", "SchedulerConfig", "DenseCachePool",
           "PagedCachePool", "Router", "RouterConfig"]
