from repro.serving.engine import EngineConfig, SpinEngine

__all__ = ["EngineConfig", "SpinEngine"]
