"""Paged-KV attention plumbing for the serving engine (XLA path).

The paged ``CachePool`` stores each model's KV in a block pool
``(num_blocks, block_size, Kh, D)``; requests own ordered lists of physical
blocks (block tables).  The model forward never sees a dense
``(rows, max_len)`` grid: the override closures below route every attention
layer through the block table —

* **write**: new K/V is scattered straight into the owning request's tail
  block(s) (``flat = table[row, pos // bs] * bs + pos % bs``); rows without
  an allocated block (idle pool rows, padding) map to an out-of-range index
  and the scatter drops them;
* **read**: only *live* blocks are gathered — ``(B, nb_max * bs)`` for
  decode (nb_max = live blocks of the longest row, bucketed) and
  ``(M * bs,)`` for packed verification (M = live blocks of the verified
  cohort) — so per-step HBM traffic tracks the live context, not the pool
  capacity and not ``max_len``.

These mirror the Pallas kernels in ``kernels/paged_attention.py`` (the TPU
hot path, validated against the same oracles); like the rest of the model
stack, the engine's functional path uses the XLA formulation so results are
identical on any backend.

Entry points ``decode_step_paged`` / ``verify_step_paged`` wrap
``models.transformer`` with the right override; ``core.spec_decode.Bundle``
jits them per model (block tables are *traced* arguments, so a step never
retraces when the tables' contents change).

Invariants this plumbing relies on (owned by ``serving/pool.py``,
previously stated only in PR descriptions):

* **Block ownership** — ``row_of`` is a bijection from live request ids
  to pool rows, and each physical block belongs to at most one row's
  table; ``free_blocks + Σ allocated == num_blocks`` after any
  admit/evict/grow sequence (property-tested).  Rows not in ``row_of``
  own no blocks, which is what makes static-shape writes safe: their
  positions resolve out of range and the scatter drops them.
* **Attendability** — a KV slot is readable only when its block is in a
  live table AND its ``seg >= 0``; freshly allocated blocks are
  seg-invalidated so a previous owner's data can never be attended.
* **Speculation margin** — before decode/verify writes land, each
  participating row's table covers ``ctx + k_i + 1`` cells (granted
  depth + bonus token; draft pools add one more for the catch-up hole),
  and rollback scrubs ``[ctx + 1 + n_acc, ctx + W + 1)`` so rejected
  drafts are never attendable afterwards.
* **Budget unit** — the pool holds ``kv_budget // block_size`` physical
  blocks (plus the one-full-row deadlock-freedom floor); the scheduler
  accounts demand in block-rounded cells, so "budget exceeded" and
  "allocation fails" are the same event, not two models of it.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.kernels import quant
from repro.models import config as C
from repro.models import transformer as T
from repro.models.layers import attention


def pool_dims(cache) -> Tuple[int, int]:
    """(num_blocks, block_size) of a paged cache tree."""
    for name, entry in cache.get("scan", {}).items():
        if isinstance(entry, dict) and "k" in entry:
            return entry["k"].shape[1], entry["k"].shape[2]
    for name, entry in cache.items():
        if isinstance(entry, dict) and "k" in entry:
            return entry["k"].shape[0], entry["k"].shape[1]
    raise ValueError("cache tree has no attention entries")


def _flat_write_idx(block_tables, positions, bs: int, oob: int):
    """Flat pool slot per (row, position); ``oob`` for unmapped positions
    (idle row / position beyond the row's allocated blocks) — the scatter
    drops those updates."""
    nb = block_tables.shape[1]
    lb = positions // bs
    phys = jnp.take_along_axis(
        block_tables, jnp.clip(lb, 0, nb - 1), axis=1)
    ok = (positions >= 0) & (lb < nb) & (phys >= 0)
    return jnp.where(ok, phys * bs + positions % bs, oob)


def _write_kv(kv_cache, widx_flat, k_new, v_new, positions, segments,
              num_blocks: int, bs: int):
    """Scatter new K/V (+pos/seg) into the flattened pool; returns the
    updated (num_blocks, bs, ...) tree.  O(new tokens), not O(pool).

    Quantized pools (``k_scale``/``v_scale`` sidecar leaves present —
    kernels/quant.py) quantize each new token's K/V per (slot, head) on
    the way in and scatter the scales into the same flat slots, so the
    write stays one pass and no dequantized pool copy ever exists."""
    Kh, hd = kv_cache["k"].shape[-2:]
    quantized = "k_scale" in kv_cache
    out = dict(kv_cache)
    for leaf, new in (("k", k_new), ("v", v_new)):
        src = new.reshape(-1, Kh, hd)
        pool = kv_cache[leaf]
        if quantized:
            src, scales = quant.quantize(src, pool.dtype)
            sp = kv_cache[leaf + "_scale"]
            out[leaf + "_scale"] = sp.reshape(num_blocks * bs, Kh) \
                .at[widx_flat].set(scales) \
                .reshape(num_blocks, bs, Kh)
        out[leaf] = pool.reshape(num_blocks * bs, Kh, hd) \
            .at[widx_flat].set(src.astype(pool.dtype)) \
            .reshape(num_blocks, bs, Kh, hd)
    out["pos"] = kv_cache["pos"].reshape(-1).at[widx_flat].set(
        positions.reshape(-1)).reshape(num_blocks, bs)
    out["seg"] = kv_cache["seg"].reshape(-1).at[widx_flat].set(
        segments.reshape(-1)).reshape(num_blocks, bs)
    return out


def _gather_dequant(new_cache, leaf, slot, num_blocks: int, bs: int, shape,
                    dtype):
    """Gather pool slots ``slot`` of ``leaf`` ('k'/'v') and, on a
    quantized pool, dequantize post-gather (the XLA fallback path — the
    Pallas kernels dequantize in-kernel instead)."""
    flat = new_cache[leaf].reshape(num_blocks * bs, *shape)
    g = flat[slot]
    if leaf + "_scale" not in new_cache:
        return g
    sc = new_cache[leaf + "_scale"].reshape(num_blocks * bs, shape[0])[slot]
    return quant.dequantize(g, sc, dtype)


def make_paged_decode_override(block_tables, num_blocks: int, bs: int):
    """Attention override for decode/draft/verify-padded over a paged pool.

    block_tables: (B, nb_max) int32, -1 = unallocated.  Queries of row b
    attend the gathered view of row b's blocks (write-then-read, so the new
    tokens attend each other causally like the dense path).
    """
    bt = block_tables.astype(jnp.int32)

    def override(q, k_new, v_new, positions, segments, kv_cache, cfg, opts):
        B, Tn = positions.shape
        widx = _flat_write_idx(bt, positions, bs, num_blocks * bs)
        new_cache = _write_kv(kv_cache, widx.reshape(-1), k_new, v_new,
                              positions, segments, num_blocks, bs)
        # gather each row's live blocks into a (B, nb_max*bs) view;
        # quantized pools dequantize the gathered slots (XLA fallback)
        nb_max = bt.shape[1]
        slot = (jnp.maximum(bt, 0) * bs)[:, :, None] + jnp.arange(bs)
        slot = slot.reshape(B, nb_max * bs)
        kg = _gather_dequant(new_cache, "k", slot, num_blocks, bs,
                             k_new.shape[2:], k_new.dtype)
        vg = _gather_dequant(new_cache, "v", slot, num_blocks, bs,
                             v_new.shape[2:], v_new.dtype)
        posg = new_cache["pos"].reshape(-1)[slot]
        segg = new_cache["seg"].reshape(-1)[slot]
        live = jnp.repeat(bt >= 0, bs, axis=1)
        segg = jnp.where(live, segg, -1)
        o = attention(q, kg, vg, q_positions=positions, kv_positions=posg,
                      q_segments=segments, kv_segments=segg,
                      window=cfg.sliding_window, q_block=opts.q_block)
        return o, new_cache

    return override


def make_fused_decode_override(block_tables, num_blocks: int, bs: int,
                               fused_cfg):
    """Fused-kernel variant of :func:`make_paged_decode_override`: the
    write scatter is unchanged (O(new tokens)), but the read side is ONE
    ``kernels/fused_decode.fused_paged_decode`` launch streaming the
    row's blocks straight from the pool — the ``(B, nb_max * bs)``
    gathered view is never materialized.  ``fused_cfg`` is the
    ``kernels/autotune.FusedConfig`` pinning the tile shapes (resolved by
    the engine at construction; static under jit)."""
    from repro.kernels import ops
    bt = block_tables.astype(jnp.int32)

    def override(q, k_new, v_new, positions, segments, kv_cache, cfg, opts):
        widx = _flat_write_idx(bt, positions, bs, num_blocks * bs)
        new_cache = _write_kv(kv_cache, widx.reshape(-1), k_new, v_new,
                              positions, segments, num_blocks, bs)
        o = ops.fused_paged_decode(
            q, new_cache["k"], new_cache["v"], new_cache["seg"],
            new_cache["pos"], segments, positions, bt,
            k_scale=new_cache.get("k_scale"),
            v_scale=new_cache.get("v_scale"), config=fused_cfg)
        return o.astype(q.dtype), new_cache

    return override


def make_paged_verify_override(q_rows, block_tables, block_ids, block_owner,
                               num_blocks: int, bs: int,
                               q_anc=None, block_node=None):
    """Attention override for SPIN packed verification over a paged pool.

    q_rows: (Tq,) pool row per flattened query token; block_ids /
    block_owner: (M,) live physical blocks of the verified cohort and the
    row owning each (-1 owner = padding entry).  The packed KV is gathered
    fragment-by-fragment — no flat packed copy, no padded grid.

    Optional tree-speculation topology: ``q_anc`` (Tq,) is the per-query
    ancestor bitmask and ``block_node`` (M, bs) tags each gathered slot
    with its tree-node id (-1 committed, -2 dead, n >= 0 tree node); both
    omitted reduces to the linear Eq. 13 mask exactly.
    """
    q_rows = jnp.asarray(q_rows, jnp.int32)
    bt = block_tables.astype(jnp.int32)
    ids = jnp.maximum(jnp.asarray(block_ids, jnp.int32), 0)
    owner = jnp.asarray(block_owner, jnp.int32)
    M = ids.shape[0]
    anc = None if q_anc is None else \
        jnp.asarray(q_anc, jnp.int32).reshape(1, -1)
    node = None if block_node is None else \
        jnp.asarray(block_node, jnp.int32).reshape(1, M * bs)

    def override(q, k_new, v_new, positions, segments, kv_cache, cfg, opts):
        # q/k_new/v_new: (1, Tq, ·, hd); positions/segments: (1, Tq) with
        # segments = owning row (Eq. 13 segment ids)
        pos = positions[0]
        nb = bt.shape[1]
        lb = pos // bs
        phys = bt[q_rows, jnp.clip(lb, 0, nb - 1)]        # (Tq,)
        ok = (pos >= 0) & (lb < nb) & (phys >= 0)
        widx = jnp.where(ok, phys * bs + pos % bs, num_blocks * bs)
        # pool slots store seg=0 (valid), mirroring the dense cache
        new_cache = _write_kv(kv_cache, widx.reshape(-1), k_new, v_new,
                              positions, jnp.zeros_like(segments),
                              num_blocks, bs)
        slot = ((ids * bs)[:, None] + jnp.arange(bs)).reshape(M * bs)
        kg = _gather_dequant(new_cache, "k", slot, num_blocks, bs,
                             k_new.shape[2:], k_new.dtype)[None]
        vg = _gather_dequant(new_cache, "v", slot, num_blocks, bs,
                             v_new.shape[2:], v_new.dtype)[None]
        posg = new_cache["pos"].reshape(-1)[slot][None]
        slot_seg = new_cache["seg"].reshape(-1)[slot]
        segg = jnp.where((slot_seg >= 0) & (jnp.repeat(owner, bs) >= 0),
                         jnp.repeat(owner, bs), -1)[None]
        o = attention(q, kg, vg, q_positions=positions, kv_positions=posg,
                      q_segments=segments, kv_segments=segg,
                      window=cfg.sliding_window, q_block=opts.q_block,
                      q_anc=anc, kv_node=node)
        return o, new_cache

    return override


def make_fused_verify_override(q_rows, block_tables, block_ids, block_owner,
                               num_blocks: int, bs: int,
                               q_anc=None, block_node=None, fused_cfg=None):
    """Fused-kernel variant of :func:`make_paged_verify_override`: one
    ``kernels/fused_verify.fused_paged_verify`` launch replaces the
    ``(M * bs,)`` fragment gather + packed attention pair, for linear and
    tree shapes alike (``q_anc``/``block_node`` thread straight into the
    kernel's inline mask)."""
    from repro.kernels import ops
    q_rows = jnp.asarray(q_rows, jnp.int32)
    bt = block_tables.astype(jnp.int32)
    ids = jnp.asarray(block_ids, jnp.int32)
    owner = jnp.asarray(block_owner, jnp.int32)
    anc = None if q_anc is None else jnp.asarray(q_anc, jnp.int32)
    node = None if block_node is None else jnp.asarray(block_node, jnp.int32)

    def override(q, k_new, v_new, positions, segments, kv_cache, cfg, opts):
        pos = positions[0]
        nb = bt.shape[1]
        lb = pos // bs
        phys = bt[q_rows, jnp.clip(lb, 0, nb - 1)]        # (Tq,)
        ok = (pos >= 0) & (lb < nb) & (phys >= 0)
        widx = jnp.where(ok, phys * bs + pos % bs, num_blocks * bs)
        new_cache = _write_kv(kv_cache, widx.reshape(-1), k_new, v_new,
                              positions, jnp.zeros_like(segments),
                              num_blocks, bs)
        o = ops.fused_paged_verify(
            q[0], new_cache["k"], new_cache["v"], new_cache["seg"],
            new_cache["pos"], segments[0], pos, ids, owner, anc, node,
            k_scale=new_cache.get("k_scale"),
            v_scale=new_cache.get("v_scale"), config=fused_cfg)
        return o[None].astype(q.dtype), new_cache

    return override


# ------------------------------------------------------- model entrypoints --

def decode_step_paged(params, cfg, cache, *, tokens, lengths, block_tables,
                      segments=None, fused_cfg=None,
                      opts: T.Opts = T.Opts()):
    """Paged analogue of ``transformer.decode_step``: T new tokens per row,
    K/V written to / read from the rows' block tables.

    ``segments`` (optional, (B, T)) marks padding query tokens with -1:
    their KV writes land seg-invalidated (never attendable) and their
    outputs are masked garbage the caller ignores.  This is how **chunked
    prefill** appends a prompt chunk into an existing block table — a
    (1, chunk) call whose queries attend the row's prior context blocks
    plus themselves causally.  It is the same query-segment-over-prefix
    shape as packed verification, so the TPU hot path reuses
    ``kernels.paged_attention.paged_verify_attention`` (q_pos = chunk
    positions, owner = the row's blocks) instead of a dedicated
    chunk-prefill kernel.

    ``fused_cfg`` (a ``kernels/autotune.FusedConfig``, static) routes the
    read side through the fused Pallas kernel instead of the XLA gather;
    None keeps the gather formulation (bit-identical legacy path)."""
    num_blocks, bs = pool_dims(cache)
    if fused_cfg is not None:
        override = make_fused_decode_override(block_tables, num_blocks, bs,
                                              fused_cfg)
    else:
        override = make_paged_decode_override(block_tables, num_blocks, bs)
    return T.decode_step(params, cfg, cache, tokens=tokens, lengths=lengths,
                         segments=segments, opts=opts, attn_override=override)


def verify_step_paged(params, cfg, cache, *, tokens, positions, segments,
                      q_rows, block_tables, block_ids, block_owner,
                      q_anc=None, block_node=None, fused_cfg=None,
                      opts: T.Opts = T.Opts()):
    """Paged analogue of ``transformer.verify_step_packed``; optional
    ``q_anc``/``block_node`` add the token-tree topology mask term.
    ``fused_cfg`` selects the single-launch fused verify kernel (see
    :func:`decode_step_paged`)."""
    num_blocks, bs = pool_dims(cache)
    if fused_cfg is not None:
        override = make_fused_verify_override(
            q_rows, block_tables, block_ids, block_owner, num_blocks, bs,
            q_anc=q_anc, block_node=block_node, fused_cfg=fused_cfg)
    else:
        override = make_paged_verify_override(
            q_rows, block_tables, block_ids, block_owner, num_blocks, bs,
            q_anc=q_anc, block_node=block_node)
    return T.verify_step_packed(params, cfg, cache, tokens=tokens,
                                positions=positions, segments=segments,
                                attn_override=override, opts=opts)


def paged_compatible(cfg) -> bool:
    """Paged layout supports attention-family blocks (KV grids) only;
    recurrent state (mamba2/xlstm) is O(1) per request and sliding-window
    ring buffers have their own layout — both stay on the dense pool."""
    kinds = set(cfg.unit) | set(cfg.tail)
    return (kinds <= {C.ATTN, C.MOE, C.SHARED_ATTN}
            and not cfg.sliding_window)
