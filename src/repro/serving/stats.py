"""Typed serving telemetry: the snapshot surface every layer exports.

Before this module each layer grew its own ad-hoc stats surface — the
engine exposed ``outstanding_tokens()`` / ``kv_free_cells()`` /
``kv_occupancy()`` methods, the scheduler ``queue_depth`` /
``outstanding_requests``, and the router glued them into loose
``replica_snapshot`` dicts whose keys nothing checked.  Routing policies
and benchmarks string-indexed those dicts, so a renamed key failed at
dispatch time, not import time.

Now each layer returns ONE frozen dataclass from a single ``snapshot()``
method:

* :class:`SchedulerStats` — ``ContinuousScheduler.snapshot()``: queue
  and lifecycle counters plus the most urgent outstanding deadline.
* :class:`EngineStats` — ``SpinEngine.snapshot()``: embeds the scheduler
  snapshot and adds the KV/token-load view plus the SLO headroom term.
* :class:`ReplicaStats` — ``Router.replica_snapshot()``: one per
  replica, the engine snapshot tagged with the replica index and its
  dispatch count.  Routing policies read these typed objects — the
  fields they compare are attributes, not string keys.

Frozen on purpose: a snapshot is a point-in-time reading, and policies
must never mutate shared telemetry.  ``asdict()`` is the JSON boundary
for ``stats()`` blobs and bench records.

This module also owns the **goodput-under-SLO** arithmetic: engines
stamp every committed token's sim-clock time onto
``Request.token_times``, and :func:`slo_summary` folds those against
each request's :class:`~repro.data.workloads.SLO` contract into the
headline serving metric — tokens that met their deadline per second,
the figure an operator with latency contracts actually buys.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable

from repro.data.workloads import Request


@dataclasses.dataclass(frozen=True)
class SchedulerStats:
    """One scheduler's live state: the queue/lifecycle view."""
    queue_depth: int           # waiting + not-yet-arrived pending
    waiting: int               # arrived, rowless — the live backlog the
    #                            autoscaler reads (pending future arrivals
    #                            are not pressure yet)
    running: int               # row owners (prefilling included)
    prefilling: int            # subset of running still ingesting context
    admissions: int
    preemptions: int
    finished: int
    stolen: int                # queued requests released to another replica
    queue_wait: float
    # most urgent next-token deadline over everything this scheduler
    # still owes (running + waiting + pending); +inf when no outstanding
    # request carries an SLO
    min_deadline: float


@dataclasses.dataclass(frozen=True)
class EngineStats:
    """One engine's live state: the dispatch-time load/memory/SLO view."""
    sim_time: float
    outstanding_tokens: int    # context to ingest + output still owed
    kv_free_cells: int         # admissible KV headroom (budget currency)
    kv_occupancy: float        # 1 - free/budget
    accepted_tokens: int
    # cluster-level SLO headroom (SpecServe's dispatch term): time until
    # the most urgent outstanding deadline, net of the estimated time to
    # drain the engine's current token backlog.  Positive = the engine
    # can absorb more work without busting a deadline; with no deadlines
    # outstanding it degrades to a pure (negated) backlog reading.
    slo_headroom: float
    scheduler: SchedulerStats

    def asdict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class ReplicaStats:
    """An engine snapshot as the router sees it."""
    replica: int
    dispatched: int
    engine: EngineStats

    def asdict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class FleetStats:
    """The elastic control plane's fleet-level view: every replica's
    snapshot tagged with its lifecycle state, plus the provisioning
    ledger the cost-normalized-goodput metric is computed from.

    ``states[i]`` is one of ``active`` (serving, dispatch-eligible),
    ``draining`` (finishing in-flight work, excluded from new
    admissions) or ``standby`` (retired or never activated — idle,
    unprovisioned).  ``provisioned_s[i]`` is the sim-clock seconds
    replica ``i`` has been provisioned (activation to retirement, open
    segments credited to the fleet clock), the denominator an
    autoscaling operator pays for."""
    replicas: tuple            # tuple of ReplicaStats, one per replica
    states: tuple              # per-replica lifecycle state strings
    classes: tuple             # per-replica class names ("general", ...)
    active: int                # replicas currently dispatch-eligible
    provisioned_s: tuple       # per-replica provisioned sim-seconds
    steals: int                # queued requests migrated between replicas
    scale_ups: int
    scale_downs: int

    @property
    def replica_seconds(self) -> float:
        """Total replica-seconds provisioned — the cost denominator."""
        return float(sum(self.provisioned_s))

    def cost_normalized_goodput(self, accepted_tokens: int) -> float:
        """Accepted tokens per replica-second provisioned: the number an
        autoscaling operator optimizes (raw goodput at half the fleet
        cost doubles it; over-provisioning dilutes it)."""
        return accepted_tokens / max(self.replica_seconds, 1e-9)

    def asdict(self) -> dict:
        d = dataclasses.asdict(self)
        d["replica_seconds"] = self.replica_seconds
        return d


# --------------------------------------------------------- SLO metrics --

@dataclasses.dataclass(frozen=True)
class SLOSummary:
    """Deadline attainment over a set of requests (sim-clock)."""
    slo_requests: int          # requests carrying an SLO contract
    slo_tokens: int            # their committed tokens with deadlines
    tokens_met: int            # committed no later than their deadline
    ttft_met: int              # first tokens inside the TTFT deadline

    @property
    def attainment(self) -> float:
        """Fraction of deadline-carrying tokens that met their deadline
        (1.0 when nothing carries an SLO — nothing was violated)."""
        if self.slo_tokens == 0:
            return 1.0
        return self.tokens_met / self.slo_tokens

    def goodput_under_slo(self, makespan: float) -> float:
        """Tokens that met their deadline per second — the headline
        serving metric once requests carry latency contracts."""
        return self.tokens_met / max(makespan, 1e-9)

    def asdict(self) -> dict:
        d = dataclasses.asdict(self)
        d["attainment"] = self.attainment
        return d


def slo_summary(reqs: Iterable[Request]) -> SLOSummary:
    """Fold per-token commit times against each request's SLO contract.

    Only the first ``max_new`` tokens count (the engine may emit one
    trailing not-fed-back token past the target length); requests
    without an SLO contribute nothing.  Tokens missing a timestamp (not
    yet committed) are not counted as met or missed — attainment is over
    committed tokens, so partial streams are comparable mid-run."""
    n_req = toks = met = ttft_met = 0
    for r in reqs:
        if r.slo is None:
            continue
        n_req += 1
        times = r.token_times or []
        n = min(len(times), r.max_new)
        for j in range(n):
            toks += 1
            if times[j] <= r.slo.token_deadline(r.arrival, j) + 1e-12:
                met += 1
                if j == 0:
                    ttft_met += 1
    return SLOSummary(slo_requests=n_req, slo_tokens=toks,
                      tokens_met=met, ttft_met=ttft_met)


def min_outstanding_deadline(reqs: Iterable[Request]) -> float:
    """The most urgent next-token deadline over ``reqs`` (+inf when no
    request carries an SLO) — the scheduler/router urgency reading."""
    return min((r.next_deadline() for r in reqs), default=math.inf)


# Deadline horizon used when an engine has NO outstanding deadlines: a
# large constant rather than +inf so ``slo_headroom`` stays finite and
# comparable — between two deadline-free replicas the constant cancels
# and the comparison degrades to backlog (least-outstanding-tokens-ish).
DEADLINE_HORIZON = 1e6


def slo_headroom(min_deadline: float, sim_time: float,
                 outstanding_tokens: int,
                 time_per_token: float) -> float:
    """SpecServe-style cluster headroom: slack to the most urgent
    outstanding deadline minus the estimated backlog drain time."""
    slack = min(min_deadline - sim_time, DEADLINE_HORIZON)
    return slack - outstanding_tokens * max(time_per_token, 0.0)


def expected_time_per_token(sim_time: float, accepted_tokens: int,
                            fallback: float) -> float:
    """Observed mean seconds per committed token, falling back to the
    cost model's per-token verify figure before anything committed."""
    if accepted_tokens > 0:
        return sim_time / accepted_tokens
    return fallback


__all__ = [
    "SchedulerStats", "EngineStats", "ReplicaStats", "FleetStats",
    "SLOSummary", "slo_summary", "min_outstanding_deadline",
    "slo_headroom", "expected_time_per_token", "DEADLINE_HORIZON",
]
