"""Multi-replica serving: an elastic router in front of N engines.

SPIN is a serving system (§II; §VI evaluates under Poisson traffic), and
one engine on one device mesh caps its throughput at whatever a single
LLM verification queue can drain.  The scaling unit of this layer is the
**replica**: an independent ``SpinEngine`` + ``ContinuousScheduler`` pair
whose LLM parameters are sharded over a sub-mesh carved from the leading
``replica`` axis of the serving mesh (``launch/mesh.py``
``replica_submeshes``; shard specs come from the same rule tables in
``distributed/sharding.py`` — the replica axis never appears inside a
rule table, so each replica shards exactly like a single-mesh engine).
Replicas share model *weights* (the ``Bundle`` objects — and therefore
jit caches — may be shared freely; they are read-only) but own disjoint
KV pools, selectors, schedulers and sim clocks.

The ``Router`` owns the **global arrival stream**: requests are submitted
to the router, not to an engine, and are handed to a replica at their
arrival instant by a routing policy:

* ``lot`` — **least outstanding tokens** (default): dispatch to the
  replica owing the fewest total tokens (remaining context + remaining
  output over everything queued/running there).  Greedy join-shortest-
  queue in token currency.
* ``p2c`` — **power of two choices on free KV blocks**: sample two
  *distinct* replicas (seeded, deterministic) and dispatch to the one
  with more free KV cells.  The classic load-balancing result: two
  probes get most of the benefit of querying everyone, and probing
  *memory* rather than queue length tracks the resource that actually
  gates admission.
* ``slo`` — **most SLO headroom** (SpecServe's cluster-level dispatch
  term): dispatch to the replica whose ``EngineStats.slo_headroom`` —
  slack to its most urgent outstanding deadline, net of the estimated
  time to drain its token backlog — is largest.  A deadline-free
  replica reads a large constant horizon minus its backlog drain time,
  so with no contracts anywhere the policy degrades to a
  backlog-drain-time comparison (lot weighted by observed service
  rate); with contracts it keeps strict traffic away from replicas that
  are already close to busting a deadline.

Ties always break toward the lower replica index, so a dispatch trace is
reproducible from (policy, seed, workload) alone.  Policies read the
typed :class:`~repro.serving.stats.ReplicaStats` snapshots
(``replica_snapshot()``) — attributes, not string-keyed dicts.

Co-simulation: each replica advances its own simulated clock, and the
router always steps the replica that is furthest behind (min ``sim_time``
among replicas with work, ties by index).  Pending arrivals are
dispatched once the router clock — the lagging live replica's clock —
reaches them, so a policy never reads replica state from *earlier* than
the dispatch instant (replicas ahead of the lagging clock are read at
their current, slightly later state — the co-simulation analogue of
probing a remote replica whose telemetry is a beat ahead).  Because
engines honour arrival timestamps internally, a dispatched request still
queues inside its replica until that replica's clock reaches its
arrival.

Elastic control plane (DistServe / SpecServe lineage, see PAPERS.md)
-------------------------------------------------------------------

Beyond placement, the router can *reshape the fleet* while it serves:

* **Autoscaling** (``autoscale="target-occupancy"``): every replica has
  a lifecycle state — ``active`` (dispatch-eligible), ``draining``
  (finishing in-flight rows, excluded from new admissions) or
  ``standby`` (unprovisioned).  The control loop watches mean KV
  occupancy, arrived-but-rowless backlog and the worst SLO headroom
  over the active set; sustained pressure activates a standby replica
  (its sim clock fast-forwarded to the fleet clock — a freshly
  provisioned machine comes up *now*, not in the past), and a quiet
  fleet **drain-before-retires** its least-loaded active: queued work
  is released back to the router, in-flight rows decode to completion,
  and only a fully drained replica flips to standby.  A replica with
  live rows is never retired — the conservation contract the chaos
  suite (tests/test_elastic.py) hammers.
* **Work stealing** (``steal``): queued, not-yet-prefilled requests
  migrate from the hottest active replica to the least-loaded one when
  the expected wait at the source exceeds the expected wait at the
  target *plus* the re-prefill cost (``CostModel.prefill_time``) with a
  safety margin.  No KV moves — a queued request owns no rows, so the
  target simply prefills from scratch; greedy speculative decoding
  makes the resulting token stream identical to serving in place.
* **Heterogeneous replica classes** (``parse_replica_classes`` /
  ``class_engine_config``): a ``prefill:1,decode:3`` spec carves
  per-class engine configs — prefill-heavy replicas take big chunk /
  token budgets (and cap adaptive speculation shallow), decode
  replicas take the KV-weighted share — and dispatch prefers the class
  matching each request's shape (long prompt → prefill, long output →
  decode), a router-level approximation of disaggregated
  prefill/decode serving.

The fleet ledger (``FleetStats``) tracks per-replica *provisioned
sim-seconds* (activation → retirement, open segments credited to the
fleet clock), the denominator of **cost-normalized goodput** — accepted
tokens per replica-second provisioned, the number an autoscaling
operator optimizes (``benchmarks/bench_elastic.py``).

With one replica every policy is the constant choice and the router adds
nothing to the timeline: tokens, sim-clock metrics and scheduler counters
are bit-identical to driving the bare engine directly
(``tests/test_router.py``).  With ``autoscale="off"`` and no classes the
control plane never runs — the router is bit-identical (tokens AND
sim-clock stats) to the pre-elastic router, across policies and spec
shapes (tests/test_elastic.py).  ``benchmarks/bench_router.py`` measures
aggregate goodput scaling at a fixed total KV budget and compares the
policies under skewed load.
"""

from __future__ import annotations

import contextlib
import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.data.workloads import Request
from repro.serving.engine import EngineConfig, SpinEngine
from repro.serving.stats import (FleetStats, ReplicaStats,
                                 expected_time_per_token, slo_summary)

POLICIES = ("lot", "p2c", "slo")
AUTOSCALE_MODES = ("off", "target-occupancy")
REPLICA_CLASSES = ("general", "prefill", "decode")
# Relative KV-budget weights when serve.py splits the aggregate
# ``--kv-budget`` across a heterogeneous fleet: decode replicas hold
# long-lived contexts (big KV), prefill replicas turn theirs over per
# chunk and hand requests off.
CLASS_KV_WEIGHTS = {"general": 2, "prefill": 1, "decode": 3}


def parse_replica_classes(spec: str) -> List[str]:
    """Parse a ``--replica-classes`` spec into one class name per
    replica: ``"prefill:1,decode:3"`` → ``['prefill', 'decode',
    'decode', 'decode']``.  An omitted count means 1; the empty spec
    means a homogeneous (class-free) fleet and returns ``[]``."""
    if not spec or not spec.strip():
        return []
    out: List[str] = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        name, _, cnt = part.partition(":")
        name = name.strip()
        if name not in REPLICA_CLASSES:
            raise ValueError(
                f"unknown replica class {name!r} in {spec!r} "
                f"(choose from {', '.join(REPLICA_CLASSES)})")
        if cnt.strip():
            try:
                n = int(cnt)
            except ValueError:
                raise ValueError(
                    f"bad replica count {cnt!r} for class {name!r} "
                    f"in {spec!r}") from None
        else:
            n = 1
        if n < 1:
            raise ValueError(
                f"replica class counts must be >= 1 (got {name}:{n})")
        out.extend([name] * n)
    if not out:
        raise ValueError(f"empty --replica-classes spec {spec!r}")
    return out


def class_engine_config(base: EngineConfig, cls: str) -> EngineConfig:
    """Carve a per-class engine config from the fleet-wide base.

    ``prefill`` replicas absorb long prompts: chunked ingestion is
    forced on and the per-slot token budget doubled so chunk grants
    dominate the step plan (adaptive speculation is capped shallow by
    the engine's ``replica_class`` wiring).  ``decode`` replicas keep
    the base knobs — their edge is the larger KV share serve.py carves
    via :data:`CLASS_KV_WEIGHTS` (long-resident contexts, deep gamma
    already granted by the adaptive controller).  ``general`` is the
    base config, tagged."""
    if cls not in REPLICA_CLASSES:
        raise ValueError(f"unknown replica class {cls!r}")
    if cls == "prefill":
        return dataclasses.replace(
            base, replica_class="prefill",
            prefill_chunk=base.prefill_chunk if base.prefill_chunk > 0
            else 32,
            token_budget=(base.token_budget * 2
                          if base.token_budget else None))
    return dataclasses.replace(base, replica_class=cls)


@dataclasses.dataclass(kw_only=True)
class RouterConfig:
    """Keyword-only like the other serving configs (fields are appended
    as the router grows)."""

    policy: str = "lot"
    seed: int = 0          # p2c probe sampling (lot/slo are sample-free)
    # elastic control plane: "off" = the pre-elastic router, bit-identical
    # tokens and sim-clock stats; "target-occupancy" = scale the active
    # set between replicas_min and replicas_max against mean KV occupancy
    # / backlog / SLO headroom, with drain-before-retire.
    autoscale: str = "off"
    replicas_min: int = 1
    replicas_max: Optional[int] = None    # None -> every engine provided
    # work stealing of queued (rowless) requests: "auto" = on exactly
    # when autoscaling is (the default keeps --autoscale off
    # bit-identical), "on"/"off" force it.
    steal: str = "auto"
    # --replica-classes spec (validated here; serve.py carves the
    # per-class EngineConfigs, the router reads each engine's tag)
    classes: str = ""
    # target-occupancy thresholds: scale up when mean active KV occupancy
    # crosses occ_high (or backlog/SLO pressure appears), drain when it
    # falls under occ_low with an empty backlog.
    occ_high: float = 0.80
    occ_low: float = 0.25
    # min sim-seconds between scale actions (flap damping)
    cooldown: float = 0.05
    # steal only when the source's expected wait exceeds the target's by
    # this multiple of the re-prefill cost (0 = any positive saving)
    steal_margin: float = 1.0

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(f"unknown router policy {self.policy!r}")
        if self.autoscale not in AUTOSCALE_MODES:
            raise ValueError(
                f"unknown autoscale mode {self.autoscale!r} "
                f"(choose from {', '.join(AUTOSCALE_MODES)})")
        if self.steal not in ("auto", "on", "off"):
            raise ValueError(f"steal must be auto|on|off, got {self.steal!r}")
        if self.replicas_min < 1:
            raise ValueError("replicas_min must be >= 1")
        if self.replicas_max is not None \
                and self.replicas_max < self.replicas_min:
            raise ValueError("replicas_max must be >= replicas_min")
        if not 0.0 <= self.occ_low < self.occ_high <= 1.0:
            raise ValueError(
                "need 0 <= occ_low < occ_high <= 1 "
                f"(got {self.occ_low}, {self.occ_high})")
        if self.cooldown < 0:
            raise ValueError("cooldown must be >= 0")
        if self.steal_margin < 0:
            raise ValueError("steal_margin must be >= 0")
        parse_replica_classes(self.classes)  # validate the spec shape

    @classmethod
    def from_args(cls, args):
        """Build a RouterConfig from a ``launch.serve.build_parser()``
        namespace (``--router-policy`` unset means the default policy,
        routed or not — serve.py decides whether a router exists)."""
        return cls(policy=args.router_policy or "lot", seed=args.seed,
                   autoscale=getattr(args, "autoscale", "off"),
                   replicas_min=getattr(args, "replicas_min", 1),
                   replicas_max=getattr(args, "replicas_max", None),
                   steal=getattr(args, "steal", "auto"),
                   classes=getattr(args, "replica_classes", "") or "")


class Router:
    """Dispatches a global request stream across engine replicas.

    ``submeshes`` / ``rules`` are optional: when given (one sub-mesh per
    replica, from ``launch.mesh.replica_submeshes``, plus a
    ``distributed.sharding`` rule table), every replica step runs inside
    ``use_rules(submeshes[i], rules)`` so the model forward's sharding
    constraints resolve against that replica's own device slice.  Without
    them ``constrain`` is a no-op and the engines run single-device —
    the CPU test path.

    With ``cfg.autoscale != "off"`` the router is the elastic control
    plane: ``engines`` is the *pre-carved maximum* fleet (serve.py
    builds ``replicas_max`` engines up front — submeshes cannot be
    re-carved mid-run), of which the first ``replicas_min`` start
    ``active`` and the rest ``standby`` until the autoscaler provisions
    them.
    """

    def __init__(self, engines: Sequence[SpinEngine],
                 cfg: Optional[RouterConfig] = None, *,
                 submeshes=None, rules=None):
        if not engines:
            raise ValueError("router needs at least one replica engine")
        self.engines = list(engines)
        self.cfg = cfg or RouterConfig()
        if submeshes is not None and len(submeshes) != len(self.engines):
            raise ValueError(
                f"{len(submeshes)} sub-meshes for {len(self.engines)} "
                "replicas — carve one per replica (launch.mesh."
                "replica_submeshes)")
        self.submeshes = submeshes
        self.rules = rules
        self._rng = np.random.default_rng(self.cfg.seed)
        self._pending: List = []           # heap of (arrival, seq, Request)
        self._seq = 0
        self.dispatched_to: Dict[int, int] = {}       # rid -> replica
        self._budget: Optional[List[int]] = None      # run()'s step budget
        n = len(self.engines)
        self.dispatch_count = [0] * n
        self.peak_queue_depth = [0] * n
        self.peak_kv_occupancy = [0.0] * n
        self.steps = [0] * n
        # --------------------------------------------- elastic control --
        self.classes = [getattr(eng.ecfg, "replica_class", "general")
                        for eng in self.engines]
        self.has_classes = any(c != "general" for c in self.classes)
        self.elastic = self.cfg.autoscale != "off"
        self.steal_on = (self.cfg.steal == "on"
                         or (self.cfg.steal == "auto" and self.elastic))
        if self.cfg.replicas_min > n:
            raise ValueError(
                f"replicas_min={self.cfg.replicas_min} exceeds the "
                f"{n} engines provided")
        self.rmax = min(self.cfg.replicas_max or n, n)
        if self.elastic:
            self.states = ["active" if i < self.cfg.replicas_min
                           else "standby" for i in range(n)]
        else:
            # non-elastic fleets are fully provisioned for the whole run
            # — the static cost baseline (replica_seconds = n * makespan)
            self.states = ["active"] * n
        self._active_since: List[Optional[float]] = [
            0.0 if s == "active" else None for s in self.states]
        self.provisioned = [0.0] * n       # closed activation segments
        self._last_scale_t: Optional[float] = None
        self.steals = 0
        self.scale_ups = 0
        self.scale_downs = 0
        # control-plane audit trail (the chaos suite's evidence stream):
        # {"t", "event": scale_up|drain|retire|steal, ...}
        self.events: List[dict] = []

    # ----------------------------------------------------------- intake --
    def submit(self, reqs: Sequence[Request]):
        """Add requests to the global stream.  Dispatch happens when the
        router clock reaches each request's ``arrival``, not here."""
        for r in reqs:
            heapq.heappush(self._pending, (float(r.arrival), self._seq, r))
            self._seq += 1

    # ----------------------------------------------------------- policy --
    def replica_snapshot(self) -> List[ReplicaStats]:
        """Live per-replica state, the policies' (and benchmarks') view:
        one typed :class:`ReplicaStats` per replica — the engine's frozen
        snapshot tagged with its index and dispatch count."""
        return [ReplicaStats(replica=i, dispatched=self.dispatch_count[i],
                             engine=eng.snapshot())
                for i, eng in enumerate(self.engines)]

    def fleet_snapshot(self) -> FleetStats:
        """The control plane's typed fleet view: every replica snapshot
        plus lifecycle states, classes and the provisioning ledger (open
        activation segments credited up to the fleet clock)."""
        now = self._fleet_now()
        prov = []
        for i in range(len(self.engines)):
            p = self.provisioned[i]
            since = self._active_since[i]
            if since is not None:
                p += max(0.0, now - since)
            prov.append(p)
        return FleetStats(
            replicas=tuple(self.replica_snapshot()),
            states=tuple(self.states),
            classes=tuple(self.classes),
            active=sum(s == "active" for s in self.states),
            provisioned_s=tuple(prov),
            steals=self.steals,
            scale_ups=self.scale_ups,
            scale_downs=self.scale_downs)

    def _actives(self) -> List[int]:
        return [i for i, s in enumerate(self.states) if s == "active"]

    def _eligible(self) -> List[int]:
        """Replicas a dispatch may target: ``active`` replicas with step
        budget left in the current run.  Draining replicas are excluded
        — they are emptying, and a new admission would either strand
        there or re-migrate — as are standby ones (unprovisioned).  A
        budget-exhausted replica will never be stepped again, so handing
        it a request strands the request while a budgeted replica could
        have served it.  Falls back (active → anyone) rather than
        returning empty — conservation over progress."""
        act = self._actives()
        if self._budget is None:
            return act or list(range(len(self.engines)))
        el = [i for i in act if self._budget[i] > 0]
        return el or act or list(range(len(self.engines)))

    def _class_candidates(self, r: Request, cand: List[int]) -> List[int]:
        """Class-aware dispatch (heterogeneous fleets only): a request
        whose remaining work is dominated by prompt ingestion prefers a
        ``prefill`` replica, one dominated by decode prefers ``decode``;
        ``general`` replicas serve either.  Preference, not a hard
        partition — with no matching replica eligible the full candidate
        set stands (conservation over affinity)."""
        if not self.has_classes:
            return cand
        want = "prefill" if r.prompt_len >= r.max_new else "decode"
        pref = [i for i in cand if self.classes[i] in (want, "general")]
        return pref or cand

    def _choose(self, r: Request) -> int:
        cand = self._class_candidates(r, self._eligible())
        if len(cand) == 1:
            return cand[0]
        if self.cfg.policy == "lot":
            return min(cand,
                       key=lambda i: (self.engines[i].outstanding_tokens(),
                                      i))
        if self.cfg.policy == "slo":
            # most cluster-level SLO headroom (ties: lower index) — reads
            # the typed engine snapshots, not ad-hoc probes
            return min(cand,
                       key=lambda i: (-self.engines[i].snapshot()
                                      .slo_headroom, i))
        # p2c: two seeded probes of *distinct* replicas, keep the roomier
        # one (ties: lower index).  Sampling with replacement would
        # collapse to a single uniform probe 1/n of the time — at n=2
        # that is half the dispatches ignoring KV state entirely.
        a, b = (int(x) for x in
                self._rng.choice(len(cand), size=2, replace=False))
        pair = sorted((cand[a], cand[b]))
        return max(pair,
                   key=lambda i: (self.engines[i].kv_free_cells(), -i))

    def _dispatch_due(self, now: float):
        """Hand every pending request with ``arrival <= now`` to a replica
        (in arrival order — each dispatch updates the state the next
        choice reads)."""
        while self._pending and self._pending[0][0] <= now + 1e-12:
            _, _, r = heapq.heappop(self._pending)
            i = self._choose(r)
            self.dispatched_to[r.rid] = i
            self.dispatch_count[i] += 1
            self.engines[i].add_requests([r])
            depth = self.engines[i].scheduler.queue_depth
            if depth > self.peak_queue_depth[i]:
                self.peak_queue_depth[i] = depth
            self._observe_kv(i)

    def _observe_kv(self, i: int):
        """Track peak live occupancy — the end-of-run snapshot is always
        drained (0), so benchmarks report this instead."""
        occ = self.engines[i].kv_occupancy()
        if occ > self.peak_kv_occupancy[i]:
            self.peak_kv_occupancy[i] = occ

    # -------------------------------------------------- elastic control --
    def _fleet_now(self) -> float:
        """The fleet clock: the furthest-ahead replica's sim time — what
        a wall clock over the co-simulation would read.  Provisioning
        ledgers and scale decisions are stamped against it."""
        return max((eng.sim_time for eng in self.engines), default=0.0)

    def _control(self, now: float):
        """One control-plane tick (elastic mode only): complete pending
        drains, then let the autoscaler and the work stealer act.  Pure
        function of fleet state + config — a rerun replays the same
        scale/steal trace."""
        for i, st in enumerate(self.states):
            if st == "draining" \
                    and not self.engines[i].scheduler.outstanding:
                # drained dry: close the provisioning segment and retire.
                # outstanding == empty means no rows, no queue, no
                # pendings — drain-before-retire by construction.
                self.states[i] = "standby"
                since = self._active_since[i]
                if since is not None:
                    self.provisioned[i] += max(
                        0.0, self.engines[i].sim_time - since)
                    self._active_since[i] = None
                self.events.append(
                    {"t": now, "event": "retire", "replica": i})
        if self.cfg.autoscale == "target-occupancy":
            self._autoscale(now)
        if self.steal_on:
            self._steal(now)

    def _autoscale(self, now: float):
        act = self._actives()
        if not act:
            return
        if self._last_scale_t is not None \
                and now - self._last_scale_t < self.cfg.cooldown:
            return
        occ = sum(self.engines[i].kv_occupancy() for i in act) / len(act)
        backlog = sum(len(self.engines[i].scheduler.waiting) for i in act)
        headroom = min(self.engines[i].snapshot().slo_headroom for i in act)
        # pressure: KV nearly full, queues building past one-per-replica,
        # or some active replica already past deadline-safe load
        pressure = (occ >= self.cfg.occ_high or backlog > len(act)
                    or headroom < 0.0)
        idle = occ <= self.cfg.occ_low and backlog == 0
        if pressure and len(act) < self.rmax:
            standby = [i for i, s in enumerate(self.states)
                       if s == "standby"]
            if standby:
                self._activate(standby[0], now)
            return
        if idle and len(act) > self.cfg.replicas_min:
            # retire the least-loaded active: cheapest drain, and its
            # queued work redistributes with the least disruption
            i = min(act, key=lambda j: (self.engines[j].outstanding_tokens(),
                                        j))
            self._drain(i, now)

    def _activate(self, i: int, now: float):
        """Provision a standby replica.  Its sim clock fast-forwards to
        the fleet clock — a machine provisioned at t serves from t, it
        does not retroactively absorb the past — which also keeps the
        co-simulation's lagging-clock invariant (the new replica is
        never *behind* the dispatch instant that fills it)."""
        eng = self.engines[i]
        eng.sim_time = max(eng.sim_time, now)
        self.states[i] = "active"
        self._active_since[i] = eng.sim_time
        self.scale_ups += 1
        self._last_scale_t = now
        self.events.append({"t": now, "event": "scale_up", "replica": i})

    def _drain(self, i: int, now: float):
        """Begin retiring replica ``i``: flip it to ``draining`` (no new
        admissions — ``_eligible`` skips it), release every queued
        (rowless) request back to the router's pending stream at its
        original arrival, and let in-flight rows decode to completion.
        ``_control`` flips it to ``standby`` only once the scheduler
        reports nothing outstanding."""
        self.states[i] = "draining"
        self.scale_downs += 1
        self._last_scale_t = now
        freed = self.engines[i].release_queued(include_pending=True)
        for r in freed:
            self.dispatched_to.pop(r.rid, None)
            heapq.heappush(self._pending,
                           (float(r.arrival), self._seq, r))
            self._seq += 1
        self.events.append({"t": now, "event": "drain", "replica": i,
                            "released": [r.rid for r in freed]})

    def _steal(self, now: float):
        """Migrate queued work from the hottest active replica to the
        least-loaded one when re-prefilling at the target beats waiting
        at the source.  Expected waits are backlog-drain estimates
        (outstanding tokens x observed seconds/token); the migration
        must win by ``steal_margin`` x the re-prefill cost, so marginal
        steals — which burn prefill FLOPs for nothing — stay put.  Only
        rowless requests move: no KV migrates, the target prefills the
        request's context from scratch."""
        act = self._actives()
        if len(act) < 2:
            return
        src = max(act, key=lambda i: (len(self.engines[i].scheduler.waiting),
                                      -i))
        if not self.engines[src].scheduler.waiting:
            return
        dst = min(act, key=lambda i: (self.engines[i].outstanding_tokens(),
                                      i))
        if dst == src:
            return
        esrc, edst = self.engines[src], self.engines[dst]
        tpt_s = expected_time_per_token(esrc.sim_time, esrc.accepted_tokens,
                                        esrc.cost.llm_time_per_token)
        tpt_d = expected_time_per_token(edst.sim_time, edst.accepted_tokens,
                                        edst.cost.llm_time_per_token)
        out_src = esrc.outstanding_tokens()
        out_dst = edst.outstanding_tokens()
        moved: List[int] = []
        for r in esrc.scheduler.steal_candidates():
            emitted = len(r.emitted or [])
            ctx = r.prompt_len + max(0, emitted - 1)
            owed = ctx + max(0, r.max_new - max(0, emitted - 1))
            pre = edst.cost.prefill_time(ctx)
            if out_src * tpt_s > (out_dst * tpt_d
                                  + (1.0 + self.cfg.steal_margin) * pre):
                moved.append(r.rid)
                out_src -= owed
                out_dst += owed
        if not moved:
            return
        reqs = esrc.release_queued(moved)
        edst.add_requests(reqs)
        for r in reqs:
            self.dispatched_to[r.rid] = dst
        self.steals += len(reqs)
        self._observe_kv(dst)
        self.events.append({"t": now, "event": "steal", "src": src,
                            "dst": dst, "rids": [r.rid for r in reqs]})

    # ------------------------------------------------------------- loop --
    def _replica_ctx(self, i: int):
        if self.submeshes is None or self.rules is None:
            return contextlib.nullcontext()
        from repro.distributed.sharding import use_rules
        return use_rules(self.submeshes[i], self.rules)

    def step_replica(self, i: int) -> dict:
        """One engine slot on replica ``i`` (under its sub-mesh's sharding
        rules when meshes were provided)."""
        with self._replica_ctx(i):
            rec = self.engines[i].step()
        self.steps[i] += 1
        self._observe_kv(i)
        return rec

    def run(self, max_slots: int = 1000) -> dict:
        """Drive the co-simulation until the stream drains (or every
        replica with work exhausts its ``max_slots`` step budget)."""
        budget = [max_slots] * len(self.engines)
        self._budget = budget
        try:
            while True:
                if self.elastic or self.steal_on:
                    self._control(self._fleet_now())
                live = [i for i, eng in enumerate(self.engines)
                        if eng.scheduler.outstanding and budget[i] > 0]
                if not live:
                    if self._pending and any(b > 0 for b in budget):
                        # every replica idle: fast-forward the router clock
                        # to the next arrival and dispatch it
                        self._dispatch_due(self._pending[0][0])
                        continue
                    break
                i = min(live, key=lambda j: (self.engines[j].sim_time, j))
                self._dispatch_due(self.engines[i].sim_time)
                self.step_replica(i)
                budget[i] -= 1
        finally:
            self._budget = None
        return self.stats()

    # ------------------------------------------------------------ stats --
    def stats(self) -> dict:
        """Aggregate serving stats plus the per-replica breakdown.
        ``replica_stats[i]`` is replica i's full engine stats dict —
        with one replica it is exactly what the bare engine would
        report."""
        per = [eng.stats() for eng in self.engines]
        accepted = sum(eng.accepted_tokens for eng in self.engines)
        makespan = max((eng.sim_time for eng in self.engines), default=0.0)
        reqs = [r for eng in self.engines for r in eng.requests.values()]
        lat = [r.latency for r in reqs if r.latency is not None]
        ttft = [r.first_token_time - r.arrival for r in reqs
                if r.first_token_time is not None]
        summ = slo_summary(reqs)
        fleet = self.fleet_snapshot()
        return {
            "router_policy": self.cfg.policy,
            "slo": {**summ.asdict(),
                    "goodput_under_slo": summ.goodput_under_slo(makespan)},
            "replicas": len(self.engines),
            "dispatched": list(self.dispatch_count),
            "undispatched": len(self._pending),
            "steps": list(self.steps),
            "peak_queue_depth": list(self.peak_queue_depth),
            "peak_kv_occupancy": list(self.peak_kv_occupancy),
            "accepted_tokens": accepted,
            "makespan_sim": makespan,
            "aggregate_goodput_sim": accepted / max(makespan, 1e-9),
            "mean_latency": float(np.mean(lat)) if lat else 0.0,
            "p95_latency": float(np.percentile(lat, 95)) if lat else 0.0,
            "ttft_p50": float(np.percentile(ttft, 50)) if ttft else 0.0,
            "ttft_p95": float(np.percentile(ttft, 95)) if ttft else 0.0,
            "finished": sum(len(eng.scheduler.finished)
                            for eng in self.engines),
            # elastic control plane (all zeros / fully-provisioned under
            # autoscale=off — the static cost baseline)
            "autoscale": self.cfg.autoscale,
            "states": list(self.states),
            "classes": list(self.classes),
            "steals": self.steals,
            "scale_ups": self.scale_ups,
            "scale_downs": self.scale_downs,
            "replica_seconds": fleet.replica_seconds,
            "cost_normalized_goodput":
                fleet.cost_normalized_goodput(accepted),
            "replica_snapshot": [s.asdict()
                                 for s in self.replica_snapshot()],
            "replica_stats": per,
        }
