"""Multi-replica serving: a router in front of N independent engines.

SPIN is a serving system (§II; §VI evaluates under Poisson traffic), and
one engine on one device mesh caps its throughput at whatever a single
LLM verification queue can drain.  The scaling unit of this layer is the
**replica**: an independent ``SpinEngine`` + ``ContinuousScheduler`` pair
whose LLM parameters are sharded over a sub-mesh carved from the leading
``replica`` axis of the serving mesh (``launch/mesh.py``
``replica_submeshes``; shard specs come from the same rule tables in
``distributed/sharding.py`` — the replica axis never appears inside a
rule table, so each replica shards exactly like a single-mesh engine).
Replicas share model *weights* (the ``Bundle`` objects — and therefore
jit caches — may be shared freely; they are read-only) but own disjoint
KV pools, selectors, schedulers and sim clocks.

The ``Router`` owns the **global arrival stream**: requests are submitted
to the router, not to an engine, and are handed to a replica at their
arrival instant by a routing policy:

* ``lot`` — **least outstanding tokens** (default): dispatch to the
  replica owing the fewest total tokens (remaining context + remaining
  output over everything queued/running there).  Greedy join-shortest-
  queue in token currency.
* ``p2c`` — **power of two choices on free KV blocks**: sample two
  *distinct* replicas (seeded, deterministic) and dispatch to the one
  with more free KV cells.  The classic load-balancing result: two
  probes get most of the benefit of querying everyone, and probing
  *memory* rather than queue length tracks the resource that actually
  gates admission.
* ``slo`` — **most SLO headroom** (SpecServe's cluster-level dispatch
  term): dispatch to the replica whose ``EngineStats.slo_headroom`` —
  slack to its most urgent outstanding deadline, net of the estimated
  time to drain its token backlog — is largest.  A deadline-free
  replica reads a large constant horizon minus its backlog drain time,
  so with no contracts anywhere the policy degrades to a
  backlog-drain-time comparison (lot weighted by observed service
  rate); with contracts it keeps strict traffic away from replicas that
  are already close to busting a deadline.

Ties always break toward the lower replica index, so a dispatch trace is
reproducible from (policy, seed, workload) alone.  Policies read the
typed :class:`~repro.serving.stats.ReplicaStats` snapshots
(``replica_snapshot()``) — attributes, not string-keyed dicts.

Co-simulation: each replica advances its own simulated clock, and the
router always steps the replica that is furthest behind (min ``sim_time``
among replicas with work, ties by index).  Pending arrivals are
dispatched once the router clock — the lagging live replica's clock —
reaches them, so a policy never reads replica state from *earlier* than
the dispatch instant (replicas ahead of the lagging clock are read at
their current, slightly later state — the co-simulation analogue of
probing a remote replica whose telemetry is a beat ahead).  Because
engines honour arrival timestamps internally, a dispatched request still
queues inside its replica until that replica's clock reaches its
arrival.

With one replica every policy is the constant choice and the router adds
nothing to the timeline: tokens, sim-clock metrics and scheduler counters
are bit-identical to driving the bare engine directly
(``tests/test_router.py``).  ``benchmarks/bench_router.py`` measures
aggregate goodput scaling at a fixed total KV budget and compares the
policies under skewed load.
"""

from __future__ import annotations

import contextlib
import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.data.workloads import Request
from repro.serving.engine import SpinEngine
from repro.serving.stats import ReplicaStats, slo_summary

POLICIES = ("lot", "p2c", "slo")


@dataclasses.dataclass(kw_only=True)
class RouterConfig:
    """Keyword-only like the other serving configs (fields are appended
    as the router grows)."""

    policy: str = "lot"
    seed: int = 0          # p2c probe sampling (lot/slo are sample-free)

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(f"unknown router policy {self.policy!r}")

    @classmethod
    def from_args(cls, args):
        """Build a RouterConfig from a ``launch.serve.build_parser()``
        namespace (``--router-policy`` unset means the default policy,
        routed or not — serve.py decides whether a router exists)."""
        return cls(policy=args.router_policy or "lot", seed=args.seed)


class Router:
    """Dispatches a global request stream across engine replicas.

    ``submeshes`` / ``rules`` are optional: when given (one sub-mesh per
    replica, from ``launch.mesh.replica_submeshes``, plus a
    ``distributed.sharding`` rule table), every replica step runs inside
    ``use_rules(submeshes[i], rules)`` so the model forward's sharding
    constraints resolve against that replica's own device slice.  Without
    them ``constrain`` is a no-op and the engines run single-device —
    the CPU test path.
    """

    def __init__(self, engines: Sequence[SpinEngine],
                 cfg: Optional[RouterConfig] = None, *,
                 submeshes=None, rules=None):
        if not engines:
            raise ValueError("router needs at least one replica engine")
        self.engines = list(engines)
        self.cfg = cfg or RouterConfig()
        if submeshes is not None and len(submeshes) != len(self.engines):
            raise ValueError(
                f"{len(submeshes)} sub-meshes for {len(self.engines)} "
                "replicas — carve one per replica (launch.mesh."
                "replica_submeshes)")
        self.submeshes = submeshes
        self.rules = rules
        self._rng = np.random.default_rng(self.cfg.seed)
        self._pending: List = []           # heap of (arrival, seq, Request)
        self._seq = 0
        self.dispatched_to: Dict[int, int] = {}       # rid -> replica
        self._budget: Optional[List[int]] = None      # run()'s step budget
        self.dispatch_count = [0] * len(self.engines)
        self.peak_queue_depth = [0] * len(self.engines)
        self.peak_kv_occupancy = [0.0] * len(self.engines)
        self.steps = [0] * len(self.engines)

    # ----------------------------------------------------------- intake --
    def submit(self, reqs: Sequence[Request]):
        """Add requests to the global stream.  Dispatch happens when the
        router clock reaches each request's ``arrival``, not here."""
        for r in reqs:
            heapq.heappush(self._pending, (float(r.arrival), self._seq, r))
            self._seq += 1

    # ----------------------------------------------------------- policy --
    def replica_snapshot(self) -> List[ReplicaStats]:
        """Live per-replica state, the policies' (and benchmarks') view:
        one typed :class:`ReplicaStats` per replica — the engine's frozen
        snapshot tagged with its index and dispatch count."""
        return [ReplicaStats(replica=i, dispatched=self.dispatch_count[i],
                             engine=eng.snapshot())
                for i, eng in enumerate(self.engines)]

    def _eligible(self) -> List[int]:
        """Replicas a dispatch may target: those with step budget left in
        the current run (a budget-exhausted replica will never be stepped
        again, so handing it a request strands the request while a
        budgeted replica could have served it).  Falls back to everyone
        when no replica has budget — conservation over progress."""
        if self._budget is None:
            return list(range(len(self.engines)))
        el = [i for i, b in enumerate(self._budget) if b > 0]
        return el or list(range(len(self.engines)))

    def _choose(self, r: Request) -> int:
        cand = self._eligible()
        if len(cand) == 1:
            return cand[0]
        if self.cfg.policy == "lot":
            return min(cand,
                       key=lambda i: (self.engines[i].outstanding_tokens(),
                                      i))
        if self.cfg.policy == "slo":
            # most cluster-level SLO headroom (ties: lower index) — reads
            # the typed engine snapshots, not ad-hoc probes
            return min(cand,
                       key=lambda i: (-self.engines[i].snapshot()
                                      .slo_headroom, i))
        # p2c: two seeded probes of *distinct* replicas, keep the roomier
        # one (ties: lower index).  Sampling with replacement would
        # collapse to a single uniform probe 1/n of the time — at n=2
        # that is half the dispatches ignoring KV state entirely.
        a, b = (int(x) for x in
                self._rng.choice(len(cand), size=2, replace=False))
        pair = sorted((cand[a], cand[b]))
        return max(pair,
                   key=lambda i: (self.engines[i].kv_free_cells(), -i))

    def _dispatch_due(self, now: float):
        """Hand every pending request with ``arrival <= now`` to a replica
        (in arrival order — each dispatch updates the state the next
        choice reads)."""
        while self._pending and self._pending[0][0] <= now + 1e-12:
            _, _, r = heapq.heappop(self._pending)
            i = self._choose(r)
            self.dispatched_to[r.rid] = i
            self.dispatch_count[i] += 1
            self.engines[i].add_requests([r])
            depth = self.engines[i].scheduler.queue_depth
            if depth > self.peak_queue_depth[i]:
                self.peak_queue_depth[i] = depth
            self._observe_kv(i)

    def _observe_kv(self, i: int):
        """Track peak live occupancy — the end-of-run snapshot is always
        drained (0), so benchmarks report this instead."""
        occ = self.engines[i].kv_occupancy()
        if occ > self.peak_kv_occupancy[i]:
            self.peak_kv_occupancy[i] = occ

    # ------------------------------------------------------------- loop --
    def _replica_ctx(self, i: int):
        if self.submeshes is None or self.rules is None:
            return contextlib.nullcontext()
        from repro.distributed.sharding import use_rules
        return use_rules(self.submeshes[i], self.rules)

    def step_replica(self, i: int) -> dict:
        """One engine slot on replica ``i`` (under its sub-mesh's sharding
        rules when meshes were provided)."""
        with self._replica_ctx(i):
            rec = self.engines[i].step()
        self.steps[i] += 1
        self._observe_kv(i)
        return rec

    def run(self, max_slots: int = 1000) -> dict:
        """Drive the co-simulation until the stream drains (or every
        replica with work exhausts its ``max_slots`` step budget)."""
        budget = [max_slots] * len(self.engines)
        self._budget = budget
        try:
            while True:
                live = [i for i, eng in enumerate(self.engines)
                        if eng.scheduler.outstanding and budget[i] > 0]
                if not live:
                    if self._pending and any(b > 0 for b in budget):
                        # every replica idle: fast-forward the router clock
                        # to the next arrival and dispatch it
                        self._dispatch_due(self._pending[0][0])
                        continue
                    break
                i = min(live, key=lambda j: (self.engines[j].sim_time, j))
                self._dispatch_due(self.engines[i].sim_time)
                self.step_replica(i)
                budget[i] -= 1
        finally:
            self._budget = None
        return self.stats()

    # ------------------------------------------------------------ stats --
    def stats(self) -> dict:
        """Aggregate serving stats plus the per-replica breakdown.
        ``replica_stats[i]`` is replica i's full engine stats dict —
        with one replica it is exactly what the bare engine would
        report."""
        per = [eng.stats() for eng in self.engines]
        accepted = sum(eng.accepted_tokens for eng in self.engines)
        makespan = max((eng.sim_time for eng in self.engines), default=0.0)
        reqs = [r for eng in self.engines for r in eng.requests.values()]
        lat = [r.latency for r in reqs if r.latency is not None]
        ttft = [r.first_token_time - r.arrival for r in reqs
                if r.first_token_time is not None]
        summ = slo_summary(reqs)
        return {
            "router_policy": self.cfg.policy,
            "slo": {**summ.asdict(),
                    "goodput_under_slo": summ.goodput_under_slo(makespan)},
            "replicas": len(self.engines),
            "dispatched": list(self.dispatch_count),
            "undispatched": len(self._pending),
            "steps": list(self.steps),
            "peak_queue_depth": list(self.peak_queue_depth),
            "peak_kv_occupancy": list(self.peak_kv_occupancy),
            "accepted_tokens": accepted,
            "makespan_sim": makespan,
            "aggregate_goodput_sim": accepted / max(makespan, 1e-9),
            "mean_latency": float(np.mean(lat)) if lat else 0.0,
            "p95_latency": float(np.percentile(lat, 95)) if lat else 0.0,
            "ttft_p50": float(np.percentile(ttft, 50)) if ttft else 0.0,
            "ttft_p95": float(np.percentile(ttft, 95)) if ttft else 0.0,
            "finished": sum(len(eng.scheduler.finished)
                            for eng in self.engines),
            "replica_snapshot": [s.asdict()
                                 for s in self.replica_snapshot()],
            "replica_stats": per,
        }
