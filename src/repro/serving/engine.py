"""SPIN's runtime engine (paper §III Fig. 7 + §V) with continuous batching.

Per time slot:
  0. the continuous-batching scheduler (serving/scheduler.py) admits
     arrived requests into free pool rows and preempts lowest-priority
     requests when the KV budget is exceeded.  With ``prefill_chunk=0``
     admission prefills the whole prompt monolithically; with
     ``prefill_chunk>0`` the scheduler's token-budget step planner grants
     prompt *chunks* instead — an admitted request holds a row in the
     ``prefilling`` state (partial KV, not drafting) and its chunks are
     appended into the existing row/block table while other slots keep
     decoding in the same step (Sarathi-style mixed batches; under the
     paged layout a chunk allocates exactly its blocks and writes through
     the row's block table);
  1. the selector assigns each active request to an SSM (LBSS / baselines);
     switches go through the SwitchManager (fast pre-computed switching);
  2. the gamma controller (core/gamma.py) grants every request a
     speculation depth k_i in [1, gamma_max] — ``fixed`` policy: the
     uniform ``gamma`` everywhere (bit-identical to the pre-controller
     engine); ``adaptive``: expected-goodput argmax over the selector's
     per-(request, SSM) acceptance estimates, with a load-aware cap when
     the step planner's token budget is contended;
  3. every SSM drafts its rows' granted depths (static-shape pools at the
     slot's max depth; tail positions beyond a row's grant are masked);
  4. the LLM verifies all candidates — padded (vanilla) or packed via
     request decomposition (§V-A) — accepting at most k_i per row;
  5. accepted tokens are committed, caches rolled back, goodput and
     acceptance observed back into the selector; rows of finished requests
     are recycled and immediately re-filled from the waiting queue (same
     step).

The engine clock is the simulated time: requests whose ``arrival``
timestamp lies in the future stay queued until the clock reaches them,
and when the pool drains the clock fast-forwards to the next arrival.

Timing: functional results are exact; the slot TIMELINE (draft/verify
overlap with micro-batch pipelining, §V-B) is computed by the calibrated
event simulator in core/pipeline.py, because this host has one CPU — on a
TPU pod the same schedule is realized by dispatching drafts and
verifications to disjoint device groups (launch/serve.py maps SSM replicas
and the LLM onto sub-meshes; JAX async dispatch overlaps them).  Wall-clock
is also recorded for reference.

Fault tolerance: ``fail_ssm`` drops a replica (requests re-routed through
the switching path); straggler mitigation re-dispatches micro-batches whose
simulated draft time exceeds ``straggler_factor`` x the expected time.
"""

from __future__ import annotations

import dataclasses
import math
import time
import warnings
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decompose as D
from repro.core import pipeline as P
from repro.core import spec_decode as sd
from repro.core.gamma import GammaConfig, GammaController
from repro.core.switching import SwitchManager
from repro.data.workloads import Request
from repro.kernels import autotune, quant
from repro.models import transformer as T
from repro.serving.paged import paged_compatible
from repro.serving.pool import DenseCachePool, PagedCachePool
from repro.serving.scheduler import ContinuousScheduler, SchedulerConfig
from repro.serving.stats import (EngineStats, expected_time_per_token,
                                 slo_headroom, slo_summary)


def _bucket(n: int, align: int = 16) -> int:
    return max(align, int(math.ceil(n / align) * align))


@dataclasses.dataclass(kw_only=True)
class EngineConfig:
    """Keyword-only on purpose (like ``SchedulerConfig``): fields are
    appended as the engine grows and positional construction would
    silently shift arguments."""
    gamma: int = 4
    # speculation-depth policy (core/gamma.py): "fixed" drafts gamma tokens
    # for every request every slot (bit-identical to the pre-controller
    # engine); "adaptive" grants each request k in [1, gamma_max] by
    # expected-goodput argmax over the selector's acceptance estimates.
    gamma_policy: str = "fixed"
    # adaptive depth cap; None -> 2 * gamma ("fixed" always uses gamma).
    # Pools, KV margins and admission reserve this worst case.
    gamma_max: Optional[int] = None
    max_len: int = 256
    capacity: int = 16                 # concurrent requests (LLM pool rows)
    use_packed_verify: bool = True
    use_pipeline: bool = True
    micro_batches: Optional[List[int]] = None   # None -> paper heuristic
    packed_bucket: int = 256           # packed-KV shape bucketing (retraces)
    straggler_factor: float = 4.0
    straggler_mitigation: bool = True
    seed: int = 0
    # continuous-batching scheduler
    scheduler_policy: str = "continuous"   # or "static" (gang baseline)
    # total KV cells before preemption; None -> capacity*max_len, which
    # never binds (add_requests caps each request at max_len cells)
    kv_budget: Optional[int] = None
    # KV memory layout: "paged" = block-table pools, budget enforced as
    # physical blocks (kv_budget // block_size); "dense" = legacy
    # capacity x max_len grids.  Models with recurrent state or sliding
    # windows fall back to dense automatically.
    kv_layout: str = "paged"
    block_size: int = 16
    # chunked prefill: max prompt tokens ingested per request per slot
    # (0 = monolithic prefill-on-admit).  Continuous policy +
    # attention-family LLM only — recurrent-state LLMs fall back to
    # monolithic automatically (their state updates are not
    # segment-maskable, so bucket-padded chunk appends would corrupt them).
    prefill_chunk: int = 0
    # per-slot LLM query-token budget split between decode slots
    # (gamma+1 tokens each) and prefill chunks; None = unthrottled
    token_budget: Optional[int] = None
    # speculation shape: "linear" drafts one chain per request (the
    # classic SPIN iteration); "tree" splits each granted depth k across
    # up to ``spec_branch`` branches (the drafter's top-k step-1
    # candidates), forks the request's paged KV row copy-on-write per
    # branch, and verifies the whole token tree in ONE packed pass with a
    # topology-aware mask — the longest verified root-to-leaf path wins.
    # Tree mode needs the paged layout + packed verification; otherwise
    # it falls back to linear with a warning (like the paged->dense
    # auto-fallback).  spec_branch=1 is bit-identical to linear.
    spec_shape: str = "linear"
    spec_branch: int = 2
    # fused speculative-step Pallas kernels (kernels/fused_decode.py /
    # fused_verify.py): "on" streams KV straight from the paged pool in a
    # single launch per attention site, with tile shapes resolved once at
    # engine construction from the autotune cache
    # (results/TUNE_cache.json, safe default on a cold miss); "off" keeps
    # the PR-6 gather + paged-kernel path bit-identically.  Requires the
    # paged layout; "on" under a dense fallback warns and stays unfused.
    fused_kernels: str = "off"
    # paged-KV block storage dtype (kernels/quant.py): "bf16" stores the
    # model's compute dtype (bit-identical default); "int8"/"fp8" store
    # quantized blocks with per-(slot, head) float32 scale sidecars —
    # 2-4x more resident contexts at the same physical KV budget, with
    # dequant fused into the attention kernels.  Requires the paged
    # layout; a quantized choice under the dense fallback warns and
    # reverts to bf16.
    kv_dtype: str = "bf16"
    # SLO-aware serving: when True, requests carrying a Request.slo
    # contract steer admission order, prefill chunk sizing and (under the
    # adaptive gamma policy) speculation depth.  Requests WITHOUT a
    # contract are handled identically either way, so True with an
    # SLO-free workload is bit-identical to False — the
    # `--slo-profile off` contract.
    slo_aware: bool = True
    # heterogeneous replica class (elastic fleet, serving/router.py):
    # "general" serves everything (the default, bit-identical to the
    # class-free engine); "prefill" is tuned for prompt ingestion (the
    # router steers long-prompt requests here and ADAPTIVE gamma grants
    # are capped shallow so verify budget feeds chunks); "decode" is
    # tuned for flat TPOT on short-prompt/long-output streams.  The class
    # itself never changes engine semantics — only which knob preset the
    # router carves (see router.class_engine_config) plus the gamma cap.
    replica_class: str = "general"

    @classmethod
    def from_args(cls, args, *, capacity=None, kv_budget=None, seed=None):
        """Build an EngineConfig from a ``launch.serve.build_parser()``
        namespace — THE flag translation, shared by serve.py, tests and
        benchmarks so nobody re-derives it by hand.  ``capacity`` /
        ``kv_budget`` override the per-replica share (serve.py splits the
        aggregate flags across replicas); cross-flag validation lives
        here and raises ``ValueError`` (serve.py maps it to
        ``parser.error``)."""
        if args.block_size <= 0:
            raise ValueError("--block-size must be positive")
        if args.prefill_chunk < 0:
            raise ValueError(
                "--prefill-chunk must be >= 0 (0 disables chunking)")
        if args.token_budget is not None and args.token_budget <= 0:
            raise ValueError("--token-budget must be positive (omit it "
                             "for unthrottled slots)")
        if args.gamma <= 0:
            raise ValueError("--gamma must be positive")
        if args.gamma_max is not None and args.gamma_max <= 0:
            raise ValueError(
                "--gamma-max must be positive (omit it for 2 * --gamma)")
        if args.spec_branch < 1:
            raise ValueError("--spec-branch must be >= 1")
        if args.spec_shape == "tree":
            gmax = (args.gamma if args.gamma_policy == "fixed"
                    else (args.gamma_max if args.gamma_max is not None
                          else 2 * args.gamma))
            max_nodes = D.max_tree_nodes()
            if gmax + min(args.spec_branch, gmax) > max_nodes:
                raise ValueError(
                    f"--spec-shape tree needs gamma_max + branches <= "
                    f"{max_nodes} tree nodes for the "
                    f"{D.ANCESTOR_MASK_BITS}-bit ancestor mask (got "
                    f"--gamma-max {gmax}, --spec-branch "
                    f"{args.spec_branch}); lower one of them")
        return cls(
            gamma=args.gamma, gamma_policy=args.gamma_policy,
            gamma_max=args.gamma_max, max_len=256,
            capacity=(capacity if capacity is not None
                      else (args.capacity if args.capacity is not None
                            else args.requests)),
            use_packed_verify=not args.no_packed,
            use_pipeline=not args.no_pipeline,
            scheduler_policy=args.scheduler,
            kv_budget=kv_budget if kv_budget is not None else args.kv_budget,
            kv_layout=args.kv_layout,
            block_size=args.block_size,
            prefill_chunk=args.prefill_chunk,
            token_budget=args.token_budget,
            spec_shape=args.spec_shape,
            spec_branch=args.spec_branch,
            fused_kernels=args.fused_kernels,
            kv_dtype=args.kv_dtype,
            slo_aware=getattr(args, "slo_profile", "off") != "off",
            seed=seed if seed is not None else args.seed)


class SpinEngine:
    def __init__(self, llm: sd.Bundle, ssms: Sequence[sd.Bundle],
                 selector, ecfg: EngineConfig,
                 cost_model: Optional[P.CostModel] = None):
        self.llm = llm
        self.ssms = list(ssms)
        self.selector = selector
        self.ecfg = ecfg
        if ecfg.kv_layout not in ("paged", "dense"):
            raise ValueError(f"unknown kv_layout {ecfg.kv_layout!r}")
        if ecfg.spec_shape not in ("linear", "tree"):
            raise ValueError(f"unknown spec_shape {ecfg.spec_shape!r}")
        if ecfg.spec_branch < 1:
            raise ValueError("spec_branch must be >= 1")
        if ecfg.fused_kernels not in ("on", "off"):
            raise ValueError(
                f"unknown fused_kernels {ecfg.fused_kernels!r}")
        if ecfg.kv_dtype not in quant.KV_DTYPE_NAMES:
            raise ValueError(
                f"unknown kv_dtype {ecfg.kv_dtype!r} "
                f"(expected one of {'/'.join(quant.KV_DTYPE_NAMES)})")
        if ecfg.gamma_policy == "fixed":
            self.gamma_max = ecfg.gamma
        else:
            self.gamma_max = (ecfg.gamma_max if ecfg.gamma_max is not None
                              else 2 * ecfg.gamma)
        self.paged = (ecfg.kv_layout == "paged"
                      and paged_compatible(llm.cfg)
                      and all(paged_compatible(b.cfg) for b in self.ssms))
        # tree speculation rides the paged packed-verify path (forks are
        # block-table aliases; the topology mask threads through the
        # packed query layout) — anything else falls back to the linear
        # shape, mirroring the paged->dense auto-fallback
        self.tree = (ecfg.spec_shape == "tree" and self.paged
                     and ecfg.use_packed_verify)
        if ecfg.spec_shape == "tree" and not self.tree:
            warnings.warn(
                "spec_shape='tree' requires the paged KV layout and packed "
                "verification; falling back to linear speculation",
                stacklevel=2)
        self.branches = ecfg.spec_branch if self.tree else 1
        max_nodes = D.max_tree_nodes()
        if self.tree and self.gamma_max + min(ecfg.spec_branch,
                                              self.gamma_max) > max_nodes:
            raise ValueError(
                f"tree speculation needs gamma_max + branches <= "
                f"{max_nodes} tree nodes for the "
                f"{D.ANCESTOR_MASK_BITS}-bit ancestor mask (got gamma_max="
                f"{self.gamma_max} + min(spec_branch={ecfg.spec_branch}, "
                f"gamma_max) = "
                f"{self.gamma_max + min(ecfg.spec_branch, self.gamma_max)}"
                f"); lower --gamma-max or --spec-branch")
        # fused Pallas kernels stream KV straight out of the paged block
        # pool, so they require the paged layout; resolve each bundle's
        # tile config ONCE here (autotune-cache lookup with the safe
        # default on a cold miss) so dispatch never tunes implicitly and
        # every jit trace sees a stable static config
        self.fused = ecfg.fused_kernels == "on" and self.paged
        if ecfg.fused_kernels == "on" and not self.paged:
            warnings.warn(
                "fused_kernels='on' requires the paged KV layout; "
                "falling back to the unfused attention path",
                stacklevel=2)
        # quantized blocks live in the paged pool's block/scale layout;
        # the dense grids have no sidecar plumbing, so a dense fallback
        # reverts to the compute dtype (mirrors the fused fallback above)
        self.kv_dtype = ecfg.kv_dtype if self.paged else "bf16"
        if quant.is_quantized(ecfg.kv_dtype) and not self.paged:
            warnings.warn(
                f"kv_dtype={ecfg.kv_dtype!r} requires the paged KV "
                "layout; falling back to bf16 (unquantized) KV",
                stacklevel=2)
        shape = "tree" if self.tree else "linear"

        def _fused_cfg(kind, b, s="linear"):
            if not self.fused:
                return None
            return autotune.get_config(
                kind, H=b.cfg.n_heads, Kh=b.cfg.n_kv_heads, D=b.cfg.hd,
                gamma_max=self.gamma_max, block_size=ecfg.block_size,
                shape=s, kv_dtype=self.kv_dtype)

        self.fused_llm_decode = _fused_cfg("decode", llm)
        self.fused_llm_verify = _fused_cfg("verify", llm, shape)
        self.fused_ssm_decode = [_fused_cfg("decode", b) for b in self.ssms]
        # each extra branch needs a pool row to draft/verify through;
        # scheduler capacity (concurrent requests) stays ecfg.capacity
        row_mult = self.branches
        if self.paged:
            bs = ecfg.block_size
            bpr = math.ceil(ecfg.max_len / bs)
            self.max_len = bpr * bs                  # block-aligned
            budget = (ecfg.kv_budget if ecfg.kv_budget is not None
                      else ecfg.capacity * self.max_len)
            # the scheduler enforces the block-rounded budget; the pool
            # holds max(budget, one full row) physical blocks — the extra
            # headroom exists only so an oversized request admitted into
            # an empty pool (deadlock-freedom guarantee) always fits
            budget_blocks = max(1, budget // bs)
            self.llm_pool = PagedCachePool(
                llm.cfg, ecfg.capacity * row_mult, self.max_len, bs,
                num_blocks=max(budget_blocks, bpr),
                kv_dtype=self.kv_dtype)
            # draft pools are capacity-sized (fast switching keeps every
            # row draftable); the budget-constrained pool is the LLM's
            self.ssm_pools = [
                PagedCachePool(b.cfg,
                               selector.cfg.batch_limits[j] * row_mult,
                               self.max_len, bs, kv_dtype=self.kv_dtype)
                for j, b in enumerate(self.ssms)]
            sched_budget = budget_blocks * bs
        else:
            self.max_len = ecfg.max_len
            self.llm_pool = DenseCachePool(llm.cfg, ecfg.capacity,
                                           ecfg.max_len)
            self.ssm_pools = [
                DenseCachePool(b.cfg, selector.cfg.batch_limits[j],
                               ecfg.max_len)
                for j, b in enumerate(self.ssms)]
            sched_budget = ecfg.kv_budget
        self.switcher = SwitchManager(self.ssms)
        self.cost = cost_model or P.CostModel(
            ssm_time_per_token=[1e-4 * (j + 1) for j in range(len(ssms))],
            ssm_fixed=[2e-4] * len(ssms),
            llm_fixed=1e-3, llm_time_per_token=5e-4, gamma=ecfg.gamma)
        if ecfg.replica_class not in ("general", "prefill", "decode"):
            raise ValueError(
                f"unknown replica_class {ecfg.replica_class!r} "
                "(general | prefill | decode)")
        # prefill-class replicas keep adaptive speculation shallow: their
        # verify budget belongs to prompt chunks, and requests routed here
        # are about to be handed off anyway.  Fixed policy ignores the cap
        # (bit-identity contract of --gamma-policy fixed).
        depth_cap = (max(1, math.ceil(self.gamma_max / 2))
                     if ecfg.replica_class == "prefill" else None)
        self.gamma_ctl = GammaController(
            GammaConfig(policy=ecfg.gamma_policy, gamma=ecfg.gamma,
                        gamma_max=self.gamma_max, branches=self.branches,
                        depth_cap=depth_cap),
            self.cost, selector)
        self.failed_ssms: set = set()
        self.requests: Dict[int, Request] = {}
        self.assignment: Dict[int, int] = {}
        # chunked prefill relies on segment-maskable KV appends; recurrent
        # state advances on every token and cannot mask bucket padding, so
        # those models keep monolithic admission (mirrors the paged->dense
        # auto-fallback).
        self.chunked = (ecfg.prefill_chunk > 0
                        and ecfg.scheduler_policy == "continuous"
                        and not llm.has_recurrent_state)
        self.slo_aware = ecfg.slo_aware
        self.scheduler = ContinuousScheduler(SchedulerConfig(
            capacity=ecfg.capacity, max_len=self.max_len,
            gamma=self.gamma_max,
            kv_budget=sched_budget, policy=ecfg.scheduler_policy,
            block_size=ecfg.block_size if self.paged else 0,
            prefill_chunk=ecfg.prefill_chunk if self.chunked else 0,
            token_budget=ecfg.token_budget,
            spec_branches=self.branches,
            slo_aware=ecfg.slo_aware))
        self.rng = jax.random.PRNGKey(ecfg.seed)
        # metrics
        self.sim_time = 0.0
        self.wall_time = 0.0
        self.accepted_tokens = 0
        self.total_drafted = 0
        self.verify_tokens_total = 0       # LLM verify query tokens issued
        self.tree_forks = 0                # CoW row forks (tree mode)
        self.tree_adoptions = 0            # slots won by a non-main branch
        self.prefill_tokens_total = 0
        self.slot_log: List[dict] = []
        self.straggler_redispatches = 0
        self._accept_by_req: Dict[int, List[float]] = {}
        # prefill work issued since the last slot simulation (monolithic
        # admissions and chunk appends); consumed into the next slot's
        # makespan so prompt ingestion is paid for on the sim clock
        self._prefill_tokens_pending = 0
        self._prefill_cells_pending = 0.0
        self._unstamped: set = set()       # rids awaiting first_token_time

    # ------------------------------------------------------------ admin --
    @property
    def waiting(self) -> List[Request]:
        """Arrived-but-not-admitted requests (scheduler queue view)."""
        return self.scheduler.waiting

    # ------------------------------------------------- replica-level view --
    # Load/occupancy metrics the multi-replica router (serving/router.py)
    # reads at dispatch time.  Cheap (no JAX work) and deterministic.
    def outstanding_tokens(self) -> int:
        """Token-denominated estimate of all work this engine still owes:
        for every submitted-but-unfinished request, the context still to
        ingest plus the output tokens still to emit."""
        total = 0
        pre = self.scheduler.prefilling
        for r in self.scheduler.outstanding_requests():
            emitted = len(r.emitted or [])
            total += max(0, r.max_new - max(0, emitted - 1))
            if r.rid in pre:
                total += max(0,
                             self.scheduler.prefill_target(r) - r.prefill_pos)
            elif not self.llm_pool.has(r.rid):
                # no row yet: the whole context must still be ingested
                total += self.scheduler.prefill_target(r)
        return total

    def kv_free_cells(self) -> int:
        """*Admissible* KV headroom in cells: the scheduler budget minus
        the running set's projected demand — exactly what admission
        checks.  Under paging this is additionally capped by the
        physical free-block ledger; the pool's one-full-row
        deadlock-freedom floor can hold blocks *above* the budget, and
        that headroom is not admissible, so it must not attract p2c
        dispatches."""
        demand = sum(self.scheduler.kv_need(r)
                     for r in self.scheduler.running.values())
        free = max(0, self.scheduler.kv_budget - demand)
        if self.paged:
            free = min(free,
                       self.llm_pool.free_blocks * self.ecfg.block_size)
        return free

    def kv_occupancy(self) -> float:
        """Fraction of the admissible KV budget currently committed."""
        budget = max(1, self.scheduler.kv_budget)
        return 1.0 - self.kv_free_cells() / budget

    def snapshot(self) -> EngineStats:
        """The engine's typed dispatch-time telemetry: ONE frozen object
        embedding the scheduler snapshot — the router's (and any
        benchmark's) replica view.  ``slo_headroom`` is the SpecServe
        dispatch term: slack to the most urgent outstanding deadline
        minus the estimated time to drain the current token backlog."""
        sched = self.scheduler.snapshot()
        out = self.outstanding_tokens()
        tpt = expected_time_per_token(self.sim_time, self.accepted_tokens,
                                      self.cost.llm_time_per_token)
        return EngineStats(
            sim_time=self.sim_time,
            outstanding_tokens=out,
            kv_free_cells=self.kv_free_cells(),
            kv_occupancy=self.kv_occupancy(),
            accepted_tokens=self.accepted_tokens,
            slo_headroom=slo_headroom(sched.min_deadline, self.sim_time,
                                      out, tpt),
            scheduler=sched)

    def add_requests(self, reqs: Sequence[Request]):
        """Submit requests.  Arrival timestamps on the requests are
        honoured: a request whose ``arrival`` lies in the simulated future
        stays pending until the engine clock reaches it."""
        for r in reqs:
            # worst-case KV cells this request can ever occupy: full
            # context + speculation window.  Validating here keeps every
            # later (re-)prefill in bounds — a silent out-of-range scatter
            # would corrupt the cache instead of erroring.
            need = r.prompt_len + r.max_new + self.gamma_max + 1
            if need > self.max_len:
                raise ValueError(
                    f"request {r.rid} needs up to {need} KV slots "
                    f"(prompt {r.prompt_len} + max_new {r.max_new} + "
                    f"gamma_max+1) > max_len={self.max_len}")
        self.scheduler.submit(reqs)
        self._schedule()

    def release_queued(self, rids: Optional[Sequence[int]] = None, *,
                       include_pending: bool = False) -> List[Request]:
        """Hand queued (rowless) requests off to another replica — the
        work-stealing / drain release hook.  Only waiting requests (and,
        with ``include_pending``, not-yet-arrived ones — the drain case)
        leave; row owners keep decoding here.  A released request holds
        no pool row and therefore no KV on this engine — the target
        re-prefills its context from the ``Request`` itself, so there is
        no stale cache to migrate or corrupt.  The rid is scrubbed from
        every engine-side index so fleet-level stats (which union
        ``requests`` across replicas) count it exactly once, at whichever
        replica finishes it."""
        out = self.scheduler.release_queued(rids,
                                            include_pending=include_pending)
        for r in out:
            assert not self.llm_pool.has(r.rid), \
                f"released request {r.rid} still owns a KV row"
            self.requests.pop(r.rid, None)
            self._unstamped.discard(r.rid)
            self._accept_by_req.pop(r.rid, None)
        return out

    def _schedule(self, grant_prefill: bool = False):
        """Ask the scheduler for this instant's decision and apply it:
        preemptions release rows/KV first, then admissions take rows, then
        prefill chunks are appended.  ``grant_prefill`` is True only for
        the start-of-step pass so the chunk budget is spent once per slot
        (end-of-step recycling and ``add_requests`` only move rows)."""
        dec = self.scheduler.plan(self.sim_time,
                                  grant_prefill=grant_prefill)
        for r in dec.preempt:
            self._preempt(r)
        for r in dec.admit:
            if r.first_token_time is None:
                self._unstamped.add(r.rid)
            self._begin_admit(r)
        for r, n in dec.prefill:
            self._prefill_chunk(r, n)

    @staticmethod
    def _context_tokens(r: Request) -> np.ndarray:
        """Committed context to (re-)prefill: the prompt plus emitted
        tokens except the last, which has not been fed back yet — it
        becomes the pool's last_token."""
        return np.concatenate([np.asarray(r.prompt, np.int64),
                               np.asarray(r.emitted[:-1] if r.emitted
                                          else [], np.int64)])

    def _begin_admit(self, r: Request):
        """Grant the request a pool row.  Monolithic mode prefills the
        whole context here (fresh prompt, or prompt + committed tokens
        after preemption — greedy continuation stays bit-identical to an
        uninterrupted run).  Chunked mode only takes the row; context
        arrives through :meth:`_prefill_chunk` grants."""
        self.requests[r.rid] = r
        if self.chunked:
            r.prefill_pos = 0
            self.llm_pool.insert_empty(r.rid)
            self.scheduler.mark_admitted(r, self.sim_time)
            return
        tokens = self._context_tokens(r)
        L = len(tokens)
        row = np.zeros((1, _bucket(L)), np.int32)
        row[0, :L] = tokens
        lengths = jnp.asarray([L], jnp.int32)
        # paged: prefill a cache of just the prompt's blocks — admission
        # cost is O(prompt blocks), independent of pool capacity/max_len
        plen = (self.llm_pool.prefill_len(row.shape[1]) if self.paged
                else self.max_len)
        logits, cache = self.llm.prefill(jnp.asarray(row), lengths, plen)
        last = self._first_token(r, logits, L - 1)
        self.llm_pool.insert(r.rid, cache, L, last)
        self._account_prefill(0, L)
        self.scheduler.mark_admitted(r, self.sim_time)

    def _first_token(self, r: Request, logits, idx: int) -> int:
        """The token that follows the ingested context — the emitted tail
        on re-admission, else the greedy pick at the last context
        position.  Shared by the monolithic and final-chunk paths so the
        bit-exactness contract between them cannot drift."""
        if r.emitted:
            return int(r.emitted[-1])
        last = int(jnp.argmax(logits[0, idx, :self.llm.cfg.vocab_size]))
        r.emitted = [last]
        return last

    def _account_prefill(self, pos: int, n: int):
        """Record prefill work for the next slot simulation: n query
        tokens starting at context offset pos, attending Σ (pos+i+1)
        KV cells — same affine terms as verification."""
        self._prefill_tokens_pending += n
        self._prefill_cells_pending += n * pos + n * (n + 1) / 2.0

    def _prefill_chunk(self, r: Request, n: int):
        """Append one prompt chunk into the request's existing row.  The
        chunk's queries attend the prior context plus themselves causally
        (decode-path forward), so the final logits — and therefore the
        first emitted token and the greedy continuation — are the
        monolithic prefill's.  Bucket padding carries segment -1: its KV
        writes land invalidated and one trace serves each width bucket."""
        rid = r.rid
        ctx = self._context_tokens(r)
        L = len(ctx)
        pos = r.prefill_pos
        n = min(n, L - pos)
        if n <= 0:
            return
        Tb = _bucket(n, 8)
        toks = np.zeros((1, Tb), np.int32)
        toks[0, :n] = ctx[pos:pos + n]
        segs = np.full((1, Tb), -1, np.int32)
        segs[0, :n] = 0
        lengths = jnp.asarray([pos], jnp.int32)
        if self.paged:
            self.llm_pool.ensure(rid, pos + n)
            bt = self.llm_pool.row_table(rid)
            logits, cache = self.llm.append_paged(
                self.llm_pool.cache, jnp.asarray(toks), lengths,
                jnp.asarray(segs), bt, self.fused_llm_decode)
            self.llm_pool.cache = cache
        else:
            one = self.llm_pool.row_cache(rid)
            logits, one = self.llm.append(one, jnp.asarray(toks), lengths,
                                          jnp.asarray(segs))
            self.llm_pool.write_row(rid, one)
        r.prefill_pos = pos + n
        row = self.llm_pool.row_of[rid]
        self.llm_pool.lengths[row] = r.prefill_pos
        self._account_prefill(pos, n)
        if r.prefill_pos >= L:
            self.llm_pool.last_token[row] = self._first_token(r, logits,
                                                              n - 1)
            self.scheduler.mark_prefill_done(r)

    def _preempt(self, r: Request):
        """Release the request's row and draft-pool slot; generated tokens
        stay on the Request, so nothing decoded is lost."""
        rid = r.rid
        if self.llm_pool.has(rid):
            self.llm_pool.evict(rid)
        j = self.assignment.pop(rid, None)
        if j is not None and self.ssm_pools[j].has(rid):
            self.ssm_pools[j].evict(rid)
        if hasattr(self.selector, "retire"):
            self.selector.retire(rid)
        self.gamma_ctl.retire(rid)
        self.scheduler.mark_preempted(r, self.sim_time)

    def _finish(self, r: Request):
        r.done = True
        r.finish_time = self.sim_time
        self.llm_pool.evict(r.rid)
        j = self.assignment.pop(r.rid, None)
        if j is not None and self.ssm_pools[j].has(r.rid):
            self.ssm_pools[j].evict(r.rid)
        if hasattr(self.selector, "retire"):
            self.selector.retire(r.rid)
        self.gamma_ctl.retire(r.rid)
        self.scheduler.mark_finished(r.rid)

    def fail_ssm(self, j: int):
        """Replica failure: drain its requests, zero its capacity."""
        self.failed_ssms.add(j)
        self.selector.cfg.batch_limits[j] = 0
        for rid in list(self.ssm_pools[j].row_of):
            self.ssm_pools[j].evict(rid)
            self.assignment.pop(rid, None)

    # --------------------------------------------------------- one slot --
    def _active(self) -> List[Request]:
        """Decode-ready requests: own a row AND are fully prefilled —
        prefilling rows hold partial KV and must not draft or verify."""
        pre = self.scheduler.prefilling
        return [r for r in self.requests.values()
                if not r.done and self.llm_pool.has(r.rid)
                and r.rid not in pre]

    def _consume_prefill(self):
        """(time, tokens) of prefill work issued since the last slot
        simulation; resets the pending counters."""
        toks = self._prefill_tokens_pending
        t = self.cost.prefill_time(toks, self._prefill_cells_pending)
        self.prefill_tokens_total += toks
        self._prefill_tokens_pending = 0
        self._prefill_cells_pending = 0.0
        return t, toks

    def _stamp_tokens(self, r: Request):
        """Deadline attainment source: ``token_times[j]`` is the sim-clock
        instant token j was committed — the end of the slot that paid for
        it (commit loop) or, for the prefill-born first token, the end of
        the slot that carried the prefill work (same instant
        ``first_token_time`` is stamped).  Idempotent: only missing tails
        are appended, so preempted requests keep their history."""
        if r.token_times is None:
            r.token_times = []
        while len(r.token_times) < len(r.emitted or []):
            r.token_times.append(self.sim_time)

    def _stamp_first_tokens(self):
        """TTFT: a request's first token exists once its (monolithic or
        final-chunk) prefill has been paid for on the sim clock — i.e. at
        the end of the slot that carried the work.  Only requests not yet
        stamped are scanned, so the per-slot cost tracks new first tokens
        rather than total stream history."""
        for rid in list(self._unstamped):
            r = self.requests[rid]
            if r.emitted:
                r.first_token_time = self.sim_time
                self._stamp_tokens(r)
                self._unstamped.discard(rid)

    def step(self) -> dict:
        t_wall = time.perf_counter()
        self._schedule(grant_prefill=True)
        active = self._active()
        if not active:
            nxt = self.scheduler.next_arrival()
            if nxt is not None and not self.scheduler.running:
                # pool drained: fast-forward the sim clock to the next
                # arrival and admit it
                self.sim_time = max(self.sim_time, nxt)
                self._schedule(grant_prefill=True)
                active = self._active()
        if not active:
            if self._prefill_tokens_pending > 0:
                # prefill-only slot: every row is still ingesting context;
                # the clock advances by the chunk work just issued
                pre_t, pre_n = self._consume_prefill()
                self.sim_time += pre_t
                self._stamp_first_tokens()
                self.wall_time += time.perf_counter() - t_wall
                rec = {"tokens": 0, "sim_time": pre_t, "llm_idle": 0.0,
                       "micro_batches": [], "active": 0,
                       "running": len(self.scheduler.running),
                       "queued": len(self.scheduler.waiting),
                       "prefill_tokens": pre_n}
                self.slot_log.append(rec)
                return rec
            return {"done": True}
        ids = [r.rid for r in active]
        assign = self.selector.assign(ids)

        # apply switches / placements
        for rid, j in assign.items():
            if j in self.failed_ssms:
                j = min(set(range(len(self.ssms))) - self.failed_ssms)
                assign[rid] = j
            prev = self.assignment.get(rid)
            if prev == j and self.ssm_pools[j].has(rid):
                continue
            if prev is not None and prev != j and \
                    self.ssm_pools[prev].has(rid):
                self.ssm_pools[prev].evict(rid)
            if not self.ssm_pools[j].has(rid):
                self._place_on_ssm(rid, j, assign)
            self.assignment[rid] = j

        # per-request speculation depths for this slot (goodput-aware
        # argmax on the selector's acceptance estimates; "fixed" policy:
        # the uniform ecfg.gamma).  The cap charges the prompt-chunk
        # tokens this slot's plan already granted, so decode + prefill
        # together respect the token budget; the scheduler's next
        # token-budget split costs decode slots at these granted depths.
        slo_slack = None
        if self.slo_aware:
            # seconds until each SLO-carrying request's next-token
            # deadline — the gamma controller's deadline-headroom input;
            # None/absent entries mean no deadline pressure
            slo_slack = {r.rid: r.next_deadline() - self.sim_time
                         for r in active if r.slo is not None} or None
        depths = self.gamma_ctl.grant(
            ids, assign,
            token_budget=self.ecfg.token_budget if self.chunked else None,
            reserved_tokens=self.scheduler.last_prefill_granted,
            slo_slack=slo_slack)
        # tree mode: a depth-k grant verifies k + b_eff query tokens (one
        # root copy per branch), so the step planner's token-budget split
        # must see that cost; linear b_eff = 1 keeps the k + 1 charge
        self.scheduler.set_decode_depths(
            {rid: k + self._beff(k) - 1 for rid, k in depths.items()}
            if self.tree else depths)
        if self.paged:
            # append-a-block growth: cover context + this slot's granted
            # speculation window (k_i + 1) before decode/verify writes land
            self.llm_pool.ensure_rows({
                r.rid: int(self.llm_pool.lengths[self.llm_pool.row_of[r.rid]])
                + depths[r.rid] + 1 for r in active})

        # draft on every SSM pool (static shapes at the pool's slot-max
        # depth; rows granted less contribute only their k_i-token prefix)
        drafts: Dict[int, object] = {}
        per_ssm_batch = []
        per_ssm_depth = []
        per_ssm_vextra = []
        for j, (b, pool) in enumerate(zip(self.ssms, self.ssm_pools)):
            rids = [r for r in ids if assign.get(r) == j]
            per_ssm_batch.append(len(rids))
            if not rids or j in self.failed_ssms:
                per_ssm_depth.append(float(self.cost.gamma))
                per_ssm_vextra.append(0.0)
                continue
            # ragged per-slot batch: cost covers the requests actually
            # assigned this slot at their granted depths, not the static
            # pool capacity at a uniform gamma
            per_ssm_depth.append(float(np.mean([depths[r] for r in rids])))
            per_ssm_vextra.append(float(np.mean(
                [self._beff(depths[r]) - 1 for r in rids])))
            width = max(depths[r] for r in rids)
            if self.tree:
                cand, branch_map = self._draft_pool_tree(
                    j, width, depths, rids)
                for rid in rids:
                    drafts[rid] = [cand[row, :kk]
                                   for row, kk in branch_map[rid]]
            else:
                cand = self._draft_pool(j, width, depths)
                rows = pool.rows(rids)
                for rid, row in zip(rids, rows):
                    drafts[rid] = cand[row, :depths[rid]]
        self.total_drafted += sum(depths.values())
        self.verify_tokens_total += sum(
            depths[rid] + self._beff(depths[rid]) for rid in ids)

        # verification (functional, full batch; per-row depth masking)
        n_acc, out, out_len = self._verify(ids, drafts, depths)

        # simulated slot timeline (pipeline §V-B); verification cost sees
        # the padded vs decomposed-packed KV grid size (§V-A), ragged per
        # SSM under continuous batching — and ragged draft depths under
        # the adaptive gamma policy
        accept_rates = self._accept_rates_per_ssm(assign, ids, n_acc, depths)
        kv_cells_per_req = self._kv_cells_per_ssm(assign, ids, depths)
        vextra = per_ssm_vextra if self.tree else None
        if self.ecfg.use_pipeline:
            mb = self.ecfg.micro_batches or P.choose_micro_batches(
                self.cost, per_ssm_batch, accept_rates,
                kv_cells_per_req=kv_cells_per_req,
                depth_per_req=per_ssm_depth,
                verify_extra_per_req=vextra)[0]
        else:
            mb = [1] * len(self.ssms)
        # mixed slot: chunk-prefill work issued this step (and monolithic
        # admissions since the last slot) occupies the LLM ahead of the
        # verify queue while SSMs draft concurrently
        pre_t, pre_n = self._consume_prefill()
        slot = self._simulate_slot(per_ssm_batch, mb, kv_cells_per_req,
                                   prefill_time=pre_t,
                                   depth_per_req=per_ssm_depth,
                                   verify_extra_per_req=vextra)

        # commit tokens, update request state, observe goodput + acceptance
        self.sim_time += slot.makespan
        slot_tokens = 0
        observe_accept = getattr(self.selector, "observe_accept", None)
        for i, rid in enumerate(ids):
            r = self.requests[rid]
            k = int(out_len[i])
            r.emitted.extend(int(x) for x in out[i, :k])
            self._stamp_tokens(r)
            slot_tokens += k
            g = k / max(slot.makespan, 1e-9)
            self.selector.observe(rid, assign[rid], g)
            # per-token acceptance estimate: successes over positions
            # actually tested — the accept chain stops at the first
            # rejection, so n_acc/k would bias deep grants low (a
            # truncated-geometric mean) and collapse adaptive depths
            tested = min(depths[rid], int(n_acc[i]) + 1)
            rate = float(n_acc[i]) / tested
            if observe_accept is not None:
                observe_accept(rid, assign[rid], rate)
            self._accept_by_req.setdefault(rid, []).append(rate)
            if len(r.emitted) - 1 >= r.max_new:
                self._finish(r)
        self.accepted_tokens += slot_tokens
        self._stamp_first_tokens()
        self.wall_time += time.perf_counter() - t_wall

        # fast-switching prediction for next slot (§IV-C)
        self._precompute_switches(ids)
        # recycle rows freed by finished requests within the SAME step:
        # queued arrivals are admitted into them before the slot returns
        self._schedule()

        rec = {"tokens": slot_tokens, "sim_time": slot.makespan,
               "llm_idle": slot.llm_idle_frac, "micro_batches": mb,
               "active": len(ids),
               "running": len(self.scheduler.running),
               "queued": len(self.scheduler.waiting),
               "prefill_tokens": pre_n}
        self.slot_log.append(rec)
        return rec

    # ---------------------------------------------------------- internals --
    def _switch_width(self, j: int, length: int) -> int:
        """Cache width for switch prefills/precomputes on SSM j.  Paged
        pools only need the context's blocks (plus a gamma_max+1 growth
        margin so a next-slot switch still hits at any granted depth) —
        O(context), not the capacity-proportional max_len the dense layout
        requires."""
        if not self.paged:
            return self.max_len
        need = min(self.max_len, length + self.gamma_max + 1)
        return self.ssm_pools[j].prefill_len(_bucket(need))

    def _place_on_ssm(self, rid: int, j: int, current):
        """Switch-place ``rid`` on SSM j's pool.  ``current`` is this
        slot's full assignment map: residents NOT placed here this slot
        are the eviction candidates (a resident may still carry a stale
        ``self.assignment`` entry while it moves away later in the same
        placement pass)."""
        r = self.requests[rid]
        tokens = np.concatenate([np.asarray(r.prompt),
                                 np.asarray(r.emitted[:-1], np.int64)])
        length = len(tokens)
        cache, _ = self.switcher.switch(rid, j, tokens, length,
                                        self._switch_width(j, length))
        pool = self.ssm_pools[j]
        while not pool.can_admit(length):
            # evict someone not assigned here this slot (frees the row
            # and, under paging, its blocks)
            victim = next((rr for rr in pool.row_of
                           if current.get(rr) != j), None)
            if victim is None:
                raise RuntimeError(
                    f"SSM {j} draft pool over-committed: all "
                    f"{len(pool.row_of)} residents are assigned here this "
                    f"slot — selector batch_limits[{j}] exceeds the pool")
            pool.evict(victim)
        pool.insert(rid, cache, length, r.emitted[-1])

    def _precompute_switches(self, ids):
        if not hasattr(self.selector, "predicted_destination"):
            return
        for rid in ids:
            if rid not in self.requests or self.requests[rid].done:
                continue
            dst = self.selector.predicted_destination(rid)
            if dst == self.assignment.get(rid) or dst in self.failed_ssms:
                continue
            r = self.requests[rid]
            tokens = np.concatenate([np.asarray(r.prompt),
                                     np.asarray(r.emitted[:-1], np.int64)])
            self.switcher.precompute(rid, dst, tokens, len(tokens),
                                     self._switch_width(dst, len(tokens)))

    def _draft_pool(self, j: int, width: int, depths) -> np.ndarray:
        """Draft ``width`` tokens (the slot-max granted depth on this SSM)
        for every row of SSM j's pool; returns (capacity, width)
        candidates — callers take each row's granted k_i-prefix.  Inactive
        rows are drafted too (static shape); dense rows are re-invalidated
        afterwards, paged idle rows own no blocks so their writes are
        dropped at the source."""
        b = self.ssms[j]
        pool = self.ssm_pools[j]
        lengths = jnp.asarray(pool.lengths, jnp.int32)
        tok = jnp.asarray(pool.last_token, jnp.int32)[:, None]
        self.rng, k = jax.random.split(self.rng)
        if self.paged:
            # cover draft writes (ctx..ctx+k_i-1) and the catch-up hole
            # fill (ctx+1..ctx+k_i+1) before any decode lands
            pool.ensure_rows({
                rid: int(pool.lengths[row]) + depths.get(rid, width) + 2
                for rid, row in pool.row_of.items()})
            bt, _ = pool.block_table_array()
            cand, _, cache = sd.draft(b, pool.cache, tok, lengths,
                                      width, k, block_tables=bt,
                                      fused_cfg=self.fused_ssm_decode[j])
            pool.cache = cache
            return np.asarray(cand)
        cand, _, cache = sd.draft(b, pool.cache, tok, lengths,
                                  width, k)
        pool.cache = cache
        idle = [row for row in range(pool.capacity)
                if row not in pool.row_of.values()]
        pool.invalidate_rows(idle)
        return np.asarray(cand)

    # ----------------------------------------------------- tree helpers --
    @staticmethod
    def _brid(rid: int, j: int):
        """Synthetic pool key for branch j of request rid — tuples never
        collide with real (integer) request ids."""
        return ("~branch", rid, j)

    def _beff(self, k) -> int:
        """Effective branch count of a depth-k grant: every branch drafts
        at least one token, so min(branches, k); 1 in linear mode."""
        return max(1, min(self.branches, int(k))) if self.tree else 1

    def _draft_pool_tree(self, j: int, width: int, depths, rids):
        """Tree drafting on SSM j: fork a CoW pool row per extra branch,
        draft every row greedily with per-row first-step top-k ranks
        (identical context in forked rows means identical step-1 logits,
        so each row self-selects its branch without cross-row
        communication), then evict the fork rows — their chains live on
        as verify candidates, and accepted tokens re-enter the main row
        via the catch-up decode.  Returns (cand (capacity, width),
        branch_map: rid -> [(row, k_j), ...] branch-ordered)."""
        b = self.ssms[j]
        pool = self.ssm_pools[j]
        # cover draft writes + catch-up hole on the resident (main) rows
        pool.ensure_rows({
            rid: int(pool.lengths[row]) + depths.get(rid, width) + 2
            for rid, row in pool.row_of.items()})
        # stale switch residents may hold rows the forks need: the pool
        # has batch_limits * branches rows, so evicting non-assigned
        # residents always frees enough
        need = sum(self._beff(depths[rid]) - 1 for rid in rids)
        free = pool.capacity - len(pool.row_of)
        if free < need:
            keep = set(rids)
            for victim in [r for r in pool.row_of if r not in keep]:
                pool.evict(victim)
                free += 1
                if free >= need:
                    break
        branch_map = {}
        forked = []
        for rid in rids:
            bd = D.split_tree_depths(depths[rid], self.branches)
            L = int(pool.lengths[pool.row_of[rid]])
            entries = [(pool.row_of[rid], bd[0])]
            for jj in range(1, len(bd)):
                brid = self._brid(rid, jj)
                entries.append((pool.fork(rid, brid), bd[jj]))
                forked.append(brid)
            if len(bd) > 1:
                for jj in range(1, len(bd)):
                    pool.cow_prepare(self._brid(rid, jj), L, L + width + 2)
                pool.cow_prepare(rid, L, L + width + 2)
            branch_map[rid] = entries
        ranks = np.zeros(pool.capacity, np.int32)
        for rid in rids:
            for bi, (row, _) in enumerate(branch_map[rid]):
                ranks[row] = bi
        lengths = jnp.asarray(pool.lengths, jnp.int32)
        tok = jnp.asarray(pool.last_token, jnp.int32)[:, None]
        # keep the rng stream aligned with the linear draft path
        self.rng, _ = jax.random.split(self.rng)
        bt, _ = pool.block_table_array()
        cand, cache = sd.draft_tree(b, pool.cache, tok, lengths, width,
                                    ranks, block_tables=bt,
                                    fused_cfg=self.fused_ssm_decode[j])
        pool.cache = cache
        for brid in forked:
            pool.evict(brid)
        return np.asarray(cand), branch_map

    def _tree_block_maps(self, ids_np, owner_np, tree_rows, W: int):
        """Per-slot tree metadata for the packed gather: block owners of
        branch rows remap to the request's main row (the verify segment),
        and every gathered KV slot gets a tree-node tag — -1 committed
        (attendable via segment + causality alone), -2 dead (a branch's
        CoW copy of committed straddle cells, which would otherwise be
        softmax-counted twice, or a padding slot past the branch's
        depth), n >= 0 a tree node attendable only by queries whose
        ancestor bitmask has bit n set."""
        pool = self.llm_pool
        bs = pool.block_size
        seg_of_row = {row: seg for row, (seg, _, _) in tree_rows.items()}
        owner_seg = np.array(
            [seg_of_row.get(int(o), int(o)) if o >= 0 else -1
             for o in owner_np], np.int32)
        id2m = {int(blk): m for m, blk in enumerate(ids_np)
                if owner_np[m] >= 0}
        node = np.full((len(ids_np), bs), -1, np.int32)
        for row, (seg_row, off, k) in tree_rows.items():
            L = int(pool.lengths[row])
            nb = int(pool._nb[row])
            if row != seg_row and L % bs:
                # branch rows own a private copy of the straddling tail
                # block; its committed cells [L - L%bs, L) duplicate the
                # main row's originals — dead-tag the copies
                bi0 = L // bs
                if bi0 < nb:
                    m = id2m.get(int(pool._table[row, bi0]))
                    if m is not None:
                        node[m, :L % bs] = -2
            for d in range(W + 1):
                p = L + d
                bi = p // bs
                if bi >= nb:
                    break        # writes past the table were dropped
                m = id2m.get(int(pool._table[row, bi]))
                if m is None:
                    continue
                node[m, p % bs] = (off + d) if d <= k else -2
        return owner_seg, node

    def _verify(self, ids, drafts, depths):
        """LLM verification over the full pool (padded or packed).

        ``depths`` maps request -> granted speculation depth.  The forward
        runs at the slot's max depth W (static shape per W; at most
        gamma_max distinct traces); rows granted less carry zero-padded
        candidate tails whose match is masked out, so a row can never
        accept beyond its grant, and whose speculative KV writes land in
        the rollback scrub window like any rejected draft."""
        W = max(depths[rid] for rid in ids)
        N = self.llm_pool.capacity
        # tree mode: fork a CoW row per extra branch BEFORE capturing the
        # pool arrays — each branch verifies its own root copy + chain
        # through its own (prefix-shared) block table
        fork_rows: Dict[int, list] = {}
        tree_rows = None
        if self.tree:
            tree_rows = {}
            for rid in ids:
                bd = D.split_tree_depths(depths[rid], self.branches)
                mrow = self.llm_pool.row_of[rid]
                L = int(self.llm_pool.lengths[mrow])
                lst = []
                for jj in range(1, len(bd)):
                    brid = self._brid(rid, jj)
                    brow = self.llm_pool.fork(rid, brid)
                    lst.append((jj, brid, brow))
                    self.tree_forks += 1
                if lst:
                    # un-share the speculation window: every branch (and
                    # the main row, last so it keeps the originals) writes
                    # through private block copies
                    for jj, brid, brow in lst:
                        self.llm_pool.cow_prepare(brid, L, L + W + 2)
                    self.llm_pool.cow_prepare(rid, L, L + W + 2)
                fork_rows[rid] = lst
                tree_rows[mrow] = (mrow, 0, bd[0])
                off = bd[0] + 1
                for jj, brid, brow in lst:
                    tree_rows[brow] = (mrow, off, bd[jj])
                    off += bd[jj] + 1
        cand = np.zeros((N, W), np.int32)
        k_row = np.zeros(N, np.int64)
        lengths = jnp.asarray(self.llm_pool.lengths, jnp.int32)
        last = jnp.asarray(self.llm_pool.last_token, jnp.int32)[:, None]
        rows = self.llm_pool.rows(ids)
        for rid, row in zip(ids, rows):
            if self.tree:
                bd = D.split_tree_depths(depths[rid], self.branches)
                chains = drafts.get(
                    rid, [np.zeros(kk, np.int32) for kk in bd])
                cand[row, :len(chains[0])] = chains[0]
                k_row[row] = bd[0]
                for (jj, brid, brow) in fork_rows[rid]:
                    cand[brow, :len(chains[jj])] = chains[jj]
                    k_row[brow] = bd[jj]
            else:
                d = drafts.get(rid, np.zeros(depths[rid], np.int32))
                cand[row, :len(d)] = d
                k_row[row] = depths[rid]
        cand = jnp.asarray(cand)

        if self.ecfg.use_packed_verify:
            logits = self._verify_packed(cand, lengths, last, W,
                                         tree_rows=tree_rows)
        else:
            inp = jnp.concatenate([last, cand], axis=1)
            if self.paged:
                bt, _ = self.llm_pool.block_table_array()
                logits, cache = self.llm.decode_paged(
                    self.llm_pool.cache, inp, lengths, bt,
                    self.fused_llm_decode)
            else:
                logits, cache = self.llm.decode(self.llm_pool.cache, inp,
                                                lengths)
            self.llm_pool.cache = cache
        V = self.llm.cfg.vocab_size
        greedy = jnp.argmax(logits.astype(jnp.float32)[..., :V],
                            axis=-1).astype(jnp.int32)
        # per-row depth mask: positions at or beyond a row's grant can
        # never match (they hold padding, not drafts)
        in_depth = jnp.arange(W)[None] < jnp.asarray(k_row, jnp.int32)[:, None]
        match = (greedy[:, :W] == cand) & in_depth
        n_acc_all = jnp.sum(jnp.cumprod(match.astype(jnp.int32), 1), 1)
        idx = jnp.arange(W + 1)[None]
        out_all = jnp.where(idx < n_acc_all[:, None],
                            jnp.pad(cand, ((0, 0), (0, 1))), 0)
        bonus = jnp.take_along_axis(greedy, n_acc_all[:, None], axis=1)
        out_all = out_all.at[jnp.arange(N), n_acc_all].set(bonus[:, 0])

        # tree: adopt the winning branch per request — the row with the
        # longest accepted root-to-leaf path keeps the request id (its CoW
        # copies become canonical); losers are evicted in O(branches),
        # dropping refs so shared prefix blocks survive via the winner.
        # Under greedy verification at most one branch accepts >= 1 token
        # (branches differ at their first draft and only the one matching
        # the LLM argmax can accept), so ties land on branch 0 and the
        # bonus token is the LLM's own pick — lossless at any shape.
        winner_row = {rid: row for rid, row in zip(ids, rows)}
        if self.tree:
            n_acc_np = np.asarray(n_acc_all)
            for rid in ids:
                best_j, best_row = 0, winner_row[rid]
                for (jj, brid, brow) in fork_rows[rid]:
                    if int(n_acc_np[brow]) > int(n_acc_np[best_row]):
                        best_j, best_row = jj, brow
                if best_j != 0:
                    self.llm_pool.evict(rid)
                    self.llm_pool.rename(self._brid(rid, best_j), rid)
                    self.tree_adoptions += 1
                for (jj, brid, brow) in fork_rows[rid]:
                    if jj != best_j:
                        self.llm_pool.evict(brid)
                winner_row[rid] = best_row

        # rollback: keep accepted prefix only (paged: trim the tail block
        # in place — a W-wide seg scatter through the block table)
        if self.paged:
            self.llm_pool.invalidate_span(lengths + 1 + n_acc_all,
                                          lengths + W + 1, W=W)
        else:
            self.llm_pool.cache = sd.invalidate_slots_jit(
                self.llm_pool.cache, lengths + 1 + n_acc_all,
                lengths + W + 1)
            self.llm_pool.invalidate_rows(
                [row for row in range(N)
                 if row not in self.llm_pool.row_of.values()])
        # prefilling rows are live pool rows but take no part in this
        # verify: the full-pool forward still wrote speculative KV at
        # their positions [len, len+W+1) — scrub all of it, or a later
        # chunk landing below those positions would leave stale
        # attendable garbage beyond the context
        pre_rows = [self.llm_pool.row_of[rid]
                    for rid in self.scheduler.prefilling
                    if rid in self.llm_pool.row_of]
        if pre_rows:
            lo = np.zeros(N, np.int64)
            hi = np.zeros(N, np.int64)
            lens_now = np.asarray(self.llm_pool.lengths, np.int64)
            for row in pre_rows:
                lo[row] = lens_now[row]
                hi[row] = lens_now[row] + W + 1
            if self.paged:
                self.llm_pool.invalidate_span(
                    jnp.asarray(lo, jnp.int32), jnp.asarray(hi, jnp.int32),
                    W=W + 1)
            else:
                self.llm_pool.cache = sd.invalidate_slots_jit(
                    self.llm_pool.cache, jnp.asarray(lo, jnp.int32),
                    jnp.asarray(hi, jnp.int32))

        # per-SSM catch-up (fill the c_k hole) + rollback on draft pools
        for j, pool in enumerate(self.ssm_pools):
            if not pool.row_of:
                continue
            pl = jnp.asarray(pool.lengths, jnp.int32)
            outs_j = np.zeros((pool.capacity, W + 1), np.int32)
            nacc_j = np.zeros(pool.capacity, np.int64)
            for rid, row in pool.row_of.items():
                lrow = self.llm_pool.row_of.get(rid)
                if lrow is None:
                    continue
                outs_j[row] = np.asarray(out_all[lrow])
                nacc_j[row] = int(n_acc_all[lrow])
            if self.paged:
                bt, _ = pool.block_table_array()
                _, pool.cache = self.ssms[j].decode_paged(
                    pool.cache, jnp.asarray(outs_j), pl + 1, bt,
                    self.fused_ssm_decode[j])
                pool.invalidate_span(
                    pl + 2 + jnp.asarray(nacc_j, jnp.int32),
                    pl + W + 3, W=W + 1)
            else:
                _, pool.cache = self.ssms[j].decode(
                    pool.cache, jnp.asarray(outs_j), pl + 1)
                pool.cache = sd.invalidate_slots_jit(
                    pool.cache, pl + 2 + jnp.asarray(nacc_j, jnp.int32),
                    pl + W + 3)

        # update lengths / last tokens on pools
        n_acc = np.zeros(len(ids), np.int64)
        out = np.zeros((len(ids), W + 1), np.int64)
        out_len = np.zeros(len(ids), np.int64)
        for i, rid in enumerate(ids):
            row = winner_row[rid]
            n_acc[i] = int(n_acc_all[row])
            out[i] = np.asarray(out_all[row])
            out_len[i] = n_acc[i] + 1
            self.llm_pool.lengths[row] += out_len[i]
            self.llm_pool.last_token[row] = out[i, n_acc[i]]
            j = self.assignment[rid]
            srow = self.ssm_pools[j].row_of[rid]
            self.ssm_pools[j].lengths[srow] += out_len[i]
            self.ssm_pools[j].last_token[srow] = out[i, n_acc[i]]
        return n_acc, out, out_len

    def _verify_packed(self, cand, lengths, last, W: int, tree_rows=None):
        """Packed verification via request decomposition (§V-A) at the
        slot's max granted depth W.  Paged: the packed KV is the cohort's
        live blocks, gathered fragment-by-fragment from the pool — no flat
        packed copy, no padded grid.  ``tree_rows`` (tree mode) maps pool
        row -> (main row, node offset, branch depth): the query layout
        gains ancestor bitmasks, gathered slots gain node tags, and block
        owners remap to the main row so branches attend the shared
        prefix."""
        N = self.llm_pool.capacity
        if self.paged:
            bt, _ = self.llm_pool.block_table_array()
            ids_np, owner_np = self.llm_pool.live_blocks()
            lens_np = np.asarray(self.llm_pool.lengths, np.int64)
            inp = jnp.concatenate([last, cand], axis=1)   # (N, W+1)
            if tree_rows is not None:
                q_rows, q_pos, q_seg, q_anc = D.build_tree_row_layout(
                    lens_np, W, tree_rows)
                owner_np, block_node = self._tree_block_maps(
                    ids_np, owner_np, tree_rows, W)
                logits, cache = self.llm.verify_paged_tree(
                    self.llm_pool.cache, inp.reshape(1, -1),
                    jnp.asarray(q_pos.astype(np.int32)),
                    jnp.asarray(q_seg), jnp.asarray(q_rows), bt,
                    jnp.asarray(ids_np), jnp.asarray(owner_np),
                    jnp.asarray(q_anc), jnp.asarray(block_node),
                    self.fused_llm_verify)
            else:
                q_rows, q_pos, q_seg = D.build_query_layout(lens_np, W)
                logits, cache = self.llm.verify_paged(
                    self.llm_pool.cache, inp.reshape(1, -1),
                    jnp.asarray(q_pos.astype(np.int32)),
                    jnp.asarray(q_seg), jnp.asarray(q_rows), bt,
                    jnp.asarray(ids_np), jnp.asarray(owner_np),
                    self.fused_llm_verify)
            self.llm_pool.cache = cache
            return logits[0].reshape(N, W + 1, -1)
        lens_np = np.maximum(np.asarray(lengths), 1)
        plan = D.plan_decomposition(
            [int(n) for n in lens_np],
            align=min(128, _bucket(int(lens_np.max()), 16)))
        # bucket the packed size to bound retraces
        total_b = _bucket(plan.total, self.ecfg.packed_bucket)
        gb = np.zeros(total_b, np.int32)
        gs = np.zeros(total_b, np.int32)
        valid = np.zeros(total_b, bool)
        gb[:plan.total] = plan.gather_b
        gs[:plan.total] = plan.gather_s
        valid[:plan.total] = plan.valid
        self.last_plan = plan
        q_rows, q_pos, q_seg = D.build_query_layout(
            [int(n) for n in lens_np], W)
        override = D.make_attn_override(gb, gs, valid, q_rows)
        inp = jnp.concatenate([last, cand], axis=1)          # (N, W+1)
        tokens_flat = inp.reshape(1, -1)
        logits, cache = T.verify_step_packed(
            self.llm.params, self.llm.cfg, self.llm_pool.cache,
            tokens=tokens_flat, positions=jnp.asarray(q_pos),
            segments=jnp.asarray(q_seg), attn_override=override)
        self.llm_pool.cache = cache
        return logits[0].reshape(N, W + 1, -1)

    def _kv_cells_per_ssm(self, assign, ids, depths):
        """Attended KV cells per request, per SSM, for the timing model.

        Continuous batching makes per-slot batches ragged: requests on one
        SSM have genuinely different context lengths.  Padded verification
        attends the uniform max-length grid (a scalar, same for every
        SSM); packed verification attends each request's true context,
        normalised so the total matches the decomposition plan's packed
        cell count (alignment overhead included)."""
        if not ids:
            return 0.0
        gamma = max(depths[rid] for rid in ids)
        if self.paged:
            # attended cells are block-granular: a request costs its
            # allocated blocks (live context rounded up to whole blocks)
            raw = {rid: float(self.llm_pool.allocated_cells(rid))
                   for rid in ids}
            if not self.ecfg.use_packed_verify:
                # padded paged decode attends the bucketed widest table
                return float(max(raw.values()))
            cells = []
            for j in range(len(self.ssms)):
                vals = [raw[rid] for rid in ids if assign.get(rid) == j]
                cells.append(float(np.mean(vals)) if vals else 0.0)
            return cells
        if not (self.ecfg.use_packed_verify and hasattr(self, "last_plan")):
            return float(np.max(self.llm_pool.lengths)) + gamma + 1
        raw = {rid: float(self.llm_pool.lengths[self.llm_pool.row_of[rid]])
               + gamma + 1 for rid in ids}
        scale = self.last_plan.total / max(1.0, sum(raw.values()))
        cells = []
        for j in range(len(self.ssms)):
            vals = [raw[rid] * scale for rid in ids if assign.get(rid) == j]
            cells.append(float(np.mean(vals)) if vals else 0.0)
        return cells

    def _accept_rates_per_ssm(self, assign, ids, n_acc, depths):
        rates = []
        for j in range(len(self.ssms)):
            vals = [n_acc[i] / depths[rid] for i, rid in enumerate(ids)
                    if assign.get(rid) == j]
            rates.append(float(np.mean(vals)) if vals else 0.5)
        return rates

    def _simulate_slot(self, per_ssm_batch, mb, kv_cells_per_req=0.0,
                       prefill_time: float = 0.0,
                       depth_per_req=None,
                       verify_extra_per_req=None) -> P.SimResult:
        cost = self.cost
        if self.ecfg.straggler_mitigation:
            cost = self._with_straggler_mitigation(cost, per_ssm_batch)
        return P.simulate(cost, per_ssm_batch, mb, kv_cells_per_req,
                          prefill_time=prefill_time,
                          depth_per_req=depth_per_req,
                          verify_extra_per_req=verify_extra_per_req)

    def _with_straggler_mitigation(self, cost, per_ssm_batch):
        """Inject random stragglers; mitigation re-dispatches the straggling
        micro-batch to the fastest live SSM (bounded delay)."""
        jitter = np.random.default_rng(len(self.slot_log)).exponential(
            1.0, len(self.ssms))
        slow = jitter > self.ecfg.straggler_factor
        if not slow.any():
            return cost
        per_tok = list(cost.ssm_time_per_token)
        fastest = float(min(t for j, t in enumerate(per_tok)
                            if j not in self.failed_ssms))
        for j in range(len(per_tok)):
            if slow[j] and per_ssm_batch[j] > 0:
                self.straggler_redispatches += 1
                # re-dispatch: pay the fastest replica's time + small penalty
                per_tok[j] = fastest * 1.5
        return dataclasses.replace(cost, ssm_time_per_token=per_tok)

    # ------------------------------------------------------------- runs --
    def run(self, max_slots: int = 1000) -> dict:
        for _ in range(max_slots):
            rec = self.step()
            if rec.get("done") and not self.scheduler.outstanding:
                break
        return self.stats()

    def stats(self) -> dict:
        lat = [r.latency for r in self.requests.values()
               if r.latency is not None]
        ttft = [r.first_token_time - r.arrival
                for r in self.requests.values()
                if r.first_token_time is not None]
        summ = slo_summary(self.requests.values())
        return {
            "slo_aware": self.slo_aware,
            "slo": {**summ.asdict(),
                    "goodput_under_slo":
                        summ.goodput_under_slo(self.sim_time)},
            "kv_layout": "paged" if self.paged else "dense",
            "kv_blocks": (self.llm_pool.num_blocks if self.paged else None),
            "prefill_chunk": (self.ecfg.prefill_chunk if self.chunked
                              else 0),
            "spec_shape": "tree" if self.tree else "linear",
            "fused_kernels": "on" if self.fused else "off",
            "kv_dtype": self.kv_dtype,
            "spec_branches": self.branches,
            "verify_tokens": self.verify_tokens_total,
            "tree_forks": self.tree_forks,
            "tree_adoptions": self.tree_adoptions,
            "gamma": self.gamma_ctl.stats,
            "accepted_tokens": self.accepted_tokens,
            "prefill_tokens": self.prefill_tokens_total,
            "sim_time": self.sim_time,
            "wall_time": self.wall_time,
            "goodput_sim": self.accepted_tokens / max(self.sim_time, 1e-9),
            "ttft_p50": float(np.percentile(ttft, 50)) if ttft else 0.0,
            "ttft_p95": float(np.percentile(ttft, 95)) if ttft else 0.0,
            "drafted": self.total_drafted,
            "switch": self.switcher.stats,
            "scheduler": self.scheduler.stats,
            "mean_latency": float(np.mean(lat)) if lat else 0.0,
            "p95_latency": float(np.percentile(lat, 95)) if lat else 0.0,
            "straggler_redispatches": self.straggler_redispatches,
            "mean_accept": float(np.mean([
                np.mean(v) for v in self._accept_by_req.values()]))
            if self._accept_by_req else 0.0,
        }
