"""Continuous-batching request scheduler for the SPIN engine.

The seed engine stepped one fixed cohort of requests per time slot: rows of
the LLM ``CachePool`` were filled once up front and capacity idled as
requests finished.  Under *serving* conditions (streaming arrivals, mixed
lengths, a finite KV budget) that throws away exactly the goodput the
paper's mechanisms buy — SpecInfer / SpecServe-style systems integrate
speculative decoding with a continuous-batching scheduler for this reason.

This module is the policy half of that scheduler; ``serving/engine.py``
owns the mechanics (prefill, cache eviction).  Per time slot the engine
calls :meth:`ContinuousScheduler.plan` with the current simulated clock and
applies the returned decision:

* **arrivals** — submitted requests carry an ``arrival`` timestamp
  (Poisson or trace-driven, see ``data/workloads.py``); they become
  admissible only once the engine clock reaches it.
* **admission** — waiting requests are admitted by rank
  ``(next_deadline, priority, arrival, rid)`` into free ``CachePool``
  rows: deadline-closest-first for requests carrying an SLO contract
  (``Request.slo``), then lower priority value = more urgent (like a
  nice level), then FIFO by arrival.  Requests without an SLO have an
  infinite deadline, so a contract-free stream reproduces the pre-SLO
  ``(priority, arrival, rid)`` order — and default priority 0 everywhere
  reproduces plain FIFO-by-arrival — exactly.
* **chunked prefill** (``prefill_chunk > 0``) — an admitted request does
  not prefill its whole prompt in one monolithic pass.  It enters a
  ``prefilling`` lifecycle state (owns a row, holds partial KV, does not
  draft yet) and :meth:`plan` grants it prompt *chunks* under a per-slot
  **token budget** that is shared with decode work: each decode-active
  request costs ``gamma + 1`` LLM query tokens, and whatever remains of
  ``token_budget`` is handed to prefilling requests in rank order, at most
  ``prefill_chunk`` tokens each (Sarathi-style mixed batches).  With
  ``prefill_chunk == 0`` (default) admission prefills monolithically as
  before.
* **recycling** — rows of finished requests are freed inside the engine
  step; the end-of-step ``plan`` immediately re-fills them, so a row never
  idles across a slot boundary while work is queued.
* **preemption** — when the projected KV demand of the running set exceeds
  ``kv_budget`` cells, victims are chosen farthest-from-deadline-first
  (then lowest-priority, ties by latest arrival) and re-enqueued for
  re-prefill — a request already pressed against its deadline is never
  sacrificed for a same-priority request with slack.  At least
  ``min_running`` requests always keep their rows, and an empty pool
  always admits, so the engine can never deadlock at full capacity.

Progress guarantees with chunking: a preempted prefilling request loses
its partial KV (blocks are freed) and restarts from chunk zero on
re-admission; the oldest ``min_running`` row owners are never preempted,
and when no request is decode-active the top-ranked prefilling request is
always granted a chunk even if ``token_budget`` would deny it — the
chunked analogue of the empty-pool admission rule, without which an idle
step would make no progress at all.

The ``static`` policy reproduces the seed behaviour (admit a cohort only
when the pool has fully drained, monolithic prefill) and is kept as the
baseline that ``benchmarks/bench_serving.py`` compares against.

Invariants (the contract the engine relies on; previously stated only in
PR descriptions):

* **Row ownership** — ``running`` holds exactly the requests that own an
  engine pool row (``prefilling`` is a subset of it: row granted, partial
  KV, not drafting).  A request is in at most one of pending / waiting /
  running at any instant, and moves only through the ``mark_*``
  acknowledgements — the scheduler never mutates engine state itself.
* **Budget accounting units** — ``kv_budget`` and :meth:`kv_need` are in
  KV *cells*; with ``block_size > 0`` (paged layout) demand is rounded up
  to whole blocks first, so the budget the policy enforces equals the
  physical blocks the pool holds (``kv_budget // block_size``) — an
  enforced invariant, not a model.  ``token_budget`` and
  :meth:`decode_cost` are in per-slot LLM *query tokens* (a decode slot
  costs its granted depth ``k_i + 1``; a chunk costs its tokens) — the
  two budgets are different currencies and never mix.
* **Speculation margins** — admission and preemption project each
  request at ``ctx + gamma + 1`` cells, where ``cfg.gamma`` is the
  engine's *worst-case* depth (``gamma_max`` under the adaptive
  controller): context plus the deepest draft window plus the
  bonus/correction token.  The engine writes speculative KV at exactly
  ``[ctx, ctx + k_i + 1)`` each slot, so a request the scheduler keeps
  admitted can never scatter out of budget.
"""

from __future__ import annotations

import bisect
import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.data.workloads import Request
from repro.serving.stats import SchedulerStats, min_outstanding_deadline

POLICIES = ("continuous", "static")


@dataclasses.dataclass(kw_only=True)
class SchedulerConfig:
    """Keyword-only on purpose: fields are appended as the scheduler grows
    (chunking, priorities) and positional construction would silently shift
    arguments."""
    capacity: int                      # LLM pool rows
    max_len: int = 256
    # maximum speculation window (KV headroom + default decode-token cost).
    # With the adaptive gamma controller this is ``gamma_max``: admission
    # reserves the worst-case window, while the *per-slot token budget* is
    # costed from the actually-granted depths (``set_decode_depths``).
    gamma: int = 4
    kv_budget: Optional[int] = None    # total KV cells; None -> cap*max_len
    policy: str = "continuous"
    min_running: int = 1               # never preempt below this
    # paged layout: KV is allocated in whole blocks, so demand is accounted
    # in block-rounded cells and the budget is the physical block pool —
    # an enforced invariant, not a model.  0 = cell-granular (dense layout).
    block_size: int = 0
    # chunked prefill (continuous policy only): max prompt tokens ingested
    # per request per slot.  0 = monolithic prefill-on-admit.
    prefill_chunk: int = 0
    # per-slot LLM query-token budget shared between decode slots
    # (gamma+1 tokens each) and prefill chunks.  None = decode always
    # proceeds and every prefilling request gets a full chunk.
    token_budget: Optional[int] = None
    # tree speculation: each extra branch forks the request's row
    # copy-on-write, which can copy the straddling tail block plus the
    # branch's share of the speculation window — kv_need reserves that
    # worst case per extra branch so admission cannot over-commit the
    # block pool.  1 = linear (no reservation).
    spec_branches: int = 1
    # honour per-request SLO contracts (Request.slo): admission ranks
    # deadline-closest-first, preemption victims are farthest-from-
    # deadline-first, and the token-budget split sizes prefill chunks
    # against TTFT slack.  False ignores contracts entirely (the
    # deadline-blind baseline); either way, requests WITHOUT an SLO rank
    # exactly by the pre-SLO (priority, arrival, rid) key, so a stream
    # with no contracts is bit-identical under both settings.
    slo_aware: bool = True

    @classmethod
    def from_args(cls, args, *, capacity: Optional[int] = None,
                  kv_budget: Optional[int] = None) -> "SchedulerConfig":
        """Build from a ``launch.serve.build_parser()`` namespace — the
        one flag->config translation tests and benchmarks reuse instead
        of re-deriving fields by hand.  ``capacity``/``kv_budget``
        override the flags (the router splits aggregates per replica).
        ``gamma`` is the engine's WORST-CASE depth (gamma_max under the
        adaptive policy) — the same resolution ``SpinEngine`` applies."""
        gamma = int(getattr(args, "gamma", 4))
        if getattr(args, "gamma_policy", "fixed") == "fixed":
            gmax = gamma
        else:
            gmax = getattr(args, "gamma_max", None)
            gmax = int(gmax) if gmax is not None else 2 * gamma
        paged = getattr(args, "kv_layout", "paged") == "paged"
        branches = (int(getattr(args, "spec_branch", 1))
                    if getattr(args, "spec_shape", "linear") == "tree"
                    else 1)
        return cls(
            capacity=int(capacity if capacity is not None
                         else getattr(args, "capacity", None)
                         or getattr(args, "requests", 8)),
            gamma=gmax,
            kv_budget=(kv_budget if kv_budget is not None
                       else getattr(args, "kv_budget", None)),
            policy=getattr(args, "scheduler", "continuous"),
            block_size=(int(getattr(args, "block_size", 16))
                        if paged else 0),
            prefill_chunk=int(getattr(args, "prefill_chunk", 0)),
            token_budget=getattr(args, "token_budget", None),
            spec_branches=branches,
            slo_aware=getattr(args, "slo_profile", "off") != "off",
        )


@dataclasses.dataclass
class Decision:
    """One slot's scheduling decision, applied by the engine in order:
    preemptions first (rows + KV cells freed), then admissions (row
    granted; prefill starts), then prefill chunk grants
    ``(request, n_tokens)`` — newly admitted requests appear in both
    ``admit`` and ``prefill`` when chunking is enabled."""
    admit: List[Request]
    preempt: List[Request]
    prefill: List[Tuple[Request, int]] = dataclasses.field(
        default_factory=list)

    @property
    def empty(self) -> bool:
        return not (self.admit or self.preempt or self.prefill)


def _rank(r: Request):
    """Admission / victim ranking: deadline-closest-first for requests
    carrying an SLO, then the pre-SLO key ``(priority, arrival, rid)``
    — lower priority value first (more urgent), then FIFO by arrival.

    ``next_deadline()`` is +inf without an SLO, so a stream with no
    contracts orders byte-for-byte like the pre-SLO scheduler; equal
    deadlines (including the all-inf case) fall back to the same total,
    stable ``(priority, arrival, rid)`` order.  Reversed, this is the
    preemption-victim order: farthest-from-deadline-first, THEN lowest
    priority / latest arrival — a request past its deadline is never
    sacrificed for a same-priority request with slack."""
    return (r.next_deadline(), r.priority, r.arrival, r.rid)


def _blind_rank(r: Request):
    """The pre-SLO ranking, kept for ``slo_aware=False`` (the
    deadline-blind baseline the SLO benchmarks compare against)."""
    return (math.inf, r.priority, r.arrival, r.rid)


class ContinuousScheduler:
    """Tracks the request lifecycle: pending (future arrival) -> waiting
    (arrived, no row) -> [prefilling (owns a row, partial KV) ->] running
    (row + full context, drafting) -> finished; preemption moves
    prefilling/running -> waiting with generated tokens intact (partial
    prefill progress is discarded — its blocks are freed)."""

    def __init__(self, cfg: SchedulerConfig):
        if cfg.policy not in POLICIES:
            raise ValueError(f"unknown policy {cfg.policy!r}")
        if cfg.prefill_chunk < 0:
            raise ValueError("prefill_chunk must be >= 0")
        if cfg.token_budget is not None and cfg.token_budget <= 0:
            raise ValueError("token_budget must be positive")
        self.cfg = cfg
        # one ranking for admission AND (reversed) victim selection:
        # deadline-closest-first when contracts are honoured, the pre-SLO
        # (priority, arrival, rid) key when blind
        self._rankkey = _rank if cfg.slo_aware else _blind_rank
        self.kv_budget = (cfg.kv_budget if cfg.kv_budget is not None
                          else cfg.capacity * cfg.max_len)
        self._pending: List = []           # heap of (arrival, seq, Request)
        self._seq = 0
        self.waiting: List[Request] = []   # arrived, sorted by _rank
        self.running: Dict[int, Request] = {}   # every row owner
        self.prefilling: Dict[int, Request] = {}  # subset of running
        self.finished: List[int] = []
        self.preemptions = 0
        self.admissions = 0
        self.stolen = 0                    # queued requests released away
        self.prefill_grants = 0            # chunk grants issued
        self.prefill_tokens = 0            # prompt tokens granted in chunks
        self._wait_since: Dict[int, float] = {}   # rid -> enqueue clock
        self.queue_wait = 0.0              # total waiting-time accumulated
        # per-request granted speculation depths (gamma controller); the
        # token-budget split costs each decode slot at its actual depth
        # instead of a uniform gamma+1.  Missing entries (fresh admits
        # before any grant) fall back to cfg.gamma.
        self.decode_depths: Dict[int, int] = {}
        self.decode_tokens_planned = 0     # Σ (k_i + 1) over planned slots
        # prompt tokens granted by the CURRENT slot's chunk plan; the
        # gamma controller reads this so its depth cap charges the actual
        # prefill work sharing this slot's token budget
        self.last_prefill_granted = 0
        # slot-duration EMA (sim-clock gap between successive plan()
        # calls): converts a TTFT deadline into "slots left", so the
        # chunk split can size a tight request's chunk to finish its
        # prefill before the deadline.  Observation only — with no SLOs
        # (or slo_aware=False) it never changes a decision.
        self._last_plan_now: Optional[float] = None
        self._slot_dt: Optional[float] = None
        self.slo_chunk_boosts = 0          # chunks grown for TTFT slack

    # ----------------------------------------------------------- intake --
    def submit(self, reqs: Sequence[Request]):
        for r in reqs:
            heapq.heappush(self._pending,
                           (float(r.arrival), self._seq, r))
            self._seq += 1

    def poll(self, now: float):
        """Move every request whose arrival time has passed into the
        waiting queue (kept sorted by rank)."""
        while self._pending and self._pending[0][0] <= now + 1e-12:
            arrival, _, r = heapq.heappop(self._pending)
            bisect.insort(self.waiting, r, key=self._rankkey)
            # queue wait starts at the actual arrival, not the first poll
            # that noticed it — several requests landing inside one slot
            # must each be charged their own wait
            self._wait_since[r.rid] = arrival

    @property
    def outstanding(self) -> bool:
        return bool(self._pending or self.waiting or self.running)

    def outstanding_requests(self) -> List[Request]:
        """Every request this scheduler still owes work: running
        (prefilling included), waiting, and not-yet-arrived pending —
        the router's per-replica load view."""
        return (list(self.running.values()) + list(self.waiting)
                + [r for _, _, r in self._pending])

    @property
    def queue_depth(self) -> int:
        """Requests without a row: waiting plus not-yet-arrived pending."""
        return len(self.waiting) + len(self._pending)

    def next_arrival(self) -> Optional[float]:
        return self._pending[0][0] if self._pending else None

    # ------------------------------------------------- steal / requeue --
    def steal_candidates(self) -> List[Request]:
        """Queued requests another replica could serve from scratch:
        arrived but rowless (``waiting``) — no KV, no partial prefill, so
        migration is a plain re-submission.  Worst-ranked first: the
        request that would wait longest here gains most from moving."""
        return list(reversed(self.waiting))

    def release_queued(self, rids: Optional[Sequence[int]] = None, *,
                       include_pending: bool = False) -> List[Request]:
        """Remove queued (rowless) requests from this scheduler and return
        them for hand-off to another replica — the work-stealing / drain
        hook.  ``rids=None`` releases every waiting request;
        ``include_pending`` also releases not-yet-arrived requests
        (drain-before-retire hands the whole queue off).  Row owners
        (running/prefilling) are never released — their KV lives here.

        Accrued queue wait is NOT charged at the source: the request's
        ``arrival`` rides with it, and the receiving scheduler's
        :meth:`poll` re-charges the full arrival->admission wait there,
        so fleet-level queue_wait counts each wait exactly once."""
        want = None if rids is None else set(rids)
        out: List[Request] = []
        kept: List[Request] = []
        for r in self.waiting:
            if want is None or r.rid in want:
                out.append(r)
            else:
                kept.append(r)
        self.waiting = kept
        if include_pending:
            still = []
            for arrival, seq, r in self._pending:
                if want is None or r.rid in want:
                    out.append(r)
                else:
                    still.append((arrival, seq, r))
            if len(still) != len(self._pending):
                self._pending = still
                heapq.heapify(self._pending)
        for r in out:
            self._wait_since.pop(r.rid, None)
        self.stolen += len(out)
        return out

    # ----------------------------------------------------------- policy --
    def kv_need(self, r: Request) -> int:
        """KV cells the request needs for its next slot: committed context
        plus the speculation window (gamma drafts + 1 bonus token), rounded
        up to whole blocks under the paged layout (allocation granularity
        = one block, so the rounded figure is what the pool will hold).
        Prefilling requests are accounted at their full target context —
        admission reserves the whole prompt's worth of budget up front, so
        chunked ingestion can never strand a half-prefilled request without
        blocks."""
        ctx = r.prompt_len + max(0, len(r.emitted or []) - 1)
        need = ctx + self.cfg.gamma + 1
        if self.cfg.block_size > 0:
            b = self.cfg.block_size
            need = -(-need // b) * b
            if self.cfg.spec_branches > 1:
                # per extra branch: CoW copies of the blocks covering the
                # speculation window plus the straddling tail block
                per_branch = (-(-(self.cfg.gamma + 2) // b) + 1) * b
                need += (self.cfg.spec_branches - 1) * per_branch
        return need

    def prefill_target(self, r: Request) -> int:
        """Context tokens the engine must ingest before the request can
        draft: prompt plus committed tokens (minus the one emitted-but-not-
        fed-back token that becomes the pool's last_token)."""
        return r.prompt_len + max(0, len(r.emitted or []) - 1)

    def plan(self, now: float, grant_prefill: bool = True) -> Decision:
        """One slot's decision.  ``grant_prefill=False`` plans admissions
        and preemptions only (used by the engine's end-of-step recycling
        pass, so chunk budgets are spent once per slot, not once per
        ``plan`` call)."""
        self.poll(now)
        if (self._last_plan_now is not None
                and now > self._last_plan_now + 1e-12):
            dt = now - self._last_plan_now
            self._slot_dt = (dt if self._slot_dt is None
                             else 0.5 * self._slot_dt + 0.5 * dt)
        self._last_plan_now = now
        if self.cfg.policy == "static":
            return self._plan_static()
        dec = self._plan_continuous()
        if grant_prefill and self.cfg.prefill_chunk > 0:
            dec.prefill = self._plan_chunks(dec, now)
            self.last_prefill_granted = sum(n for _, n in dec.prefill)
        return dec

    def _plan_static(self) -> Decision:
        """Seed-style gang scheduling: a new cohort is admitted only once
        the pool has fully drained (always monolithic prefill)."""
        admit: List[Request] = []
        if not self.running:
            while self.waiting and len(admit) < self.cfg.capacity:
                admit.append(self.waiting.pop(0))
        return Decision(admit=admit, preempt=[])

    def _plan_continuous(self) -> Decision:
        admit: List[Request] = []
        preempt: List[Request] = []
        # Preempt while projected demand exceeds the KV budget.  Victims
        # are the worst-ranked runners — farthest-from-deadline first
        # once SLOs exist (a request past its deadline is never the
        # victim over a same-priority request with slack), then lowest
        # priority class, ties by latest arrival; the best-ranked
        # min_running requests always keep their rows (guaranteed
        # progress -> no livelock).
        runners = sorted(self.running.values(), key=self._rankkey)
        demand = sum(self.kv_need(r) for r in runners)
        while demand > self.kv_budget and len(runners) > self.cfg.min_running:
            victim = runners.pop()
            demand -= self.kv_need(victim)
            preempt.append(victim)
        # Admit by rank into freed/free rows while the budget allows.  An
        # empty pool admits unconditionally (a single oversized request
        # must still run, otherwise the queue deadlocks).
        occupied = len(self.running) - len(preempt)
        while self.waiting and occupied + len(admit) < self.cfg.capacity:
            r = self.waiting[0]
            if (demand + self.kv_need(r) > self.kv_budget
                    and occupied + len(admit) >= self.cfg.min_running):
                break
            self.waiting.pop(0)
            admit.append(r)
            demand += self.kv_need(r)
        return Decision(admit=admit, preempt=preempt)

    def set_decode_depths(self, depths: Dict[int, int]):
        """Engine acknowledgement of the gamma controller's grants: the
        speculation depth each running request will draft next slot.  The
        token-budget split charges each decode slot ``k_i + 1`` LLM query
        tokens (its drafts + the bonus/correction token) instead of the
        uniform worst case, so shallow grants free budget for prompt
        chunks."""
        self.decode_depths = dict(depths)

    def decode_cost(self, rid: int) -> int:
        """Planned LLM query tokens of one decode slot: granted depth + 1
        (fixed policy / fresh admits: cfg.gamma + 1)."""
        return self.decode_depths.get(rid, self.cfg.gamma) + 1

    def _slo_chunk(self, r: Request, remaining: int, now: float) -> int:
        """TTFT-slack-aware chunk size: the tokens this slot must ingest
        so the request's remaining prefill completes before its TTFT
        deadline at the observed slot cadence.  At most ``prefill_chunk``
        unless the deadline demands more; never below ``prefill_chunk``
        (a tight budget still caps the grant downstream).  Requests
        without an SLO — or a scheduler without a cadence estimate yet —
        keep the flat ``prefill_chunk``."""
        base = min(self.cfg.prefill_chunk, remaining)
        if (not self.cfg.slo_aware or r.slo is None
                or self._slot_dt is None or self._slot_dt <= 0):
            return base
        slack = r.next_deadline() - now
        slots_left = max(1.0, slack / self._slot_dt)
        needed = int(math.ceil(remaining / slots_left))
        if needed > base:
            self.slo_chunk_boosts += 1
            return min(needed, remaining)
        return base

    def _plan_chunks(self, dec: Decision,
                     now: float) -> List[Tuple[Request, int]]:
        """Split this slot's token budget between decode slots and prompt
        chunks.  Decode comes first (every decode-active request costs its
        granted depth + 1 query tokens); the remainder goes to prefilling
        requests in rank order — deadline-closest-first under SLOs —
        capped at ``prefill_chunk`` tokens each unless a request's TTFT
        slack demands a bigger chunk (:meth:`_slo_chunk`).  When nothing
        is decode-active, the top-ranked prefilling request is granted a
        chunk unconditionally — an otherwise-idle slot must make
        progress."""
        victims = {r.rid for r in dec.preempt}
        cands = sorted(
            [r for rid, r in self.prefilling.items() if rid not in victims]
            + list(dec.admit), key=self._rankkey)
        decoders = [rid for rid in self.running
                    if rid not in victims and rid not in self.prefilling]
        n_decode = len(decoders)
        decode_tokens = sum(self.decode_cost(rid) for rid in decoders)
        self.decode_tokens_planned += decode_tokens
        left: Optional[int] = None
        if self.cfg.token_budget is not None:
            left = max(0, self.cfg.token_budget - decode_tokens)
        grants: List[Tuple[Request, int]] = []
        for r in cands:
            remaining = self.prefill_target(r) - r.prefill_pos
            if remaining <= 0:
                continue
            n = self._slo_chunk(r, remaining, now)
            if left is not None:
                n = min(n, left)
            if n <= 0:
                if grants or n_decode > 0:
                    break               # budget exhausted; decode advances
                n = min(self.cfg.prefill_chunk, remaining)  # idle-slot rule
            grants.append((r, n))
            if left is not None:
                left -= n
            self.prefill_grants += 1
            self.prefill_tokens += n
        return grants

    # ------------------------------------------- engine acknowledgements --
    def mark_admitted(self, r: Request, now: float):
        """The request owns a row.  Monolithic mode: it is immediately
        decode-ready.  Chunked mode: it enters the prefilling state and
        leaves it via :meth:`mark_prefill_done`."""
        self.running[r.rid] = r
        self.admissions += 1
        if self.cfg.prefill_chunk > 0 and self.cfg.policy == "continuous":
            r.prefill_pos = 0
            self.prefilling[r.rid] = r
        since = self._wait_since.pop(r.rid, None)
        if since is not None:
            self.queue_wait += max(0.0, now - since)

    def mark_prefill_done(self, r: Request):
        """All context chunks ingested: prefilling -> running (drafting)."""
        self.prefilling.pop(r.rid, None)

    def mark_preempted(self, r: Request, now: float):
        """Back to the waiting queue with emitted tokens intact; the engine
        re-prefills prompt+emitted on re-admission.  Partial prefill
        progress is discarded with the freed blocks.  Queue order stays
        rank-FIFO so a preempted old request outranks newer arrivals of the
        same priority class."""
        self.running.pop(r.rid, None)
        self.prefilling.pop(r.rid, None)
        self.decode_depths.pop(r.rid, None)
        r.prefill_pos = 0
        r.preemptions += 1
        self.preemptions += 1
        bisect.insort(self.waiting, r, key=self._rankkey)
        self._wait_since[r.rid] = now

    def mark_finished(self, rid: int):
        self.running.pop(rid, None)
        self.decode_depths.pop(rid, None)
        self.finished.append(rid)

    # ------------------------------------------------------------ stats --
    def snapshot(self) -> SchedulerStats:
        """The typed point-in-time view (serving/stats.py) the engine
        embeds in its own snapshot: queue/lifecycle counters plus the
        most urgent outstanding next-token deadline."""
        return SchedulerStats(
            queue_depth=self.queue_depth,
            waiting=len(self.waiting),
            running=len(self.running),
            prefilling=len(self.prefilling),
            admissions=self.admissions,
            preemptions=self.preemptions,
            finished=len(self.finished),
            stolen=self.stolen,
            queue_wait=self.queue_wait,
            min_deadline=min_outstanding_deadline(
                self.outstanding_requests()),
        )

    @property
    def stats(self) -> dict:
        return {
            "policy": self.cfg.policy,
            "kv_budget": self.kv_budget,
            "slo_aware": self.cfg.slo_aware,
            "admissions": self.admissions,
            "preemptions": self.preemptions,
            "finished": len(self.finished),
            "stolen": self.stolen,
            "queue_wait": self.queue_wait,
            "prefill_chunk": self.cfg.prefill_chunk,
            "prefill_grants": self.prefill_grants,
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens_planned": self.decode_tokens_planned,
            "slo_chunk_boosts": self.slo_chunk_boosts,
        }
