"""Continuous-batching request scheduler for the SPIN engine.

The seed engine stepped one fixed cohort of requests per time slot: rows of
the LLM ``CachePool`` were filled once up front and capacity idled as
requests finished.  Under *serving* conditions (streaming arrivals, mixed
lengths, a finite KV budget) that throws away exactly the goodput the
paper's mechanisms buy — SpecInfer / SpecServe-style systems integrate
speculative decoding with a continuous-batching scheduler for this reason.

This module is the policy half of that scheduler; ``serving/engine.py``
owns the mechanics (prefill-on-admit, cache eviction).  Per time slot the
engine calls :meth:`ContinuousScheduler.plan` with the current simulated
clock and applies the returned decision:

* **arrivals** — submitted requests carry an ``arrival`` timestamp
  (Poisson or trace-driven, see ``data/workloads.py``); they become
  admissible only once the engine clock reaches it.
* **admission** — waiting requests are admitted FIFO-by-arrival into free
  ``CachePool`` rows, at slot granularity (prefill happens on admit).
* **recycling** — rows of finished requests are freed inside the engine
  step; the end-of-step ``plan`` immediately re-fills them, so a row never
  idles across a slot boundary while work is queued.
* **preemption** — when the projected KV demand of the running set exceeds
  ``kv_budget`` cells, the lowest-priority (latest-arrived) requests are
  evicted and re-enqueued for re-prefill.  At least ``min_running``
  requests always keep their rows, and an empty pool always admits, so the
  engine can never deadlock at full capacity.

The ``static`` policy reproduces the seed behaviour (admit a cohort only
when the pool has fully drained) and is kept as the baseline that
``benchmarks/bench_serving.py`` compares against.
"""

from __future__ import annotations

import bisect
import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence

from repro.data.workloads import Request

POLICIES = ("continuous", "static")


@dataclasses.dataclass
class SchedulerConfig:
    capacity: int                      # LLM pool rows
    max_len: int = 256
    gamma: int = 4                     # speculation window (KV headroom)
    kv_budget: Optional[int] = None    # total KV cells; None -> cap*max_len
    policy: str = "continuous"
    min_running: int = 1               # never preempt below this
    # paged layout: KV is allocated in whole blocks, so demand is accounted
    # in block-rounded cells and the budget is the physical block pool —
    # an enforced invariant, not a model.  0 = cell-granular (dense layout).
    block_size: int = 0


@dataclasses.dataclass
class Decision:
    """One slot's scheduling decision, applied by the engine in order:
    preemptions first (rows + KV cells freed), then admissions."""
    admit: List[Request]
    preempt: List[Request]

    @property
    def empty(self) -> bool:
        return not (self.admit or self.preempt)


class ContinuousScheduler:
    """Tracks the request lifecycle: pending (future arrival) -> waiting
    (arrived, no row) -> running (owns a CachePool row) -> finished;
    preemption moves running -> waiting with generated tokens intact."""

    def __init__(self, cfg: SchedulerConfig):
        if cfg.policy not in POLICIES:
            raise ValueError(f"unknown policy {cfg.policy!r}")
        self.cfg = cfg
        self.kv_budget = (cfg.kv_budget if cfg.kv_budget is not None
                          else cfg.capacity * cfg.max_len)
        self._pending: List = []           # heap of (arrival, seq, Request)
        self._seq = 0
        self.waiting: List[Request] = []   # arrived, FIFO by (arrival, seq)
        self.running: Dict[int, Request] = {}
        self.finished: List[int] = []
        self.preemptions = 0
        self.admissions = 0
        self._wait_since: Dict[int, float] = {}   # rid -> enqueue clock
        self.queue_wait = 0.0              # total waiting-time accumulated

    # ----------------------------------------------------------- intake --
    def submit(self, reqs: Sequence[Request]):
        for r in reqs:
            heapq.heappush(self._pending,
                           (float(r.arrival), self._seq, r))
            self._seq += 1

    def poll(self, now: float):
        """Move every request whose arrival time has passed into the
        waiting queue."""
        while self._pending and self._pending[0][0] <= now + 1e-12:
            arrival, _, r = heapq.heappop(self._pending)
            self.waiting.append(r)
            self._wait_since[r.rid] = max(now, arrival)

    @property
    def outstanding(self) -> bool:
        return bool(self._pending or self.waiting or self.running)

    def next_arrival(self) -> Optional[float]:
        return self._pending[0][0] if self._pending else None

    # ----------------------------------------------------------- policy --
    def kv_need(self, r: Request) -> int:
        """KV cells the request needs for its next slot: committed context
        plus the speculation window (gamma drafts + 1 bonus token), rounded
        up to whole blocks under the paged layout (allocation granularity
        = one block, so the rounded figure is what the pool will hold)."""
        ctx = r.prompt_len + max(0, len(r.emitted or []) - 1)
        need = ctx + self.cfg.gamma + 1
        if self.cfg.block_size > 0:
            b = self.cfg.block_size
            need = -(-need // b) * b
        return need

    def plan(self, now: float) -> Decision:
        self.poll(now)
        if self.cfg.policy == "static":
            return self._plan_static()
        return self._plan_continuous()

    def _plan_static(self) -> Decision:
        """Seed-style gang scheduling: a new cohort is admitted only once
        the pool has fully drained."""
        admit: List[Request] = []
        if not self.running:
            while self.waiting and len(admit) < self.cfg.capacity:
                admit.append(self.waiting.pop(0))
        return Decision(admit=admit, preempt=[])

    def _plan_continuous(self) -> Decision:
        admit: List[Request] = []
        preempt: List[Request] = []
        # Preempt while projected demand exceeds the KV budget.  Victims
        # are the lowest-priority = latest-arrived runners; the oldest
        # min_running requests always keep their rows (guaranteed
        # progress -> no livelock).
        runners = sorted(self.running.values(),
                         key=lambda r: (r.arrival, r.rid))
        demand = sum(self.kv_need(r) for r in runners)
        while demand > self.kv_budget and len(runners) > self.cfg.min_running:
            victim = runners.pop()
            demand -= self.kv_need(victim)
            preempt.append(victim)
        # Admit FIFO into freed/free rows while the budget allows.  An
        # empty pool admits unconditionally (a single oversized request
        # must still run, otherwise the queue deadlocks).
        occupied = len(self.running) - len(preempt)
        while self.waiting and occupied + len(admit) < self.cfg.capacity:
            r = self.waiting[0]
            if (demand + self.kv_need(r) > self.kv_budget
                    and occupied + len(admit) >= self.cfg.min_running):
                break
            self.waiting.pop(0)
            admit.append(r)
            demand += self.kv_need(r)
        return Decision(admit=admit, preempt=preempt)

    # ------------------------------------------- engine acknowledgements --
    def mark_admitted(self, r: Request, now: float):
        self.running[r.rid] = r
        self.admissions += 1
        since = self._wait_since.pop(r.rid, None)
        if since is not None:
            self.queue_wait += max(0.0, now - since)

    def mark_preempted(self, r: Request, now: float):
        """Back to the waiting queue with emitted tokens intact; the engine
        re-prefills prompt+emitted on re-admission.  Queue order stays
        FIFO-by-arrival so a preempted old request outranks new arrivals."""
        self.running.pop(r.rid, None)
        r.preemptions += 1
        self.preemptions += 1
        bisect.insort(self.waiting, r, key=lambda x: (x.arrival, x.rid))
        self._wait_since[r.rid] = now

    def mark_finished(self, rid: int):
        self.running.pop(rid, None)
        self.finished.append(rid)

    # ------------------------------------------------------------ stats --
    @property
    def stats(self) -> dict:
        return {
            "policy": self.cfg.policy,
            "kv_budget": self.kv_budget,
            "admissions": self.admissions,
            "preemptions": self.preemptions,
            "finished": len(self.finished),
            "queue_wait": self.queue_wait,
        }
