"""Lower + compile one (arch x shape) cell on the production meshes.

    PYTHONPATH=src python examples/multipod_dryrun.py \
        --arch mixtral-8x22b --shape train_4k --both-meshes --roofline

Thin entry point over repro.launch.dryrun (which must own the process: it
sets the 512-placeholder-device XLA flag before jax initializes).
"""

import sys

from repro.launch.dryrun import main

if __name__ == "__main__":
    sys.exit(main())
