"""Quickstart: lossless speculative decoding in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a tiny LLM + draft SSM, speculates gamma tokens per iteration,
verifies with one LLM pass, and shows that the output exactly equals plain
LLM greedy decoding (losslessness) while needing far fewer LLM passes.
"""

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core import spec_decode as sd
from repro.models import transformer as T

VOCAB, P, NEW, GAMMA = 256, 16, 24, 4

key = jax.random.PRNGKey(0)
cfg_llm = registry.reduced_for("llama-7b", d_model=96, n_heads=4,
                               n_kv_heads=4, vocab_size=VOCAB)
llm = sd.Bundle(cfg_llm, T.init_params(cfg_llm, key))
# the draft model: here the LLM itself (100% acceptance) — swap in any
# smaller config to see acceptance fall and iterations rise.
ssm = sd.Bundle(cfg_llm, llm.params)

prompt = jax.random.randint(key, (1, P), 1, VOCAB)
max_len = P + NEW + GAMMA + 4

lg, llm_cache = llm.prefill(prompt, jnp.asarray([P], jnp.int32), max_len)
_, ssm_cache = ssm.prefill(prompt, jnp.asarray([P], jnp.int32), max_len)
lengths = jnp.asarray([P], jnp.int32)
last = jnp.argmax(lg[:, P - 1, :VOCAB], -1, keepdims=True).astype(jnp.int32)

emitted, llm_passes = [int(last[0, 0])], 0
rng = jax.random.PRNGKey(1)
while len(emitted) < NEW:
    rng, k = jax.random.split(rng)
    out, out_len, n_acc, llm_cache, ssm_cache, lengths, last = \
        sd.spec_iteration(llm, ssm, llm_cache, ssm_cache, last, lengths,
                          GAMMA, k)
    llm_passes += 1
    emitted += [int(x) for x in out[0, :int(out_len[0])]]
    print(f"iter {llm_passes}: accepted {int(n_acc[0])}/{GAMMA} "
          f"-> +{int(out_len[0])} tokens")

print(f"\n{len(emitted)} tokens with {llm_passes} LLM passes "
      f"(plain decoding would need {len(emitted)})")
print("tokens:", emitted[:NEW])
