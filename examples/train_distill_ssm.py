"""End-to-end training driver: train the LLM + heterogeneous SSM zoo.

    PYTHONPATH=src python examples/train_distill_ssm.py [--steps 250]

This is the paper's missing substrate made explicit: the five SSMs
(68M..1.4B shape-faithful reductions) and the LLM are trained on the
two-scale synthetic corpus, producing capacity-dependent acceptance rates
(small SSM aces easy requests, large SSM wins hard ones — Fig. 2/3).
Artifacts are cached under results/zoo/ and reused by benchmarks.

For full-scale training of any assigned arch on a pod, the same loop runs
through launch/train.py (checkpointed, crash-recovering, mesh-sharded).
"""

import argparse
import sys

sys.path.insert(0, "benchmarks")
sys.path.insert(0, ".")

from benchmarks.common import SSM_NAMES, build_zoo


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=250)
    ap.add_argument("--force", action="store_true", help="retrain")
    args = ap.parse_args()
    llm, ssms = build_zoo(steps=args.steps, force=args.force)
    print(f"\nLLM: {llm.cfg.n_layers}L x {llm.cfg.d_model}d "
          f"({llm.cfg.params_count() / 1e3:.0f}k params)")
    for n, s in zip(SSM_NAMES, ssms):
        print(f"SSM[{n}]: {s.cfg.n_layers}L x {s.cfg.d_model}d "
              f"({s.cfg.params_count() / 1e3:.0f}k params)")


if __name__ == "__main__":
    main()
