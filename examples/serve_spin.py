"""Serve a batched workload through the full SPIN engine.

    PYTHONPATH=src python examples/serve_spin.py \
        [--dataset mix] [--requests 8] [--selector lbss]

Demonstrates all three SPIN mechanisms live: LBSS heterogeneous-SSM
selection (with fast switching), request-decomposed packed verification,
and micro-batch pipelining (calibrated event timeline).  Prints goodput
and per-mechanism statistics.
"""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    main(sys.argv[1:])
