"""Substrate tests: data determinism, optimizer, checkpointing (atomic,
async, resharding restore), gradient compression, train launcher recovery."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data.pipeline import TokenStream
from repro.data.workloads import make_workload
from repro.distributed.collectives import (dequantize_int8, quantize_int8,
                                           topk_sparsify)
from repro.optim import AdamW, cosine_schedule


# ------------------------------------------------------------------ data --

def test_stream_deterministic_and_host_sharded():
    s0 = TokenStream(seed=1, batch=4, seq_len=32, vocab=128)
    a1, b1 = s0.batch_at(7)
    a2, b2 = s0.batch_at(7)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(b1, b2)
    # labels are next-token shifted
    np.testing.assert_array_equal(a1[:, 1:], b1[:, :-1])
    # different hosts get different data
    h1 = TokenStream(seed=1, batch=4, seq_len=32, vocab=128,
                     host_id=1, num_hosts=2).batch_at(7)[0]
    assert not np.array_equal(a1, h1)


def test_workload_difficulty_distributions():
    """Dataset difficulty ordering mirrors the paper: alpaca > cip > cp."""
    means = {}
    for name in ("alpaca", "cp", "cip"):
        reqs = make_workload(name, 64, 128, seed=2)
        means[name] = np.mean([r.difficulty for r in reqs])
    assert means["alpaca"] > means["cip"] > means["cp"]


def test_modes_use_their_structural_order():
    """Trimodal corpus: each mode's continuation follows its own table
    (capacity-graded structure, DESIGN.md §8)."""
    from repro.data.pipeline import (_backbone, _h2, _h3, mode_of,
                                     synthetic_sequence)
    tables = _backbone(np.random.default_rng(3), 128)
    t1, t2, t3 = tables

    def frac_matching(diff, predict):
        seq = synthetic_sequence(np.random.default_rng(4), 2000, 128,
                                 tables, diff)
        hits = sum(int(seq[t] == predict(seq, t))
                   for t in range(3, len(seq)))
        return hits / (len(seq) - 3)

    assert mode_of(0.1) == 1 and mode_of(0.5) == 2 and mode_of(0.9) == 3
    assert frac_matching(0.1, lambda s, t: t1[int(s[t - 1])]) > 0.9
    assert frac_matching(
        0.5, lambda s, t: t2[_h2(int(s[t - 1]), int(s[t - 2]))]) > 0.9
    assert frac_matching(
        0.9, lambda s, t: t3[_h3(int(s[t - 1]), int(s[t - 2]),
                                 int(s[t - 3]))]) > 0.9
    # markers expose the mode in-context
    for d, m in ((0.1, 1), (0.5, 2), (0.9, 3)):
        seq = synthetic_sequence(np.random.default_rng(5), 16, 128,
                                 tables, d)
        assert seq[0] == m


# ----------------------------------------------------------------- optim --

def test_adamw_converges_on_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(params, grads, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_cosine_schedule_shape():
    f = cosine_schedule(1.0, warmup=10, total=100)
    assert float(f(0)) < 0.2
    assert float(f(10)) == pytest.approx(1.0, abs=0.05)
    assert float(f(99)) < 0.2


# ------------------------------------------------------------ checkpoint --

def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.asarray(3)}


def test_checkpoint_roundtrip_including_bf16(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(5, tree)
    restored, step = mgr.restore(tree)
    assert step == 5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_atomic_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4
    # a stale tmp dir never corrupts restore
    os.makedirs(str(tmp_path / "step_9.tmp"))
    assert mgr.latest_step() == 4


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_checkpoint_resharding_restore(tmp_path):
    """Elastic restore: save unsharded, restore with explicit shardings
    (single-device here; the same path re-places onto any mesh)."""
    from jax.sharding import NamedSharding, PartitionSpec
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(2, tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, PartitionSpec()), tree)
    restored, _ = mgr.restore(tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(tree["a"]),
                                  np.asarray(restored["a"]))


# ----------------------------------------------------------- compression --

def test_int8_quantization_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 3.0
    q, scale = quantize_int8(x)
    err = jnp.max(jnp.abs(dequantize_int8(q, scale) - x))
    assert float(err) <= float(scale) * 0.5 + 1e-6
    assert q.dtype == jnp.int8


def test_topk_sparsify_keeps_largest():
    x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05])
    sparse, mask = topk_sparsify(x, frac=0.4)
    assert float(sparse[1]) == -5.0 and float(sparse[3]) == 3.0
    assert float(jnp.sum(jnp.abs(sparse) > 0)) == 2


# ---------------------------------------------------------- train loop ----

def test_train_launcher_failure_recovery(tmp_path):
    """Inject a crash; the restart loop must resume from the checkpoint and
    reach the same final loss as an uninterrupted run."""
    from repro.launch.train import main as train_main
    argv_common = ["--arch", "llama-68m", "--reduced", "--steps", "40",
                   "--batch", "2", "--seq-len", "32", "--ckpt-every", "10"]
    out_clean = train_main(argv_common + ["--ckpt-dir",
                                          str(tmp_path / "clean")])
    out_crash = train_main(argv_common + [
        "--ckpt-dir", str(tmp_path / "crash"),
        "--simulate-failures", "--fail-at", "25"])
    assert out_crash["resumed_from"] > 0
    assert out_crash["final_loss"] == pytest.approx(
        out_clean["final_loss"], rel=1e-4)
