"""Elastic fleet chaos/property suite (serving/router.py control plane).

The autoscale/steal/drain control plane moves requests between replicas
while they serve — exactly where requests get silently lost or
double-served.  This suite random-walks adversarial control schedules
(scale-up, drain-before-retire, forced steals) against a diurnal arrival
trace and asserts the conservation invariants at EVERY step:

* request conservation — every submitted rid finishes exactly once,
  never lost across a drain/steal, never owned by two engines;
* stolen requests re-prefill from scratch — a migrating request holds no
  KV row anywhere at the instant it moves;
* drain-before-retire — a replica is only ever ``standby`` with nothing
  outstanding (no rows, no queue, no pendings);
* token-stream equality — wherever a request ends up, and however often
  it was stolen before (or after a preemption released) its prefill, it
  emits the reference engine's greedy continuation token for token;
* bit-identity — with ``autoscale="off"`` and no classes the router is
  the pre-elastic router: same tokens AND sim-clock stats as the bare
  engine, across linear/tree x fused/unfused.

Runs without hypothesis too (tests/hypcompat.py): the random-walk
harness is also driven by fixed example scripts, so a bare environment
still exercises every invariant.  CI runs the ``chaos`` profile (200+
examples, fixed seed, no deadline) on top.
"""

import os

import jax
import numpy as np
import pytest
from hypcompat import HAVE_HYPOTHESIS, given, st

from repro.configs import registry
from repro.core import spec_decode as sd
from repro.core.gamma import GammaConfig
from repro.core.selector import LBSS, SelectorConfig
from repro.data.workloads import (bursty_arrivals, diurnal_arrivals,
                                  make_workload)
from repro.launch import mesh as M
from repro.launch.serve import split_weighted
from repro.models import transformer as T
from repro.serving.engine import EngineConfig, SpinEngine
from repro.serving.router import (CLASS_KV_WEIGHTS, Router, RouterConfig,
                                  class_engine_config, parse_replica_classes)

if HAVE_HYPOTHESIS:
    # Profiles instead of per-test @settings so the CI chaos step can
    # raise the example count without editing the tests:
    #   HYPOTHESIS_PROFILE=chaos pytest tests/test_elastic.py \
    #       --hypothesis-seed=0
    from hypothesis import settings as hsettings
    hsettings.register_profile("chaos", max_examples=200, deadline=None)
    hsettings.register_profile("dev", max_examples=8, deadline=None)
    hsettings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))

VOCAB = 256
N_REQ = 7


@pytest.fixture(scope="module")
def models():
    key = jax.random.PRNGKey(0)
    cfg_llm = registry.reduced_for(
        "llama-7b", d_model=96, n_heads=4, n_kv_heads=4, vocab_size=VOCAB
    )
    llm = sd.Bundle(cfg_llm, T.init_params(cfg_llm, key))
    ssms = []
    for i, (d, L) in enumerate([(32, 1), (64, 2)]):
        c = registry.reduced_for(
            "llama-68m",
            d_model=d,
            n_heads=4,
            n_kv_heads=4,
            vocab_size=VOCAB,
            n_layers=L,
        )
        ssms.append(sd.Bundle(c, T.init_params(c, jax.random.PRNGKey(i + 1))))
    return llm, ssms


def make_engine(models, capacity=2, kv_budget=None, seed=0, **ecfg_kw):
    llm, ssms = models
    sel = LBSS(
        SelectorConfig(
            n_ssms=len(ssms),
            batch_limits=[capacity] * len(ssms),
            alpha=4,
            beta=2,
            seed=seed,
        )
    )
    ecfg = EngineConfig(
        gamma=3,
        max_len=128,
        capacity=capacity,
        packed_bucket=128,
        straggler_mitigation=False,
        kv_budget=kv_budget,
        seed=seed,
        **ecfg_kw,
    )
    return SpinEngine(llm, ssms, sel, ecfg)


def workload(n=N_REQ, seed=11):
    """Diurnal-stamped request mix: the autoscaling workload (trough at a
    fifth of the peak, ~one day/night cycle over the stream)."""
    reqs = make_workload("mix", n, VOCAB, seed=seed, scale=0.25)
    trace = diurnal_arrivals(
        n, rate_base=60.0, rate_peak=300.0, period=2.0 * n / 300.0, seed=seed
    )
    for r, t in zip(reqs, trace):
        r.arrival = float(t)
    return reqs


_REFERENCE = {}  # workload seed -> {rid: reference emitted tokens}


def reference_tokens(models, seed):
    """The greedy continuation per request, from one big bare engine —
    THE token stream every chaos schedule must reproduce (speculative
    decoding is lossless; scheduling/stealing must be too)."""
    if seed not in _REFERENCE:
        eng = make_engine(models, capacity=N_REQ, seed=0)
        eng.add_requests(workload(seed=seed))
        eng.run(max_slots=400)
        _REFERENCE[seed] = {
            rid: list(r.emitted[: r.max_new])
            for rid, r in eng.requests.items()
        }
    return _REFERENCE[seed]


def sim_stats(stats: dict) -> dict:
    return {k: v for k, v in stats.items() if k != "wall_time"}


# ------------------------------------------------------- chaos harness --


def check_invariants(router):
    """The per-step conservation contract."""
    owner = {}
    for i, eng in enumerate(router.engines):
        for rid in eng.requests:
            assert rid not in owner, (
                f"rid {rid} owned by replicas {owner[rid]} and {i}"
            )
            owner[rid] = i
    for i, st_ in enumerate(router.states):
        if st_ == "standby":
            eng = router.engines[i]
            # drain-before-retire: standby means NOTHING outstanding —
            # no rows decoding, no queue, no pending arrivals
            assert not eng.scheduler.outstanding, f"replica {i}"
            assert not eng.scheduler.running, f"replica {i}"


def force_steal(router):
    """Migrate one queued (rowless) request between replicas, bypassing
    the router's cost rule — the adversarial steal.  Asserts the
    no-stale-KV contract at the instant of migration."""
    srcs = [
        i
        for i, e in enumerate(router.engines)
        if e.scheduler.waiting and router.states[i] != "standby"
    ]
    if not srcs:
        return
    src = srcs[0]
    dsts = [i for i in router._actives() if i != src]
    if not dsts:
        return
    dst = dsts[0]
    r = router.engines[src].scheduler.waiting[0]
    assert not any(e.llm_pool.has(r.rid) for e in router.engines), (
        f"queued rid {r.rid} holds a KV row"
    )
    out = router.engines[src].release_queued([r.rid])
    assert [x.rid for x in out] == [r.rid]
    router.engines[dst].add_requests(out)
    router.dispatched_to[r.rid] = dst
    router.steals += len(out)


def drive(router, script, max_iters=5000):
    """The run() co-simulation loop with an adversarial control schedule
    spliced in: each iteration applies the next scripted action (scale
    up / drain / steal / nothing), completes pending drains, checks the
    invariants, then steps the lagging live replica."""
    k = 0
    for it in range(max_iters):
        now = router._fleet_now()
        router._control(now)  # completes drains (autoscale off here)
        if script:
            act = script[k % len(script)]
            k += 1
            actives = router._actives()
            if act == "up":
                standby = [
                    i for i, s in enumerate(router.states) if s == "standby"
                ]
                if standby:
                    router._activate(standby[0], now)
            elif act == "down" and len(actives) > 1:
                router._drain(actives[-1], now)
            elif act == "steal":
                force_steal(router)
        check_invariants(router)
        live = [
            i
            for i, eng in enumerate(router.engines)
            if eng.scheduler.outstanding
        ]
        if not live:
            if router._pending:
                router._dispatch_due(router._pending[0][0])
                continue
            return it
        i = min(live, key=lambda j: (router.engines[j].sim_time, j))
        router._dispatch_due(router.engines[i].sim_time)
        router.step_replica(i)
    raise AssertionError(f"chaos run did not drain in {max_iters} iters")


def run_chaos(models, seed, script):
    reqs = workload(seed=seed)
    engines = [make_engine(models, capacity=2, seed=i) for i in range(3)]
    router = Router(engines, RouterConfig(policy="lot", seed=seed))
    router.submit(reqs)
    drive(router, script)

    # conservation: every rid finished exactly once, somewhere
    finished = [rid for e in engines for rid in e.scheduler.finished]
    assert sorted(finished) == sorted(r.rid for r in reqs), (
        f"finished {sorted(finished)} vs submitted "
        f"{sorted(r.rid for r in reqs)} (steals={router.steals}, "
        f"events={router.events})"
    )
    assert len(finished) == len(set(finished)), "a rid finished twice"
    # token-stream equality: stolen-before-prefill == served in place
    ref = reference_tokens(models, seed)
    for e in engines:
        for rid, r in e.requests.items():
            assert r.done
            assert list(r.emitted[: r.max_new]) == ref[rid], rid
    check_invariants(router)
    return router


# Fixed scripts so the invariants run even without hypothesis: a steal
# storm, a scale thrash, and a mixed schedule.
_EXAMPLE_SCRIPTS = [
    ["steal", "none", "steal"],
    ["down", "none", "up", "none", "down", "steal"],
    ["up", "steal", "down", "none", "steal", "up", "none", "down"],
]


@pytest.mark.parametrize("script", _EXAMPLE_SCRIPTS)
def test_chaos_examples(models, script):
    router = run_chaos(models, seed=11, script=script)
    if script is _EXAMPLE_SCRIPTS[0]:
        # the steal storm must actually exercise migration (the other
        # scripts steal opportunistically — queues may be empty at the
        # scripted instants; test_stolen_before_prefill_token_equality
        # covers the forced path deterministically)
        assert router.steals > 0, "steal storm moved nothing"


@given(
    seed=st.integers(min_value=11, max_value=13),
    script=st.lists(
        st.sampled_from(["none", "up", "down", "steal"]),
        min_size=1,
        max_size=24,
    ),
)
def test_chaos_random_walk(models, seed, script):
    """Hypothesis random-walk: any interleaving of scale-up / drain /
    steal events against the diurnal trace conserves every request and
    reproduces the reference token streams."""
    run_chaos(models, seed=seed, script=script)


# ----------------------------------------------- autoscale-off identity --


@pytest.mark.parametrize(
    "ekw",
    [
        {},
        {"spec_shape": "tree", "spec_branch": 2},
        {"fused_kernels": "on"},
        {"spec_shape": "tree", "spec_branch": 2, "fused_kernels": "on"},
    ],
    ids=["linear", "tree", "linear+fused", "tree+fused"],
)
def test_autoscale_off_bit_identity(models, ekw):
    """--autoscale off --replica-classes '' must be the PR 9 router:
    tokens AND sim-clock stats bit-identical to the bare engine, across
    linear/tree x fused/unfused."""
    bare = make_engine(models, capacity=3, kv_budget=96 * 3, **ekw)
    bare.add_requests(workload())
    bare_stats = bare.run(max_slots=300)

    routed = make_engine(models, capacity=3, kv_budget=96 * 3, **ekw)
    router = Router(
        [routed],
        RouterConfig(policy="lot", autoscale="off", steal="auto", classes=""),
    )
    router.submit(workload())
    rstats = router.run(max_slots=300)

    for rid, r in bare.requests.items():
        assert routed.requests[rid].emitted == r.emitted, rid
    assert sim_stats(rstats["replica_stats"][0]) == sim_stats(bare_stats)
    assert rstats["makespan_sim"] == bare_stats["sim_time"]
    assert rstats["steals"] == 0
    assert rstats["scale_ups"] == 0 and rstats["scale_downs"] == 0


def test_default_config_is_autoscale_off(models):
    """RouterConfig() defaults must not enable any control-plane action:
    a 2-replica run matches an explicitly-disabled one dispatch for
    dispatch and stat for stat."""
    results = []
    for cfg in (
        RouterConfig(policy="lot"),
        RouterConfig(policy="lot", autoscale="off", steal="off", classes=""),
    ):
        engines = [make_engine(models, capacity=2, seed=i) for i in range(2)]
        router = Router(engines, cfg)
        router.submit(workload())
        st_ = router.run(max_slots=300)
        results.append((dict(router.dispatched_to), st_))
    assert results[0][0] == results[1][0]
    a = [sim_stats(s) for s in results[0][1]["replica_stats"]]
    b = [sim_stats(s) for s in results[1][1]["replica_stats"]]
    assert a == b
    assert results[0][1]["accepted_tokens"] == results[1][1]["accepted_tokens"]


# -------------------------------------------- draining exclusion (fix) --


def test_draining_replica_excluded_from_dispatch(models):
    """Regression (ISSUE 10 satellite): _choose used to tie-break onto a
    draining replica; draining replicas must never take new admissions
    while an active replica exists."""
    reqs = workload(n=1, seed=51)
    for policy in ("lot", "p2c", "slo"):
        engines = [make_engine(models, capacity=2, seed=i) for i in range(2)]
        router = Router(engines, RouterConfig(policy=policy, seed=3))
        # equal, empty replicas: the old tie-break picks replica 0 —
        # which is exactly the draining one here
        router.states[0] = "draining"
        assert router._choose(reqs[0]) == 1, policy
    # every replica draining: conservation over progress — dispatch
    # must still land somewhere rather than strand the request
    engines = [make_engine(models, capacity=2, seed=i) for i in range(2)]
    router = Router(engines, RouterConfig(policy="lot"))
    router.states = ["draining", "draining"]
    assert router._choose(reqs[0]) in (0, 1)


def test_standby_replica_excluded_from_dispatch(models):
    engines = [make_engine(models, capacity=2, seed=i) for i in range(2)]
    router = Router(engines, RouterConfig(policy="lot"))
    router.states[0] = "standby"
    assert router._choose(workload(n=1, seed=52)[0]) == 1


# ----------------------------------------------------------- autoscaler --


def test_autoscaler_scales_up_and_down_and_conserves(models):
    """Target-occupancy on the diurnal trace: the fleet grows into the
    peak, drains through the trough, finishes everything, and pays
    strictly fewer replica-seconds than the static fleet."""
    n = 12
    reqs = make_workload("mix", n, VOCAB, seed=17, scale=0.25)
    trace = diurnal_arrivals(
        n, rate_base=30.0, rate_peak=200.0, period=2.0 * n / 200.0, seed=17
    )
    for r, t in zip(reqs, trace):
        r.arrival = float(t)
    engines = [make_engine(models, capacity=2, seed=i) for i in range(3)]
    router = Router(
        engines,
        RouterConfig(
            policy="lot",
            autoscale="target-occupancy",
            replicas_min=1,
            replicas_max=3,
            cooldown=0.01,
        ),
    )
    assert router.states == ["active", "standby", "standby"]
    router.submit(reqs)
    st_ = router.run(max_slots=2000)
    assert st_["finished"] == n
    assert st_["scale_ups"] >= 1, router.events
    finished = [rid for e in engines for rid in e.scheduler.finished]
    assert sorted(finished) == list(range(n))
    # cost: strictly cheaper than keeping all three active for the run
    assert st_["replica_seconds"] < 3 * st_["makespan_sim"] - 1e-9
    # drain-before-retire, from the audit trail: every retire followed a
    # drain of the same replica
    drained = set()
    for e in router.events:
        if e["event"] == "drain":
            drained.add(e["replica"])
        if e["event"] == "retire":
            assert e["replica"] in drained
    check_invariants(router)


def test_provisioned_ledger_static_fleet(models):
    """autoscale off: every replica is provisioned for the whole run —
    replica_seconds == n_replicas x makespan, the static cost base."""
    engines = [make_engine(models, capacity=2, seed=i) for i in range(2)]
    router = Router(engines, RouterConfig(policy="lot"))
    router.submit(workload())
    st_ = router.run(max_slots=300)
    assert st_["replica_seconds"] == pytest.approx(2 * st_["makespan_sim"])
    assert st_["cost_normalized_goodput"] == pytest.approx(
        st_["accepted_tokens"] / st_["replica_seconds"]
    )


def test_activation_clock_syncs_forward(models):
    """A replica provisioned at fleet time T serves from T: its sim
    clock never lags the activation instant (no retroactive serving)."""
    engines = [make_engine(models, capacity=2, seed=i) for i in range(2)]
    router = Router(
        engines,
        RouterConfig(
            policy="lot",
            autoscale="target-occupancy",
            replicas_min=1,
            replicas_max=2,
        ),
    )
    engines[0].sim_time = 0.25  # replica 0 has been serving a while
    router._activate(1, router._fleet_now())
    assert engines[1].sim_time == pytest.approx(0.25)
    assert router.states[1] == "active"
    assert router._active_since[1] == pytest.approx(0.25)


# ------------------------------------------------------- steal mechanics --


def test_release_queued_only_rowless(models):
    """release_queued hands back queued/pending requests and scrubs the
    engine-side indexes; row owners stay."""
    eng = make_engine(models, capacity=1, seed=0)
    reqs = workload(n=4, seed=31)
    for r in reqs:
        r.arrival = 0.0
    eng.add_requests(reqs)  # capacity 1: one admitted, three waiting
    admitted = [rid for rid in eng.requests if eng.llm_pool.has(rid)]
    assert len(admitted) == 1
    wait_before = eng.scheduler.queue_wait
    out = eng.release_queued()
    assert sorted(r.rid for r in out) == sorted(
        r.rid for r in reqs if r.rid not in admitted
    )
    # the source never charges wait for work it handed away: the target
    # re-charges the full arrival->admit wait, so the fleet counts each
    # wait exactly once
    assert eng.scheduler.queue_wait == wait_before
    assert eng.scheduler.stolen == len(out)
    for r in out:
        assert r.rid not in eng.requests
        assert not eng.llm_pool.has(r.rid)
    # the engine still drains its row owner
    st_ = eng.run(max_slots=100)
    assert st_["scheduler"]["finished"] == 1


def test_release_queued_include_pending(models):
    eng = make_engine(models, capacity=2, seed=0)
    reqs = workload(n=3, seed=33)
    reqs[0].arrival = 0.0
    reqs[1].arrival = 1e6  # far future: stays pending
    reqs[2].arrival = 1e6
    eng.add_requests(reqs)
    out = eng.release_queued()  # default: arrived-but-rowless only
    assert [r.rid for r in out] == []
    out = eng.release_queued(include_pending=True)
    assert sorted(r.rid for r in out) == [reqs[1].rid, reqs[2].rid]
    assert not eng.scheduler._pending


def test_stolen_before_prefill_token_equality(models):
    """The core steal contract in isolation: steal a request off a hot
    replica before its prefill, serve it cold on another replica, and
    the token stream matches the reference exactly."""
    ref = reference_tokens(models, 11)
    reqs = workload(seed=11)
    engines = [make_engine(models, capacity=2, seed=i) for i in range(2)]
    router = Router(engines, RouterConfig(policy="lot"))
    # pin everything on replica 0 so its queue builds, then steal one
    for r in reqs:
        r.arrival = 0.0  # timing-free: tokens don't depend on arrivals
        router.dispatched_to[r.rid] = 0
    engines[0].add_requests(reqs)
    engines[0].scheduler.poll(0.0)  # arrivals passed: queue materializes
    victim = engines[0].scheduler.steal_candidates()
    assert victim, "capacity 2 with 7 requests must leave a queue"
    rid = victim[0].rid
    out = engines[0].release_queued([rid])
    assert victim[0].prefill_pos == 0 or not engines[0].llm_pool.has(rid)
    engines[1].add_requests(out)
    drive(router, script=[])
    assert rid in engines[1].requests
    for e in engines:
        for r_id, r in e.requests.items():
            assert list(r.emitted[: r.max_new]) == ref[r_id], r_id


# -------------------------------------------------------- replica classes --


def test_parse_replica_classes():
    assert parse_replica_classes("") == []
    assert parse_replica_classes("  ") == []
    assert parse_replica_classes("prefill:1,decode:3") == [
        "prefill",
        "decode",
        "decode",
        "decode",
    ]
    assert parse_replica_classes("general") == ["general"]
    assert parse_replica_classes("decode:2, prefill") == [
        "decode",
        "decode",
        "prefill",
    ]
    with pytest.raises(ValueError):
        parse_replica_classes("turbo:2")
    with pytest.raises(ValueError):
        parse_replica_classes("decode:0")
    with pytest.raises(ValueError):
        parse_replica_classes("decode:x")
    with pytest.raises(ValueError):
        parse_replica_classes(",,")


def test_class_engine_config(models):
    base = EngineConfig(gamma=3, capacity=4, token_budget=32)
    pre = class_engine_config(base, "prefill")
    assert pre.replica_class == "prefill"
    assert pre.prefill_chunk > 0  # chunked ingestion forced on
    assert pre.token_budget == 64  # doubled: chunk grants dominate
    dec = class_engine_config(base, "decode")
    assert dec.replica_class == "decode"
    assert dec.token_budget == base.token_budget
    gen = class_engine_config(base, "general")
    assert gen == base
    with pytest.raises(ValueError):
        class_engine_config(base, "turbo")
    # KV weighting: decode > general > prefill, split conserves the total
    shares = split_weighted(
        1024, [CLASS_KV_WEIGHTS[c] for c in ("prefill", "general", "decode")]
    )
    assert sum(shares) == 1024
    assert shares[0] < shares[1] < shares[2]


def test_prefill_class_caps_adaptive_gamma(models):
    """A prefill-class replica clamps ADAPTIVE speculation shallow (its
    verify budget feeds prompt chunks); fixed policy is untouched —
    the --gamma-policy fixed bit-identity contract."""
    eng = make_engine(
        models,
        capacity=2,
        replica_class="prefill",
        gamma_policy="adaptive",
        gamma_max=6,
    )
    assert eng.gamma_ctl.cfg.depth_cap == 3  # ceil(6 / 2)
    eng_fixed = make_engine(models, capacity=2, replica_class="prefill")
    assert eng_fixed.gamma_ctl.cfg.depth_cap == 2  # ceil(3 / 2), unused
    ids = [0, 1]
    grants = eng.gamma_ctl.grant(ids, {0: 0, 1: 0})
    assert all(g <= 3 for g in grants.values())
    # fixed policy ignores the cap entirely
    grants = eng_fixed.gamma_ctl.grant(ids, {0: 0, 1: 0})
    assert all(g == 3 for g in grants.values())
    eng_gen = make_engine(models, capacity=2)
    assert eng_gen.gamma_ctl.cfg.depth_cap is None
    with pytest.raises(ValueError):
        GammaConfig(depth_cap=0)
    with pytest.raises(ValueError):
        make_engine(models, capacity=2, replica_class="turbo")


def test_class_affine_dispatch(models):
    """Long-prompt requests prefer the prefill replica, decode-heavy
    ones the decode replica; with no matching replica the fleet still
    serves (preference, not partition)."""
    llm, ssms = models
    engines = []
    for i, cls in enumerate(["prefill", "decode"]):
        sel = LBSS(
            SelectorConfig(
                n_ssms=len(ssms), batch_limits=[2] * len(ssms), alpha=4,
                beta=2, seed=i,
            )
        )
        ecfg = class_engine_config(
            EngineConfig(
                gamma=3, max_len=128, capacity=2, packed_bucket=128,
                straggler_mitigation=False, seed=i,
            ),
            cls,
        )
        engines.append(SpinEngine(llm, ssms, sel, ecfg))
    router = Router(engines, RouterConfig(policy="lot"))
    reqs = workload(n=2, seed=61)
    long_prompt, long_out = reqs
    long_prompt.prompt = np.arange(40, dtype=np.int32) % VOCAB
    long_prompt.max_new = 8
    long_out.prompt = np.arange(6, dtype=np.int32) % VOCAB
    long_out.max_new = 20
    assert router._choose(long_prompt) == 0  # prefill replica
    assert router._choose(long_out) == 1  # decode replica
    # a draining preferred replica falls through to the other class
    router.states[1] = "draining"
    assert router._choose(long_out) == 0


# ---------------------------------------------------------- mesh / traces --


def test_elastic_replica_submeshes():
    mesh = M.make_local_mesh(1, 1)
    assert M.elastic_replica_submeshes(mesh, 1) == [mesh]
    with pytest.raises(ValueError):
        M.elastic_replica_submeshes(mesh, 2)  # fleet/mesh mismatch
    with pytest.raises(ValueError):
        M.elastic_replica_submeshes(mesh, 0)


def test_diurnal_arrivals_properties():
    t = diurnal_arrivals(60, rate_base=20.0, rate_peak=200.0, period=1.0,
                         seed=5)
    assert len(t) == 60
    assert np.all(np.diff(t) > 0)  # strictly increasing timestamps
    same = diurnal_arrivals(60, rate_base=20.0, rate_peak=200.0, period=1.0,
                            seed=5)
    assert np.array_equal(t, same)  # deterministic per seed
    other = diurnal_arrivals(60, rate_base=20.0, rate_peak=200.0, period=1.0,
                             seed=6)
    assert not np.array_equal(t, other)
    # the curve starts at the trough: arrivals are denser around the
    # mid-period peak than in the opening trough quarter
    trough = np.sum(t < 0.25)
    peak = np.sum((t >= 0.25) & (t < 0.75))
    assert peak > trough
    with pytest.raises(ValueError):
        diurnal_arrivals(4, rate_base=0.0, rate_peak=10.0, period=1.0)
    with pytest.raises(ValueError):
        diurnal_arrivals(4, rate_base=20.0, rate_peak=10.0, period=1.0)
    with pytest.raises(ValueError):
        diurnal_arrivals(4, rate_base=1.0, rate_peak=2.0, period=0.0)


def test_bursty_arrivals_properties():
    t = bursty_arrivals(80, rate_base=10.0, rate_peak=400.0,
                        burst_every=1.0, burst_len=0.2, seed=7)
    assert len(t) == 80 and np.all(np.diff(t) > 0)
    assert np.array_equal(
        t,
        bursty_arrivals(80, rate_base=10.0, rate_peak=400.0,
                        burst_every=1.0, burst_len=0.2, seed=7),
    )
    # most arrivals land inside the short burst windows
    phase = t % 1.0
    in_burst = np.sum(phase >= 0.8)
    assert in_burst > len(t) / 2
    with pytest.raises(ValueError):
        bursty_arrivals(4, rate_base=1.0, rate_peak=2.0,
                        burst_every=1.0, burst_len=2.0)
    with pytest.raises(ValueError):
        bursty_arrivals(4, rate_base=1.0, rate_peak=2.0,
                        burst_every=0.0, burst_len=0.0)


# ------------------------------------------------------------- validation --


def test_router_config_validation():
    with pytest.raises(ValueError):
        RouterConfig(autoscale="bananas")
    with pytest.raises(ValueError):
        RouterConfig(steal="maybe")
    with pytest.raises(ValueError):
        RouterConfig(replicas_min=0)
    with pytest.raises(ValueError):
        RouterConfig(replicas_min=4, replicas_max=2)
    with pytest.raises(ValueError):
        RouterConfig(occ_low=0.9, occ_high=0.8)
    with pytest.raises(ValueError):
        RouterConfig(cooldown=-1.0)
    with pytest.raises(ValueError):
        RouterConfig(steal_margin=-0.1)
    with pytest.raises(ValueError):
        RouterConfig(classes="turbo:2")
    RouterConfig(autoscale="target-occupancy", replicas_min=2,
                 replicas_max=4, classes="prefill:1,decode:3")


def test_router_rejects_min_above_fleet(models):
    engines = [make_engine(models, capacity=2, seed=i) for i in range(2)]
    with pytest.raises(ValueError):
        Router(engines, RouterConfig(replicas_min=3))
