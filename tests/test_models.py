"""Per-arch smoke tests (reduced configs) + prefill/decode consistency +
chunked-vs-sequential equivalence of the SSM blocks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import mamba2, transformer as T
from repro.models.config import reduced
from repro.optim import AdamW

ASSIGNED = registry.ASSIGNED


def make_inputs(cfg, key, B, S):
    kw = {}
    if cfg.embed_inputs:
        kw["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    else:
        kw["inputs_embeds"] = jax.random.normal(key, (B, S, cfg.d_model),
                                                jnp.float32)
    if cfg.num_prefix_embeds:
        kw["prefix_embeds"] = jax.random.normal(
            key, (B, cfg.num_prefix_embeds, cfg.d_model), jnp.float32)
    return kw


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward(arch):
    """Instantiate the reduced same-family config, one forward step,
    assert output shapes + no NaNs (assignment requirement)."""
    cfg = registry.reduced_for(arch)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    B, S = 2, 32
    kw = make_inputs(cfg, key, B, S)
    logits, aux = T.apply(params, cfg, **kw)
    exp_S = S + (cfg.num_prefix_embeds or 0)
    assert logits.shape == (B, exp_S, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_train_step(arch):
    """One train step on CPU: loss is finite and params update."""
    cfg = registry.reduced_for(arch)
    key = jax.random.PRNGKey(1)
    params = T.init_params(cfg, key)
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)
    step = jax.jit(T.make_train_step(cfg, opt))
    B, S = 2, 16
    kw = make_inputs(cfg, key, B, S)
    batch = dict(kw)
    batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    p2, opt_state, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # at least one param changed
    changed = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert changed


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mixtral-8x22b", "dbrx-132b",
                                  "xlstm-350m", "zamba2-1.2b",
                                  "musicgen-large", "llama-68m"])
def test_prefill_decode_matches_full_forward(arch):
    cfg = registry.reduced_for(arch)
    key = jax.random.PRNGKey(2)
    params = T.init_params(cfg, key)
    B, S, P = 2, 24, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.embed_inputs:
        kw_full = {"tokens": toks}
        kw_pre = {"tokens": toks[:, :P]}
        def step_kw(t):
            return {"tokens": toks[:, t:t + 1]}
    else:
        emb = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
        kw_full = {"inputs_embeds": emb}
        kw_pre = {"inputs_embeds": emb[:, :P]}
        def step_kw(t):
            return {"inputs_embeds": emb[:, t:t + 1]}
    full_logits, _ = T.apply(params, cfg, **kw_full)
    logits_p, cache = T.prefill(params, cfg, max_len=S, **kw_pre)
    np.testing.assert_allclose(np.asarray(logits_p[:, P - 1]),
                               np.asarray(full_logits[:, P - 1]),
                               atol=2e-3, rtol=1e-2)
    lengths = jnp.full((B,), P, jnp.int32)
    for t in range(P, S):
        lg, cache = T.decode_step(params, cfg, cache, lengths=lengths,
                                  **step_kw(t))
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full_logits[:, t]),
                                   atol=2e-3, rtol=1e-2)
        lengths = lengths + 1


def test_sliding_window_ring_buffer_decode():
    cfg = registry.reduced_for("mixtral-8x22b", sliding_window=12)
    key = jax.random.PRNGKey(3)
    params = T.init_params(cfg, key)
    B, S, P = 2, 40, 8
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full_logits, _ = T.apply(params, cfg, tokens=toks)
    _, cache = T.prefill(params, cfg, tokens=toks[:, :P], max_len=S)
    lengths = jnp.full((B,), P, jnp.int32)
    for t in range(P, S):
        lg, cache = T.decode_step(params, cfg, cache,
                                  tokens=toks[:, t:t + 1], lengths=lengths)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full_logits[:, t]),
                                   atol=2e-3, rtol=1e-2)
        lengths = lengths + 1


def test_swa_prefill_longer_than_window():
    """Prefill a prompt longer than the window: ring writes keep the tail."""
    cfg = registry.reduced_for("mixtral-8x22b", sliding_window=8)
    key = jax.random.PRNGKey(4)
    params = T.init_params(cfg, key)
    B, S = 2, 24
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full_logits, _ = T.apply(params, cfg, tokens=toks)
    logits_p, cache = T.prefill(params, cfg, tokens=toks, max_len=S)
    np.testing.assert_allclose(np.asarray(logits_p[:, -1]),
                               np.asarray(full_logits[:, -1]),
                               atol=2e-3, rtol=1e-2)
    # and decode continues correctly off the ring buffer
    lengths = jnp.full((B,), S, jnp.int32)
    nxt = jnp.argmax(logits_p[:, -1:], axis=-1).astype(jnp.int32)
    lg, _ = T.decode_step(params, cfg, cache, tokens=nxt, lengths=lengths)
    assert not bool(jnp.any(jnp.isnan(lg)))


def test_mamba2_chunked_equals_sequential():
    """The chunked SSD form must equal token-by-token recurrence."""
    cfg = registry.reduced_for("zamba2-1.2b")
    key = jax.random.PRNGKey(5)
    spec = mamba2.param_spec(cfg)
    from repro.models import params as pp
    p = pp.init_params(spec, key, jnp.float32)
    B, S = 2, 32
    x = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32) * 0.5
    y_chunk, st_chunk = mamba2.forward(p, x, cfg, chunk=8)
    # sequential: decode token by token
    st = None
    ys = []
    for t in range(S):
        y_t, st = mamba2.forward(p, x[:, t:t + 1], cfg, state=st, chunk=1)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st_chunk.ssd),
                               np.asarray(st.ssd), atol=1e-4, rtol=1e-3)


def test_param_counts_match_analytic():
    """params.count(spec) ~ cfg.params_count() (analytic, used for 6ND)."""
    from repro.models import params as pp
    for arch in ["qwen2-0.5b", "internlm2-20b", "mixtral-8x22b"]:
        cfg = registry.get(arch)
        spec = T.param_spec(cfg)
        real = pp.count(spec)
        approx = cfg.params_count()
        assert abs(real - approx) / real < 0.05, (arch, real, approx)
