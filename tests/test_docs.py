"""The docs gate (tools/check_docs.py) must pass: relative markdown
links resolve and every launch/serve.py flag is documented in
docs/SERVING.md.  Running it as tier-1 keeps docs drift from ever
reaching CI's dedicated docs job."""

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_docs_links_and_flag_coverage():
    env = dict(os.environ)
    src = os.path.join(REPO, "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "check_docs.py")],
        capture_output=True,
        text=True,
        env=env,
        cwd=REPO,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
