"""Continuous-batching scheduler: policy-level unit tests (no models) +
engine-level integration (same-step row recycling, preemption losslessness,
no deadlock at full capacity)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import spec_decode as sd
from repro.core.selector import LBSS, SelectorConfig
from repro.data.workloads import (Request, assign_arrivals, make_workload,
                                  poisson_arrivals)
from repro.serving.engine import EngineConfig, SpinEngine
from repro.serving.scheduler import ContinuousScheduler, SchedulerConfig

VOCAB = 256


def _req(rid, arrival=0.0, prompt_len=8, max_new=8, emitted=None,
         priority=0):
    return Request(rid=rid, dataset="cip", difficulty=0.5,
                   prompt=np.zeros(prompt_len, np.int32), max_new=max_new,
                   arrival=arrival, priority=priority,
                   emitted=list(emitted or []))


# ------------------------------------------------------- policy (no jax) --

def test_admission_fills_free_rows_fifo():
    s = ContinuousScheduler(SchedulerConfig(capacity=2, max_len=64, gamma=3))
    s.submit([_req(i, arrival=0.0) for i in range(4)])
    dec = s.plan(0.0)
    assert [r.rid for r in dec.admit] == [0, 1] and not dec.preempt
    for r in dec.admit:
        s.mark_admitted(r, 0.0)
    # pool full: nothing further admitted, queue keeps the rest in order
    dec = s.plan(0.0)
    assert dec.empty
    assert [r.rid for r in s.waiting] == [2, 3]


def test_future_arrivals_stay_pending_until_clock_reaches_them():
    s = ContinuousScheduler(SchedulerConfig(capacity=4, max_len=64, gamma=3))
    s.submit([_req(0, arrival=0.0), _req(1, arrival=5.0)])
    dec = s.plan(0.0)
    assert [r.rid for r in dec.admit] == [0]
    s.mark_admitted(dec.admit[0], 0.0)
    assert s.next_arrival() == pytest.approx(5.0)
    assert s.plan(4.9).empty
    dec = s.plan(5.0)
    assert [r.rid for r in dec.admit] == [1]


def test_static_policy_gang_admits_only_when_pool_drains():
    s = ContinuousScheduler(SchedulerConfig(capacity=2, max_len=64, gamma=3,
                                            policy="static"))
    s.submit([_req(i) for i in range(3)])
    dec = s.plan(0.0)
    assert [r.rid for r in dec.admit] == [0, 1]
    for r in dec.admit:
        s.mark_admitted(r, 0.0)
    s.mark_finished(0)
    # one row free but the cohort has not drained -> no admission
    assert s.plan(1.0).empty
    s.mark_finished(1)
    dec = s.plan(2.0)
    assert [r.rid for r in dec.admit] == [2]


def test_kv_budget_preempts_latest_arrival_and_reenqueues():
    cfg = SchedulerConfig(capacity=3, max_len=64, gamma=3, kv_budget=40)
    s = ContinuousScheduler(cfg)
    a = _req(0, arrival=0.0, prompt_len=10)
    b = _req(1, arrival=1.0, prompt_len=10)
    s.submit([a, b])
    dec = s.plan(1.0)
    for r in dec.admit:
        s.mark_admitted(r, 1.0)
    assert set(s.running) == {0, 1}
    # both grow past the budget: 2 * (10 + ~12 emitted + gamma + 1) > 40
    a.emitted = list(range(13))
    b.emitted = list(range(13))
    dec = s.plan(2.0)
    assert [r.rid for r in dec.preempt] == [1]   # latest arrival evicted
    s.mark_preempted(dec.preempt[0], 2.0)
    assert b.preemptions == 1
    assert [r.rid for r in s.waiting] == [1]     # re-enqueued for re-prefill
    assert 0 in s.running                        # oldest keeps its row


def test_oversized_request_admitted_into_empty_pool_no_deadlock():
    # a single request whose KV need exceeds the whole budget must still
    # be admitted once the pool is empty, else the queue deadlocks
    s = ContinuousScheduler(SchedulerConfig(capacity=2, max_len=64, gamma=3,
                                            kv_budget=10))
    s.submit([_req(0, prompt_len=30)])
    dec = s.plan(0.0)
    assert [r.rid for r in dec.admit] == [0]


def test_queue_wait_accumulates_across_preempt_readmit_cycles():
    """queue_wait must count every stretch a request spends off a row:
    initial arrival->admission PLUS each preemption->re-admission gap."""
    s = ContinuousScheduler(SchedulerConfig(capacity=2, max_len=64, gamma=3))
    r = _req(0, arrival=1.0)
    s.submit([r])
    dec = s.plan(3.0)                      # waited 1.0 -> 3.0 = 2.0
    s.mark_admitted(dec.admit[0], 3.0)
    assert s.queue_wait == pytest.approx(2.0)
    s.mark_preempted(r, 5.0)               # off-row again at 5.0
    dec = s.plan(9.0)
    assert [x.rid for x in dec.admit] == [0]
    s.mark_admitted(r, 9.0)                # +4.0 re-admission wait
    assert s.queue_wait == pytest.approx(6.0)
    s.mark_preempted(r, 10.0)
    s.mark_admitted(r, 10.5)               # +0.5, third cycle
    assert s.queue_wait == pytest.approx(6.5)


def test_preempted_request_outranks_newer_arrivals_on_readmission():
    """A preempted request re-enters the waiting queue at its original
    rank (priority, arrival, rid), so it is re-admitted before requests
    that arrived after it — preemption must not cost queue position."""
    s = ContinuousScheduler(SchedulerConfig(capacity=1, max_len=64, gamma=3,
                                            kv_budget=30))
    old = _req(0, arrival=0.0, prompt_len=10)
    s.submit([old])
    dec = s.plan(0.0)
    s.mark_admitted(dec.admit[0], 0.0)
    s.submit([_req(1, arrival=1.0, prompt_len=8),
              _req(2, arrival=2.0, prompt_len=8)])
    s.mark_preempted(old, 3.0)             # rids 1, 2 already waiting
    dec = s.plan(3.0)
    assert [x.rid for x in dec.admit] == [0], "preempted oldest first"
    s.mark_admitted(old, 3.0)
    assert [x.rid for x in s.waiting] == [1, 2]
    # a higher-priority late arrival still outranks the preempted request
    s.mark_preempted(old, 4.0)
    s.submit([_req(3, arrival=4.0, prompt_len=8, priority=-1)])
    dec = s.plan(4.0)
    assert [x.rid for x in dec.admit] == [3]


def test_poisson_arrivals_monotone_and_rate_roughly_right():
    times = poisson_arrivals(2000, rate=50.0, seed=3)
    assert np.all(np.diff(times) > 0)
    assert times[-1] == pytest.approx(2000 / 50.0, rel=0.2)
    reqs = [_req(i) for i in range(4)]
    assign_arrivals(reqs, trace=[0.5, 1.5, 2.5, 3.5])
    assert [r.arrival for r in reqs] == [0.5, 1.5, 2.5, 3.5]
    with pytest.raises(ValueError):
        assign_arrivals(reqs, rate=1.0, trace=[1.0] * 4)
    with pytest.raises(ValueError):
        assign_arrivals(reqs, trace=[1.0])


# ------------------------------------------------------ engine-level -----

@pytest.fixture(scope="module")
def models():
    key = jax.random.PRNGKey(0)
    cfg_llm = registry.reduced_for("llama-7b", d_model=96, n_heads=4,
                                   n_kv_heads=4, vocab_size=VOCAB)
    llm = sd.Bundle(cfg_llm, T_init(cfg_llm, key))
    ssms = []
    for i, (d, L) in enumerate([(32, 1), (64, 2)]):
        c = registry.reduced_for("llama-68m", d_model=d, n_heads=4,
                                 n_kv_heads=4, vocab_size=VOCAB, n_layers=L)
        ssms.append(sd.Bundle(c, T_init(c, jax.random.PRNGKey(i + 1))))
    return llm, ssms


def T_init(cfg, key):
    from repro.models import transformer as T
    return T.init_params(cfg, key)


def greedy_reference(llm, prompt, n_new):
    P = len(prompt)
    toks = jnp.asarray(np.asarray(prompt, np.int32))[None]
    lg, cache = llm.prefill(toks, jnp.asarray([P], jnp.int32), P + n_new + 8)
    V = llm.cfg.vocab_size
    tok = jnp.argmax(lg[:, P - 1, :V], -1, keepdims=True).astype(jnp.int32)
    out = [int(tok[0, 0])]
    lengths = jnp.asarray([P], jnp.int32)
    for _ in range(n_new - 1):
        lg2, cache = llm.decode(cache, tok, lengths)
        tok = jnp.argmax(lg2[:, -1, :V], -1, keepdims=True).astype(jnp.int32)
        lengths = lengths + 1
        out.append(int(tok[0, 0]))
    return out


def _engine(llm, ssms, **kw):
    sel = LBSS(SelectorConfig(n_ssms=len(ssms),
                              batch_limits=[kw.get("capacity", 4)] * len(ssms),
                              alpha=4, beta=2, seed=1))
    defaults = dict(gamma=3, max_len=128, use_packed_verify=True,
                    packed_bucket=128, straggler_mitigation=False)
    defaults.update(kw)
    return SpinEngine(llm, ssms, sel, EngineConfig(**defaults))


def test_finished_rows_recycled_within_same_step(models):
    llm, ssms = models
    eng = _engine(llm, ssms, capacity=2)
    reqs = make_workload("cp", 5, VOCAB, seed=11, scale=0.25)
    eng.add_requests(reqs)
    for _ in range(300):
        rec = eng.step()
        if rec.get("done"):
            break
        # invariant: a row never idles across a slot boundary while the
        # queue is non-empty — finish+admit happen inside one step()
        if eng.scheduler.waiting:
            assert len(eng.scheduler.running) == 2, rec
    assert all(r.done for r in eng.requests.values())
    assert eng.scheduler.admissions == 5


def test_preemption_and_readmission_is_greedy_exact(models):
    llm, ssms = models
    # budget = 6 blocks of 16 cells: three requests fit at admission
    # (2 blocks each) and outgrow the budget mid-flight -> preemption
    eng = _engine(llm, ssms, capacity=3, kv_budget=96)
    reqs = make_workload("mix", 5, VOCAB, seed=3, scale=0.25,
                         arrival_rate=500.0)
    eng.add_requests(reqs)
    eng.run(max_slots=400)
    assert eng.scheduler.preemptions > 0, "budget never bound: tune test"
    for r in eng.requests.values():
        assert r.done, r.rid
        want = greedy_reference(llm, r.prompt, r.max_new)
        assert r.emitted[:r.max_new] == want, r.rid
    assert all(r.finish_time is not None and r.latency >= 0
               for r in eng.requests.values())


def test_full_pool_arrival_stream_drains_without_deadlock(models):
    llm, ssms = models
    eng = _engine(llm, ssms, capacity=2, kv_budget=40)
    reqs = make_workload("cp", 8, VOCAB, seed=23, scale=0.25,
                         arrival_rate=1000.0)   # burst: all arrive at once
    eng.add_requests(reqs)
    stats = eng.run(max_slots=600)
    assert all(r.done for r in eng.requests.values())
    assert not eng.scheduler.outstanding
    assert stats["scheduler"]["finished"] == 8


def test_continuous_beats_static_on_same_trace(models):
    llm, ssms = models

    def run(policy):
        eng = _engine(llm, ssms, capacity=2, scheduler_policy=policy)
        reqs = make_workload("cp", 6, VOCAB, seed=9, scale=0.25,
                             arrival_rate=300.0)
        eng.add_requests(reqs)
        st = eng.run(max_slots=400)
        assert all(r.done for r in eng.requests.values())
        return st

    cont, stat = run("continuous"), run("static")
    assert cont["goodput_sim"] > stat["goodput_sim"]
