"""Micro-batch pipeline (paper §V-B): simulator invariants + heuristic."""

import numpy as np

from repro.core.pipeline import (CostModel, choose_micro_batches,
                                 simulate, sweep_micro_batches)


def hetero_cost(gamma=4):
    # 4 SSMs: fast-but-weak to slow-but-strong (paper's 68M..1.4B spread)
    return CostModel(
        ssm_time_per_token=[0.4e-3, 0.8e-3, 1.6e-3, 3.2e-3],
        ssm_fixed=[0.2e-3] * 4,
        llm_fixed=1.0e-3,
        llm_time_per_token=1.2e-3,
        gamma=gamma)


def test_pipelining_reduces_llm_idle():
    # paper's regime: heterogeneous SSM speeds dominate; the LLM waits on
    # the slowest SSM (Fig. 6a) unless micro-batched (Fig. 6b).
    cost = CostModel(ssm_time_per_token=[0.5e-3, 1e-3, 2e-3, 8e-3],
                     ssm_fixed=[0.1e-3] * 4,
                     llm_fixed=0.2e-3, llm_time_per_token=0.3e-3, gamma=4)
    batches = [8, 8, 8, 8]
    nosplit = simulate(cost, batches, [1, 1, 1, 1])
    split = simulate(cost, batches, [4, 4, 4, 4])
    assert split.llm_idle_frac < nosplit.llm_idle_frac
    assert split.makespan < nosplit.makespan


def test_goodput_peaks_then_degrades():
    """Paper Fig. 13: goodput rises with micro-batches up to a point, then
    sequentialization overhead wins."""
    cost = CostModel(ssm_time_per_token=[0.3e-3, 4.0e-3],
                     ssm_fixed=[0.5e-3] * 2,
                     llm_fixed=3.0e-3, llm_time_per_token=0.8e-3, gamma=4)
    sweep = sweep_micro_batches(cost, [12, 12], [0.7, 0.9], max_mb=10)
    gs = [g for _, g in sweep]
    best = int(np.argmax(gs))
    assert 0 < best < 9          # interior optimum
    assert gs[best] > gs[0]      # pipelining helps
    assert gs[-1] < gs[best]     # over-splitting hurts


def test_heuristic_close_to_optimal():
    cost = hetero_cost()
    batches = [8, 6, 8, 10]
    rates = [0.4, 0.55, 0.7, 0.8]
    mb, g_h = choose_micro_batches(cost, batches, rates)
    sweep = sweep_micro_batches(cost, batches, rates, max_mb=12)
    g_best = max(g for _, g in sweep)
    assert g_h >= 0.9 * g_best, (mb, g_h, g_best)


def test_simulator_conserves_work():
    cost = hetero_cost()
    batches = [4, 0, 2, 0]
    sim = simulate(cost, batches, [2, 1, 2, 1])
    # busy time equals sum of verification durations regardless of split
    want = sum(cost.verify_time(s) for s in [2, 2, 1, 1])
    assert abs(sim.llm_busy - want) < 1e-9
