"""SpinEngine integration: losslessness of the full system (heterogeneous
SSMs + LBSS switching + packed verification), fault tolerance, stragglers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import spec_decode as sd
from repro.core.selector import LBSS, SelectorConfig
from repro.data.workloads import make_workload
from repro.models import transformer as T
from repro.serving.engine import EngineConfig, SpinEngine

VOCAB = 256


@pytest.fixture(scope="module")
def models():
    key = jax.random.PRNGKey(0)
    cfg_llm = registry.reduced_for("llama-7b", d_model=96, n_heads=4,
                                   n_kv_heads=4, vocab_size=VOCAB)
    llm = sd.Bundle(cfg_llm, T.init_params(cfg_llm, key))
    ssms = []
    for i, (d, L) in enumerate([(32, 1), (64, 2)]):
        c = registry.reduced_for("llama-68m", d_model=d, n_heads=4,
                                 n_kv_heads=4, vocab_size=VOCAB, n_layers=L)
        ssms.append(sd.Bundle(c, T.init_params(c, jax.random.PRNGKey(i + 1))))
    return llm, ssms


def greedy_reference(llm, prompt, n_new):
    P = len(prompt)
    toks = jnp.asarray(np.asarray(prompt, np.int32))[None]
    lg, cache = llm.prefill(toks, jnp.asarray([P], jnp.int32), P + n_new + 8)
    V = llm.cfg.vocab_size
    tok = jnp.argmax(lg[:, P - 1, :V], -1, keepdims=True).astype(jnp.int32)
    out = [int(tok[0, 0])]
    lengths = jnp.asarray([P], jnp.int32)
    for _ in range(n_new - 1):
        lg2, cache = llm.decode(cache, tok, lengths)
        tok = jnp.argmax(lg2[:, -1, :V], -1, keepdims=True).astype(jnp.int32)
        lengths = lengths + 1
        out.append(int(tok[0, 0]))
    return out


@pytest.mark.parametrize("packed", [True, False])
def test_engine_output_is_lossless(models, packed):
    """The whole system (selector switches, packed verify, pools, rollback)
    must emit exactly the plain-LLM greedy continuation per request."""
    llm, ssms = models
    sel = LBSS(SelectorConfig(n_ssms=len(ssms),
                              batch_limits=[6] * len(ssms),
                              alpha=4, beta=2, seed=1))
    ecfg = EngineConfig(gamma=3, max_len=128, capacity=6,
                        use_packed_verify=packed, use_pipeline=True,
                        packed_bucket=128)
    eng = SpinEngine(llm, ssms, sel, ecfg)
    reqs = make_workload("mix", 5, VOCAB, seed=3, scale=0.25)
    eng.add_requests(reqs)
    eng.run(max_slots=80)
    for r in eng.requests.values():
        assert r.done
        want = greedy_reference(llm, r.prompt, r.max_new)
        assert r.emitted[:r.max_new] == want, r.rid


def test_engine_survives_ssm_failure(models):
    llm, ssms = models
    sel = LBSS(SelectorConfig(n_ssms=len(ssms),
                              batch_limits=[6] * len(ssms),
                              alpha=4, beta=2, seed=2))
    ecfg = EngineConfig(gamma=3, max_len=128, capacity=6,
                        use_packed_verify=False)
    eng = SpinEngine(llm, ssms, sel, ecfg)
    reqs = make_workload("cip", 4, VOCAB, seed=5, scale=0.25)
    eng.add_requests(reqs)
    eng.step()
    eng.fail_ssm(0)                      # kill a replica mid-flight
    eng.run(max_slots=80)
    for r in eng.requests.values():
        assert r.done
        want = greedy_reference(llm, r.prompt, r.max_new)
        assert r.emitted[:r.max_new] == want, r.rid


def test_straggler_mitigation_bounds_makespan(models):
    llm, ssms = models
    def build(mitigate):
        sel = LBSS(SelectorConfig(n_ssms=len(ssms),
                                  batch_limits=[6] * len(ssms),
                                  alpha=4, beta=2, seed=3))
        ecfg = EngineConfig(gamma=3, max_len=128, capacity=4,
                            use_packed_verify=False,
                            straggler_mitigation=mitigate,
                            straggler_factor=1.2)
        return SpinEngine(llm, ssms, sel, ecfg)
    e1 = build(True)
    reqs = make_workload("cp", 4, VOCAB, seed=7, scale=0.25)
    e1.add_requests(reqs)
    e1.run(max_slots=60)
    assert e1.straggler_redispatches > 0
    for r in e1.requests.values():
        assert r.done
