"""SLO-aware serving (ISSUE 9): the ``Request.slo`` contract threaded
scheduler -> gamma -> router.

Policy-level: admission ranking is total and deterministic, falls back
byte-for-byte to the pre-SLO ``(priority, arrival, rid)`` key for
contract-free requests, preemption victims are farthest-from-deadline
first, TTFT slack boosts prefill chunks, and the gamma controller trims
speculation depth to deadline headroom.  Config-level: the ``from_args``
constructors are THE flag translation (defaults match ``build_parser``,
invalid combinations raise).  Engine-level: a stamped stream under
``slo_aware=False`` is bit-identical (tokens AND sim clock) to the
unstamped pre-SLO engine, the aware path stays lossless, and
``token_times`` stamps every emitted token on the sim clock.
"""

import math
import random

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.core import spec_decode as sd
from repro.core.gamma import GammaConfig, GammaController
from repro.core.pipeline import CostModel
from repro.core.selector import LBSS, SelectorConfig
from repro.data.workloads import SLO, SLO_PROFILES, Request, assign_slos, make_workload
from repro.launch.serve import build_parser
from repro.models import transformer as T
from repro.serving.engine import EngineConfig, SpinEngine
from repro.serving.router import Router, RouterConfig
from repro.serving.scheduler import (
    ContinuousScheduler,
    SchedulerConfig,
    _blind_rank,
    _rank,
)
from repro.serving.stats import (
    DEADLINE_HORIZON,
    EngineStats,
    SLOSummary,
    min_outstanding_deadline,
    slo_headroom,
    slo_summary,
)

VOCAB = 256


def _req(rid, arrival=0.0, prompt_len=8, max_new=8, priority=0, slo=None, emitted=None):
    return Request(
        rid=rid,
        dataset="cip",
        difficulty=0.5,
        prompt=np.zeros(prompt_len, np.int32),
        max_new=max_new,
        arrival=arrival,
        priority=priority,
        slo=slo,
        emitted=list(emitted or []),
    )


# ---------------------------------------------------------- the contract --


def test_token_deadline_chain():
    s = SLO(ttft_deadline=0.1, tpot_target=0.01)
    assert s.token_deadline(2.0, 0) == pytest.approx(2.1)
    assert s.token_deadline(2.0, 5) == pytest.approx(2.15)


def test_next_deadline_inf_without_contract_else_next_token():
    r = _req(0, arrival=1.0)
    assert r.next_deadline() == math.inf
    r = _req(1, arrival=1.0, slo=SLO(0.1, 0.01), emitted=[7, 7])
    assert r.next_deadline() == pytest.approx(1.0 + 0.1 + 2 * 0.01)


def test_assign_slos_profiles_and_scale():
    reqs = [_req(0), _req(1)]
    assert assign_slos(reqs, "off") == reqs
    assert all(r.slo is None for r in reqs)
    assign_slos(reqs, "strict", scale=2.0)
    want = SLO_PROFILES["strict"]["cip"]
    assert reqs[0].slo.ttft_deadline == pytest.approx(2.0 * want.ttft_deadline)
    assert reqs[0].slo.tpot_target == pytest.approx(2.0 * want.tpot_target)
    with pytest.raises(ValueError, match="unknown SLO profile"):
        assign_slos(reqs, "nope")


# ------------------------------------------------------ admission ranking --


def test_rank_orders_deadline_closest_first():
    lax = _req(0, arrival=0.0, slo=SLO(1.0, 0.06))
    strict = _req(1, arrival=0.01, slo=SLO(0.05, 0.006))
    none = _req(2, arrival=0.0)
    order = sorted([none, lax, strict], key=_rank)
    assert [r.rid for r in order] == [1, 0, 2]


def test_rank_total_deterministic_and_falls_back_to_pre_slo_key():
    """Property: over random mixes of stamped/unstamped requests the
    ranking is a total order (any shuffle sorts identically) and ties on
    the deadline — including the all-inf contract-free case — break by
    exactly the pre-SLO ``(priority, arrival, rid)`` key."""
    rng = random.Random(7)
    for _trial in range(50):
        reqs = []
        for rid in range(rng.randrange(2, 20)):
            slo = None
            if rng.random() >= 0.4:
                ttft = rng.choice([0.05, 0.1, 0.1, 1.0])
                slo = SLO(ttft, rng.choice([0.006, 0.015]))
            reqs.append(
                _req(
                    rid,
                    arrival=rng.choice([0.0, 0.5, 1.0]),
                    priority=rng.randrange(2),
                    slo=slo,
                )
            )
        base = sorted(reqs, key=_rank)
        for _ in range(3):
            rng.shuffle(reqs)
            assert [r.rid for r in sorted(reqs, key=_rank)] == [r.rid for r in base]
        # equal-deadline runs are ordered by the pre-SLO key
        for a, b in zip(base, base[1:]):
            if a.next_deadline() == b.next_deadline():
                assert (a.priority, a.arrival, a.rid) < (b.priority, b.arrival, b.rid)


def test_contract_free_ordering_is_byte_identical_to_pre_slo():
    rng = random.Random(11)
    reqs = [
        _req(rid, arrival=rng.choice([0.0, 0.5, 1.0]), priority=rng.randrange(3))
        for rid in range(30)
    ]
    rng.shuffle(reqs)
    aware = [r.rid for r in sorted(reqs, key=_rank)]
    blind = [r.rid for r in sorted(reqs, key=_blind_rank)]
    pre_slo = [
        r.rid for r in sorted(reqs, key=lambda r: (r.priority, r.arrival, r.rid))
    ]
    assert aware == blind == pre_slo
    assert all(_rank(r)[0] == math.inf for r in reqs)


def test_scheduler_admits_deadline_first_blind_admits_arrival_first():
    def sched(aware):
        s = ContinuousScheduler(
            SchedulerConfig(capacity=1, max_len=64, gamma=3, slo_aware=aware)
        )
        s.submit(
            [
                _req(0, arrival=0.0, slo=SLO(1.0, 0.06)),
                _req(1, arrival=0.001, slo=SLO(0.05, 0.006)),
            ]
        )
        return [r.rid for r in s.plan(0.001).admit]

    assert sched(True) == [1]  # strict request jumps the lax earlier one
    assert sched(False) == [0]  # deadline-blind: plain arrival order


# ------------------------------------------------------ preemption order --


def test_preemption_victim_is_farthest_from_deadline():
    """Under KV pressure the victim is the most-slack runner; a request
    already past its deadline is never the victim over a same-priority
    runner with slack."""
    cfg = SchedulerConfig(capacity=3, max_len=64, gamma=3, kv_budget=40, min_running=1)
    s = ContinuousScheduler(cfg)
    late = _req(0, arrival=0.0, prompt_len=10, slo=SLO(0.01, 0.001))
    lax = _req(1, arrival=0.0, prompt_len=10, slo=SLO(5.0, 0.06))
    s.submit([late, lax])
    for r in [late, lax]:
        s.mark_admitted(r, 0.0)
    # clock far past `late`'s deadline; both outgrow the budget
    for r in [late, lax]:
        r.emitted.extend([7] * 12)
    dec = s.plan(1.0)
    assert [r.rid for r in dec.preempt] == [1]
    assert late.rid in s.running


def test_blind_preemption_keeps_pre_slo_victim_order():
    cfg = SchedulerConfig(
        capacity=3, max_len=64, gamma=3, kv_budget=40, min_running=1, slo_aware=False
    )
    s = ContinuousScheduler(cfg)
    a = _req(0, arrival=0.0, prompt_len=10, slo=SLO(5.0, 0.06))
    b = _req(1, arrival=0.5, prompt_len=10, slo=SLO(0.01, 0.001))
    s.submit([a, b])
    for r in [a, b]:
        s.mark_admitted(r, 0.5)
        r.emitted.extend([7] * 12)
    # blind: latest arrival is the victim, contracts ignored
    assert [r.rid for r in s.plan(1.0).preempt] == [1]


# ---------------------------------------------------------- chunk boosts --


def test_ttft_slack_boosts_prefill_chunk():
    cfg = SchedulerConfig(capacity=2, max_len=128, gamma=3, prefill_chunk=8)
    s = ContinuousScheduler(cfg)
    r = _req(0, arrival=0.0, prompt_len=64, slo=SLO(0.03, 0.01))
    s.submit([r])
    dec = s.plan(0.0)
    for x in dec.admit:
        s.mark_admitted(x, 0.0)
    # no cadence estimate yet -> flat chunk
    assert s._slo_chunk(r, 64, 0.0) == 8
    # two plan calls 10ms apart establish the slot cadence: ~20ms of
    # slack / 10ms slots = ~2 slots for 64 tokens -> ~32-token chunks
    # (33 after float rounding in the slack division)
    s.plan(0.01)  # this plan's own chunk pass already boosts once
    assert s._slot_dt == pytest.approx(0.01)
    before = s.slo_chunk_boosts
    assert before >= 1
    assert 32 <= s._slo_chunk(r, 64, 0.01) <= 33
    assert s.slo_chunk_boosts == before + 1
    # contract-free request keeps the flat chunk
    assert s._slo_chunk(_req(9, prompt_len=64), 64, 0.01) == 8


def test_blind_scheduler_never_boosts_chunks():
    cfg = SchedulerConfig(
        capacity=2, max_len=128, gamma=3, prefill_chunk=8, slo_aware=False
    )
    s = ContinuousScheduler(cfg)
    r = _req(0, arrival=0.0, prompt_len=64, slo=SLO(0.03, 0.01))
    s.submit([r])
    s.plan(0.0)
    s.plan(0.01)
    assert s._slo_chunk(r, 64, 0.01) == 8
    assert s.slo_chunk_boosts == 0


# ------------------------------------------------------------- gamma cap --


def _controller(gamma=4):
    cost = CostModel(
        ssm_time_per_token=[1e-4, 2e-4],
        ssm_fixed=[2e-4, 2e-4],
        llm_fixed=1e-3,
        llm_time_per_token=5e-4,
        gamma=gamma,
    )
    return GammaController(
        GammaConfig(policy="adaptive", gamma=gamma, gamma_max=8), cost
    )


def test_gamma_slo_cap_trims_to_slack():
    ctl = _controller(gamma=4)
    # iteration_time(0, k) = 2e-4 + k*1e-4 + 1e-3 + (k+1)*5e-4
    assert ctl.iteration_time(0, 2) < 3e-3 < ctl.iteration_time(0, 3)
    depths = ctl.grant([0], {0: 0}, slo_slack={0: 3e-3})
    assert depths[0] == 2
    assert ctl.slo_capped == 2
    assert ctl.stats["slo_capped"] == 2


def test_gamma_slo_cap_floor_is_depth_one():
    ctl = _controller(gamma=4)
    # positive slack smaller than even a depth-1 iteration: floor at 1,
    # never 0 (the slot must still make progress)
    assert ctl.grant([0], {0: 0}, slo_slack={0: 1e-9}) == {0: 1}


def test_gamma_slo_cap_skips_past_deadline_and_contract_free():
    ctl = _controller(gamma=4)
    depths = ctl.grant([0, 1, 2], {0: 0, 1: 0, 2: 0}, slo_slack={0: -1.0, 1: 0.0})
    # past-deadline (slack <= 0) and contract-free (absent) requests
    # keep the throughput-optimal depth
    assert depths == {0: 4, 1: 4, 2: 4}
    assert ctl.slo_capped == 0


def test_gamma_fixed_policy_ignores_slack():
    cost = CostModel(
        ssm_time_per_token=[1e-4],
        ssm_fixed=[2e-4],
        llm_fixed=1e-3,
        llm_time_per_token=5e-4,
        gamma=4,
    )
    ctl = GammaController(GammaConfig(policy="fixed", gamma=4), cost)
    assert ctl.grant([0], {0: 0}, slo_slack={0: 1e-9}) == {0: 4}


# ----------------------------------------------------- stats + summaries --


def test_slo_summary_counts_deadline_met_tokens():
    ok = _req(0, arrival=0.0, max_new=2, slo=SLO(0.1, 0.01), emitted=[7, 7])
    ok.first_token_time = 0.05
    ok.token_times = [0.05, 0.11]  # both inside the chain
    late = _req(1, arrival=0.0, max_new=2, slo=SLO(0.1, 0.01), emitted=[7, 7])
    late.first_token_time = 0.2  # TTFT bust: every token late
    late.token_times = [0.2, 0.3]
    free = _req(2, arrival=0.0, max_new=2, emitted=[7, 7])
    summ = slo_summary([ok, late, free])
    assert summ.slo_requests == 2 and summ.slo_tokens == 4
    assert summ.tokens_met == 2 and summ.ttft_met == 1
    assert summ.attainment == pytest.approx(0.5)
    assert summ.goodput_under_slo(2.0) == pytest.approx(1.0)
    assert summ.asdict()["attainment"] == pytest.approx(0.5)


def test_slo_summary_vacuous_attainment_without_contracts():
    summ = slo_summary([_req(0, emitted=[7])])
    assert summ.slo_tokens == 0 and summ.attainment == 1.0
    assert summ.goodput_under_slo(1.0) == 0.0


def test_headroom_horizon_and_min_deadline():
    assert min_outstanding_deadline([_req(0)]) == math.inf
    r = _req(1, arrival=0.0, slo=SLO(0.1, 0.01))
    assert min_outstanding_deadline([r, _req(0)]) == pytest.approx(0.1)
    # deadline-free cluster reads the horizon minus backlog drain time
    h = slo_headroom(
        math.inf, sim_time=2.0, outstanding_tokens=100, time_per_token=0.01
    )
    assert h == pytest.approx(DEADLINE_HORIZON - 1.0)
    assert slo_headroom(2.5, 2.0, 100, 0.01) == pytest.approx(0.5 - 1.0)


# ------------------------------------------------- from_args translation --


def test_engine_config_from_args_matches_parser_defaults():
    args = build_parser().parse_args([])
    ecfg = EngineConfig.from_args(args)
    assert ecfg.gamma == 4 and ecfg.gamma_policy == "fixed"
    assert ecfg.capacity == args.requests  # --capacity unset
    assert ecfg.kv_layout == "paged" and ecfg.block_size == 16
    assert ecfg.slo_aware is False  # --slo-profile off
    ecfg = EngineConfig.from_args(
        build_parser().parse_args(["--slo-profile", "interactive", "--capacity", "5"])
    )
    assert ecfg.slo_aware is True and ecfg.capacity == 5


def test_scheduler_config_from_args_resolves_worst_case_gamma():
    args = build_parser().parse_args(["--gamma-policy", "adaptive", "--gamma", "3"])
    scfg = SchedulerConfig.from_args(args)
    assert scfg.gamma == 6  # 2 * gamma, no --gamma-max
    assert scfg.slo_aware is False
    args = build_parser().parse_args(
        [
            "--gamma-policy",
            "adaptive",
            "--gamma",
            "3",
            "--gamma-max",
            "5",
            "--slo-profile",
            "strict",
        ]
    )
    scfg = SchedulerConfig.from_args(args, capacity=2, kv_budget=64)
    assert scfg.gamma == 5 and scfg.capacity == 2 and scfg.kv_budget == 64
    assert scfg.slo_aware is True


def test_router_config_from_args():
    assert RouterConfig.from_args(build_parser().parse_args([])).policy == "lot"
    args = build_parser().parse_args(["--router-policy", "slo"])
    assert RouterConfig.from_args(args).policy == "slo"
    with pytest.raises(ValueError, match="unknown router policy"):
        RouterConfig(policy="nope")


@pytest.mark.parametrize(
    "flags,match",
    [
        (["--block-size", "0"], "--block-size"),
        (["--token-budget", "0"], "--token-budget"),
        (["--gamma", "0"], "--gamma"),
        (["--prefill-chunk", "-1"], "--prefill-chunk"),
        (
            [
                "--spec-shape",
                "tree",
                "--gamma-policy",
                "adaptive",
                "--gamma-max",
                "40",
                "--spec-branch",
                "8",
            ],
            "tree nodes",
        ),
    ],
)
def test_engine_config_from_args_rejects_invalid_combos(flags, match):
    args = build_parser().parse_args(flags)
    with pytest.raises(ValueError, match=match):
        EngineConfig.from_args(args)


# ------------------------------------------------------ engine contracts --


@pytest.fixture(scope="module")
def models():
    key = jax.random.PRNGKey(0)
    cfg_llm = registry.reduced_for(
        "llama-7b", d_model=96, n_heads=4, n_kv_heads=4, vocab_size=VOCAB
    )
    llm = sd.Bundle(cfg_llm, T.init_params(cfg_llm, key))
    ssms = []
    for i, (d, L) in enumerate([(32, 1), (64, 2)]):
        c = registry.reduced_for(
            "llama-68m",
            d_model=d,
            n_heads=4,
            n_kv_heads=4,
            vocab_size=VOCAB,
            n_layers=L,
        )
        ssms.append(sd.Bundle(c, T.init_params(c, jax.random.PRNGKey(i + 1))))
    return llm, ssms


def _engine(models, *, slo_aware, **kw):
    llm, ssms = models
    cap = kw.pop("capacity", 4)
    sel = LBSS(
        SelectorConfig(
            n_ssms=len(ssms), batch_limits=[cap] * 2, alpha=4, beta=2, seed=0
        )
    )
    ecfg = EngineConfig(
        gamma=3,
        max_len=128,
        capacity=cap,
        packed_bucket=128,
        straggler_mitigation=False,
        slo_aware=slo_aware,
        **kw,
    )
    return SpinEngine(llm, ssms, sel, ecfg)


def _workload(profile):
    return make_workload(
        "mix",
        6,
        VOCAB,
        seed=3,
        scale=0.25,
        arrival_rate=400.0,
        slo_profile=profile,
        slo_scale=2.0,
    )


_CHUNKED = dict(
    gamma_policy="adaptive",
    gamma_max=4,
    prefill_chunk=8,
    token_budget=30,
    kv_budget=256,
)


@pytest.mark.parametrize("kw", [{}, _CHUNKED], ids=["plain", "chunked-adaptive"])
def test_stamped_blind_engine_bit_identical_to_unstamped(models, kw):
    """``--slo-profile off`` contract, engine half: a stamped stream run
    deadline-blind produces the exact pre-SLO timeline — same tokens AND
    same sim clock as the unstamped default engine."""
    ref = _engine(models, slo_aware=True, **kw)  # unstamped = PR 8
    ref.add_requests(_workload("off"))
    ref.run(max_slots=600)
    blind = _engine(models, slo_aware=False, **kw)
    blind.add_requests(_workload("interactive"))
    blind.run(max_slots=600)
    assert blind.sim_time == ref.sim_time
    for rid, r in ref.requests.items():
        assert blind.requests[rid].emitted == r.emitted
    assert blind.accepted_tokens == ref.accepted_tokens


def test_slo_aware_engine_lossless_and_stamps_token_times(models):
    """Deadline-aware scheduling reorders work, never changes outputs:
    the aware run emits exactly the blind run's tokens per request, and
    every emitted token carries a sim-clock stamp (monotone, >= arrival,
    first stamp == first_token_time)."""
    blind = _engine(models, slo_aware=False, **_CHUNKED)
    blind.add_requests(_workload("interactive"))
    blind.run(max_slots=600)
    eng = _engine(models, slo_aware=True, **_CHUNKED)
    eng.add_requests(_workload("interactive"))
    st = eng.run(max_slots=600)
    assert st["scheduler"]["finished"] == 6
    for rid, r in eng.requests.items():
        assert r.emitted == blind.requests[rid].emitted
        assert len(r.token_times) == len(r.emitted)
        assert all(a <= b for a, b in zip(r.token_times, r.token_times[1:]))
        assert r.token_times[0] >= r.arrival
        assert r.token_times[0] == pytest.approx(r.first_token_time)
    summ = st["slo"]
    assert summ["slo_requests"] == 6
    assert 0.0 <= summ["attainment"] <= 1.0


def test_engine_snapshot_is_typed_and_consistent(models):
    eng = _engine(models, slo_aware=True)
    eng.add_requests(_workload("interactive"))
    snap = eng.snapshot()
    assert isinstance(snap, EngineStats)
    with pytest.raises(AttributeError):  # frozen — no loose mutation
        snap.sim_time = 1.0
    assert snap.sim_time == eng.sim_time
    assert snap.scheduler.queue_depth + snap.scheduler.running >= 0
    assert snap.scheduler.min_deadline < math.inf  # contracts outstanding
    d = snap.asdict()
    assert d["scheduler"]["min_deadline"] == snap.scheduler.min_deadline
    eng.run(max_slots=600)
    snap = eng.snapshot()
    assert snap.scheduler.min_deadline == math.inf  # drained
    assert snap.outstanding_tokens == 0


def test_router_slo_policy_deterministic_dispatch(models):
    def run():
        engines = [
            _engine(models, slo_aware=True, capacity=2, kv_budget=256)
            for _ in range(2)
        ]
        router = Router(engines, RouterConfig(policy="slo", seed=5))
        router.submit(_workload("interactive"))
        return router.run(max_slots=800)

    a, b = run(), run()
    assert a["dispatched"] == b["dispatched"]
    assert sum(a["dispatched"]) == 6 and a["finished"] == 6
    assert a["slo"]["slo_requests"] == 6
    # replica_snapshot is the typed view, serialized at the JSON boundary
    assert [s["replica"] for s in a["replica_snapshot"]] == [0, 1]
    assert isinstance(SLOSummary(0, 0, 0, 0).attainment, float)
