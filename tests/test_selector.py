"""LBSS selector (paper §IV): matching optimality, batch caps, chunked
exploration, empirical O(log T)-style regret, baseline comparison."""


import numpy as np

from repro.core.selector import (LBSS, EpsilonGreedy, GreedyPromptLength,
                                 SelectorConfig, km_match)


def test_km_matching_is_optimal_small():
    W = np.array([[10.0, 2.0], [8.0, 6.0]])
    cols = km_match(W)
    # optimal: r0->c0 (10) + r1->c1 (6) = 16 beats r0->c0? greedy would also
    # find it; check against brute force
    assert cols == [0, 1]


def test_km_respects_replicated_slots():
    cfg = SelectorConfig(n_ssms=2, batch_limits=[1, 2])
    sel = LBSS(cfg)
    # request 0,1,2 all prefer ssm 0, but it only has 1 slot
    for i in range(3):
        sel.observe(i, 0, 10.0)
        sel.observe(i, 1, 1.0)
    out = sel._matching([0, 1, 2])
    assert sorted(out.values()) == [0, 1, 1]


class SynthEnv:
    """Stationary goodput per (request, ssm) + noise; difficulty-dependent
    optimum (mirrors paper Fig. 2/3)."""

    def __init__(self, n_req, n_ssm, seed=0):
        rng = np.random.default_rng(seed)
        # each request has a 'difficulty'; best ssm index ~ difficulty
        self.best = rng.integers(0, n_ssm, n_req)
        self.g = np.zeros((n_req, n_ssm))
        for i in range(n_req):
            for j in range(n_ssm):
                self.g[i, j] = 5.0 - 1.5 * abs(int(self.best[i]) - j) \
                    + rng.normal(0, 0.1)
        self.rng = rng

    def goodput(self, i, j):
        return max(0.0, self.g[i, j] + self.rng.normal(0, 0.3))

    def opt(self, i):
        return float(np.max(self.g[i]))


def run_selector(sel, env, n_req, T):
    regret = []
    cum = 0.0
    ids = list(range(n_req))
    for t in range(T):
        assign = sel.assign(ids)
        inst = 0.0
        for i, j in assign.items():
            r = env.goodput(i, j)
            sel.observe(i, j, r)
            inst += env.opt(i) - env.g[i, j]
        cum += inst
        regret.append(cum)
    return np.array(regret)


def test_lbss_regret_sublinear():
    """Theorem 1: O(log2 T).  Empirically the per-step regret must collapse:
    late-window average regret << early-window average regret."""
    n_req, n_ssm, T = 8, 4, 400
    env = SynthEnv(n_req, n_ssm, seed=1)
    cfg = SelectorConfig(n_ssms=n_ssm, batch_limits=[n_req] * n_ssm,
                         alpha=8, beta=2, seed=2)
    reg = run_selector(LBSS(cfg), env, n_req, T)
    early = reg[50] / 50
    late = (reg[-1] - reg[-100]) / 100
    assert late < 0.35 * early, (early, late)
    # and the cumulative curve should be below a linear-growth bound
    assert reg[-1] < 0.5 * reg[50] / 50 * T


def test_lbss_beats_baselines_on_synthetic():
    n_req, n_ssm, T = 8, 4, 300
    res = {}
    for name, mk in {
        "lbss": lambda: LBSS(SelectorConfig(n_ssms=n_ssm,
                                            batch_limits=[n_req] * n_ssm,
                                            alpha=8, beta=2, seed=3)),
        "eps": lambda: EpsilonGreedy(
            SelectorConfig(n_ssms=n_ssm, batch_limits=[n_req] * n_ssm,
                           seed=3), eps=0.2),
        "greedy": lambda: GreedyPromptLength(
            SelectorConfig(n_ssms=n_ssm, batch_limits=[2] * n_ssm, seed=3),
            {i: 10 * i for i in range(n_req)}),
    }.items():
        env = SynthEnv(n_req, n_ssm, seed=4)
        reg = run_selector(mk(), env, n_req, T)
        res[name] = reg[-1]
    assert res["lbss"] < res["eps"], res
    assert res["lbss"] < res["greedy"], res


def test_chunked_exploration_bounds_switching():
    """Bigger beta => fewer switches during exploration (paper Fig. 8)."""
    n_req, n_ssm = 6, 4
    def count_switches(beta):
        cfg = SelectorConfig(n_ssms=n_ssm, batch_limits=[n_req] * n_ssm,
                             alpha=12, beta=beta, seed=5)
        sel = LBSS(cfg)
        env = SynthEnv(n_req, n_ssm, seed=6)
        run_selector(sel, env, n_req, 12)   # exploration stage only
        return sel.switches
    assert count_switches(6) <= count_switches(1)


def test_predicted_destination_is_argmax():
    cfg = SelectorConfig(n_ssms=3, batch_limits=[4, 4, 4])
    sel = LBSS(cfg)
    sel.observe(0, 0, 1.0)
    sel.observe(0, 1, 9.0)
    sel.observe(0, 2, 3.0)
    assert sel.predicted_destination(0) == 1
