"""Copy-on-write block-ledger property suite (ISSUE 6 satellite).

Random interleavings of the full pool lifecycle — insert / append
(ensure) / fork / cow_prepare / rename / evict — must preserve the CoW
refcount invariants at every step:

* the per-block refcount equals the number of table references to it;
* a block is on the free list iff its refcount is zero (never free a
  block something still points at, never leak an unreferenced one);
* ``cow_prepare`` leaves its span exclusively owned — the engine's
  write paths never write through a block another row still shares;
* draining every row returns every block: free_blocks == num_blocks.
"""

import numpy as np
from hypcompat import given, settings, st

from repro.configs import registry
from repro.models import transformer as T
from repro.serving.pool import PagedCachePool


def _pool(capacity=6, max_len=64, bs=8, num_blocks=30):
    cfg = registry.reduced_for("llama-68m", d_model=32, n_heads=4,
                               n_kv_heads=4, vocab_size=64, n_layers=1)
    return PagedCachePool(cfg, capacity, max_len, bs, num_blocks=num_blocks)


def _one_cache(pool, length):
    return T.init_cache(pool.cfg, 1, pool.prefill_len(max(16, length)))


def _cow_ledger_ok(pool):
    """The refcount invariants every mutation must preserve."""
    tally = np.zeros(pool.num_blocks, np.int64)
    for row in range(pool.capacity):
        for b in pool._table[row, : pool._nb[row]]:
            assert int(b) >= 0, "live table slot holds no block"
            tally[int(b)] += 1
    assert np.array_equal(tally, pool._ref), \
        "refcounts drifted from the tables"
    free = pool._free_blocks
    assert len(set(free)) == len(free), "free list duplicates a block"
    for b in free:
        assert pool._ref[b] == 0, "freed a block with live references"
    assert sorted(free) == np.where(tally == 0)[0].tolist(), \
        "unreferenced block missing from the free list (leak)"
    assert pool.free_blocks + pool.allocated_blocks == pool.num_blocks
    ids_np, owner_np = pool.live_blocks()
    live = [int(b) for b, o in zip(ids_np, owner_np) if int(o) >= 0]
    assert len(set(live)) == len(live), "live view lists a block twice"
    assert len(live) == pool.allocated_blocks


_OP = st.tuples(
    st.sampled_from(["insert", "grow", "fork", "cow", "rename", "evict"]),
    st.integers(0, 5),              # rid (live keys may also be fork ids)
    st.integers(1, 56),             # length / growth / span operand
)


@given(ops=st.lists(_OP, min_size=1, max_size=50))
@settings(max_examples=20, deadline=None)
def test_cow_lifecycle_preserves_refcount_invariants(ops):
    pool = _pool()
    forks = 0
    for op, rid, arg in ops:
        live = list(pool.row_of)
        if op == "insert" and not pool.has(rid):
            if pool.free_rows and pool.can_admit(arg):
                pool.insert(rid, _one_cache(pool, arg), arg, 0)
        elif op == "grow" and live:
            key = live[rid % len(live)]
            row = pool.row_of[key]
            need = min(int(pool.lengths[row]) + arg, pool.max_len)
            delta = pool.blocks_needed(need) - int(pool._nb[row])
            # growth writes through the grown blocks: un-share them first
            if 0 < delta <= pool.free_blocks and not pool.shared_span(
                    key, 0, need):
                pool.ensure(key, need)
        elif op == "fork" and live and pool.free_rows:
            src = live[rid % len(live)]
            pool.fork(src, ("fork", forks))
            forks += 1
        elif op == "cow" and live:
            key = live[rid % len(live)]
            row = pool.row_of[key]
            span = int(pool._nb[row]) * pool.block_size
            lo = arg % max(span, 1)
            hi = min(lo + 2 * pool.block_size, span)
            shared = sum(
                1 for bi in range(lo // pool.block_size,
                                  -(-hi // pool.block_size))
                if bi < pool._nb[row]
                and pool._ref[int(pool._table[row, bi])] > 1)
            if shared <= pool.free_blocks:
                pool.cow_prepare(key, lo, hi)
                assert not pool.shared_span(key, lo, hi), \
                    "cow_prepare left a shared block writable in its span"
        elif op == "rename" and live:
            key = live[rid % len(live)]
            if ("r", rid) not in pool.row_of:
                pool.rename(key, ("r", rid))
        elif op == "evict" and live:
            pool.evict(live[rid % len(live)])
        _cow_ledger_ok(pool)
    for key in list(pool.row_of):
        pool.evict(key)
        _cow_ledger_ok(pool)
    assert pool.free_blocks == pool.num_blocks, "drained pool leaked blocks"


def test_fork_shares_then_cow_unshares_then_losers_release():
    """Deterministic walk of the tree-verify block lifecycle: fork aliases
    every block for free, cow_prepare privatizes only the written span,
    and evicting either side keeps shared prefix blocks alive until the
    last holder drops them."""
    pool = _pool(capacity=4, max_len=64, bs=8, num_blocks=12)
    pool.insert(0, _one_cache(pool, 20), 20, 1)          # 3 blocks
    assert pool.allocated_blocks == 3
    pool.fork(0, "b1")
    # a fork moves no blocks: pure aliasing, refcounts bumped
    assert pool.allocated_blocks == 3
    for bi in range(3):
        assert pool.ref_count(0, bi) == 2
    assert pool.shared_span("b1", 16, 24)
    # privatize the branch's speculation window [20, 23): copies only the
    # straddle block, the prefix stays shared
    assert pool.cow_prepare("b1", 20, 23) == 1
    assert pool.allocated_blocks == 4
    assert not pool.shared_span("b1", 16, 24)
    assert pool.ref_count("b1", 2) == 1
    assert pool.ref_count(0, 0) == 2 and pool.ref_count(0, 1) == 2
    # loser eviction is O(branch blocks): the winner keeps the prefix
    pool.evict(0)
    assert pool.allocated_blocks == 3            # b1's 2 shared + 1 private
    for bi in range(3):
        assert pool.ref_count("b1", bi) == 1
    # winner adoption re-keys the surviving row
    pool.rename("b1", 0)
    assert pool.has(0) and not pool.has("b1")
    pool.evict(0)
    assert pool.free_blocks == pool.num_blocks


def test_fork_needs_a_free_row_and_unique_target():
    import pytest
    pool = _pool(capacity=2, max_len=64, bs=8, num_blocks=12)
    pool.insert(0, _one_cache(pool, 10), 10, 1)
    pool.fork(0, 1)
    with pytest.raises(ValueError, match="already live"):
        pool.fork(0, 1)
    with pytest.raises(RuntimeError, match="out of rows"):
        pool.fork(0, 2)
