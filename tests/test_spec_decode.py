"""Speculative decoding invariants: losslessness, acceptance, rollback."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import spec_decode as sd
from repro.models import transformer as T


@pytest.fixture(scope="module")
def bundles():
    key = jax.random.PRNGKey(7)
    cfg_llm = registry.reduced_for("llama-7b", d_model=96, n_heads=4,
                                   n_kv_heads=4)
    cfg_ssm = registry.reduced_for("llama-68m", d_model=64)
    llm = sd.Bundle(cfg_llm, T.init_params(cfg_llm, key))
    ssm = sd.Bundle(cfg_ssm, T.init_params(cfg_ssm, jax.random.PRNGKey(8)))
    return llm, ssm


def _greedy_reference(llm, prompts, P, NEW, max_len):
    B = prompts.shape[0]
    lg, cache = llm.prefill(prompts, jnp.full((B,), P, jnp.int32), max_len)
    lengths = jnp.full((B,), P, jnp.int32)
    V = llm.cfg.vocab_size
    tok = jnp.argmax(lg[:, P - 1, :V], -1, keepdims=True).astype(jnp.int32)
    ref = [tok]
    for _ in range(NEW - 1):
        lg2, cache = llm.decode(cache, tok, lengths)
        tok = jnp.argmax(lg2[:, -1, :V], -1, keepdims=True).astype(jnp.int32)
        lengths = lengths + 1
        ref.append(tok)
    return jnp.concatenate(ref, axis=1)


def _spec_decode(llm, ssm, prompts, P, NEW, gamma, seed=9):
    B = prompts.shape[0]
    max_len = P + NEW + gamma + 4
    V = llm.cfg.vocab_size
    lg, llm_cache = llm.prefill(prompts, jnp.full((B,), P, jnp.int32),
                                max_len)
    _, ssm_cache = ssm.prefill(prompts, jnp.full((B,), P, jnp.int32),
                               max_len)
    lengths = jnp.full((B,), P, jnp.int32)
    last = jnp.argmax(lg[:, P - 1, :V], -1, keepdims=True).astype(jnp.int32)
    emitted = [[int(last[b, 0])] for b in range(B)]
    rng = jax.random.PRNGKey(seed)
    accepts = []
    it = 0
    while min(len(e) for e in emitted) < NEW and it < 60:
        rng, k = jax.random.split(rng)
        out, out_len, n_acc, llm_cache, ssm_cache, lengths, last = \
            sd.spec_iteration(llm, ssm, llm_cache, ssm_cache, last,
                              lengths, gamma, k)
        accepts.append(np.asarray(n_acc))
        for b in range(B):
            for j in range(int(out_len[b])):
                emitted[b].append(int(out[b, j]))
        it += 1
    return emitted, accepts


def test_greedy_spec_decoding_is_lossless(bundles):
    """Greedy spec decoding emits EXACTLY the plain-LLM greedy sequence."""
    llm, ssm = bundles
    key = jax.random.PRNGKey(1)
    B, P, NEW, gamma = 3, 12, 20, 4
    prompts = jax.random.randint(key, (B, P), 1, llm.cfg.vocab_size)
    ref = _greedy_reference(llm, prompts, P, NEW, P + NEW + gamma + 4)
    emitted, _ = _spec_decode(llm, ssm, prompts, P, NEW, gamma)
    for b in range(B):
        assert emitted[b][:NEW] == [int(x) for x in ref[b][:NEW]], b


def test_self_draft_full_acceptance(bundles):
    """SSM == LLM weights => every candidate accepted."""
    llm, _ = bundles
    key = jax.random.PRNGKey(2)
    B, P, gamma = 3, 10, 4
    prompts = jax.random.randint(key, (B, P), 1, llm.cfg.vocab_size)
    max_len = P + 3 * gamma + 6
    ssm2 = sd.Bundle(llm.cfg, llm.params)
    lg, llm_cache = llm.prefill(prompts, jnp.full((B,), P, jnp.int32),
                                max_len)
    _, ssm_cache = ssm2.prefill(prompts, jnp.full((B,), P, jnp.int32),
                                max_len)
    lengths = jnp.full((B,), P, jnp.int32)
    V = llm.cfg.vocab_size
    last = jnp.argmax(lg[:, P - 1, :V], -1, keepdims=True).astype(jnp.int32)
    rng = jax.random.PRNGKey(3)
    for _ in range(2):
        rng, k = jax.random.split(rng)
        out, out_len, n_acc, llm_cache, ssm_cache, lengths, last = \
            sd.spec_iteration(llm, ssm2, llm_cache, ssm_cache, last,
                              lengths, gamma, k)
        assert np.all(np.asarray(n_acc) == gamma)


def test_sampling_mode_runs_and_matches_support(bundles):
    """Sampling verification runs; accepted tokens are draft tokens and the
    final token has nonzero LLM probability."""
    llm, ssm = bundles
    key = jax.random.PRNGKey(4)
    B, P, gamma = 2, 8, 3
    prompts = jax.random.randint(key, (B, P), 1, llm.cfg.vocab_size)
    max_len = P + gamma + 6
    lg, llm_cache = llm.prefill(prompts, jnp.full((B,), P, jnp.int32),
                                max_len)
    _, ssm_cache = ssm.prefill(prompts, jnp.full((B,), P, jnp.int32),
                               max_len)
    lengths = jnp.full((B,), P, jnp.int32)
    V = llm.cfg.vocab_size
    last = jnp.argmax(lg[:, P - 1, :V], -1, keepdims=True).astype(jnp.int32)
    out, out_len, n_acc, *_ = sd.spec_iteration(
        llm, ssm, llm_cache, ssm_cache, last, lengths, gamma,
        jax.random.PRNGKey(5), temperature=1.0)
    assert out.shape == (B, gamma + 1)
    assert np.all(np.asarray(out_len) >= 1)
    assert np.all(np.asarray(out_len) <= gamma + 1)
    assert np.all(np.asarray(out)[np.arange(B), 0] < V)


def test_cache_rollback_invalidates_rejected_slots(bundles):
    llm, ssm = bundles
    key = jax.random.PRNGKey(6)
    B, P, gamma = 2, 8, 4
    prompts = jax.random.randint(key, (B, P), 1, llm.cfg.vocab_size)
    max_len = P + gamma + 6
    lg, llm_cache = llm.prefill(prompts, jnp.full((B,), P, jnp.int32),
                                max_len)
    _, ssm_cache = ssm.prefill(prompts, jnp.full((B,), P, jnp.int32),
                               max_len)
    lengths = jnp.full((B,), P, jnp.int32)
    V = llm.cfg.vocab_size
    last = jnp.argmax(lg[:, P - 1, :V], -1, keepdims=True).astype(jnp.int32)
    out, out_len, n_acc, llm_cache, ssm_cache, new_len, _ = \
        sd.spec_iteration(llm, ssm, llm_cache, ssm_cache, last, lengths,
                          gamma, jax.random.PRNGKey(7))
    seg = np.asarray(jax.tree.leaves(
        {k: v["seg"] for k, v in llm_cache["scan"].items()})[0])
    pos = np.asarray(jax.tree.leaves(
        {k: v["pos"] for k, v in llm_cache["scan"].items()})[0])
    nl = np.asarray(new_len)
    for b in range(B):
        # slots at positions >= new_len (and within the speculated range)
        # must be invalid; below must be valid
        bad = (pos[0, b] >= nl[b]) & (pos[0, b] <= int(lengths[b]) + gamma)
        assert np.all(seg[0, b][bad] == -1)
        good = (pos[0, b] >= 0) & (pos[0, b] < nl[b])
        assert np.all(seg[0, b][good] >= 0)
