"""Pallas kernel validation: interpret-mode vs pure-jnp oracles across
shape/dtype sweeps + hypothesis property tests (assignment requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.verify_attention import verify_attention

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 3e-2}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ------------------------------------------------------ verify_attention --

def _packed_layout(lens, gamma, row_align=16):
    kv_seg, kv_pos = [], []
    for i, l in enumerate(lens):
        pad = (row_align - l % row_align) % row_align
        kv_seg += [i] * l + [-1] * pad
        kv_pos += list(range(l)) + [-1] * pad
    q_seg = np.repeat(np.arange(len(lens)), gamma + 1).astype(np.int32)
    q_pos = np.concatenate(
        [l + np.arange(gamma + 1) for l in lens]).astype(np.int32)
    return (np.array(kv_seg, np.int32), np.array(kv_pos, np.int32),
            q_seg, q_pos)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("lens,H,Kh,D,bq,bk", [
    ([37, 120, 61], 8, 4, 32, 8, 32),
    ([5, 5], 4, 4, 16, 16, 16),
    ([200], 8, 2, 64, 8, 64),
    ([33, 1, 97, 15], 4, 1, 32, 8, 16),
])
def test_verify_attention_matches_eq13_oracle(lens, H, Kh, D, bq, bk, dtype):
    gamma = 4
    kv_seg, kv_pos, q_seg, q_pos = _packed_layout(lens, gamma)
    Tq, Tkv = len(q_seg), len(kv_seg)
    q = _rand(jax.random.PRNGKey(0), (Tq, H, D), dtype)
    k = _rand(jax.random.PRNGKey(1), (Tkv, Kh, D), dtype)
    v = _rand(jax.random.PRNGKey(2), (Tkv, Kh, D), dtype)
    out = verify_attention(q, k, v, jnp.asarray(q_seg), jnp.asarray(q_pos),
                           jnp.asarray(kv_seg), jnp.asarray(kv_pos),
                           bq=bq, bk=bk, interpret=True)
    want = ref.verify_attention_ref(q, k, v, jnp.asarray(q_seg),
                                    jnp.asarray(q_pos), jnp.asarray(kv_seg),
                                    jnp.asarray(kv_pos))
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=ATOL[dtype], rtol=1e-2)


@given(lens=st.lists(st.integers(min_value=1, max_value=80), min_size=1,
                     max_size=5),
       gamma=st.integers(min_value=1, max_value=5))
@settings(max_examples=12, deadline=None)
def test_verify_attention_property(lens, gamma):
    H, Kh, D = 4, 2, 16
    kv_seg, kv_pos, q_seg, q_pos = _packed_layout(lens, gamma, row_align=8)
    q = _rand(jax.random.PRNGKey(3), (len(q_seg), H, D), jnp.float32)
    k = _rand(jax.random.PRNGKey(4), (len(kv_seg), Kh, D), jnp.float32)
    v = _rand(jax.random.PRNGKey(5), (len(kv_seg), Kh, D), jnp.float32)
    out = verify_attention(q, k, v, jnp.asarray(q_seg), jnp.asarray(q_pos),
                           jnp.asarray(kv_seg), jnp.asarray(kv_pos),
                           bq=8, bk=16, interpret=True)
    want = ref.verify_attention_ref(q, k, v, jnp.asarray(q_seg),
                                    jnp.asarray(q_pos), jnp.asarray(kv_seg),
                                    jnp.asarray(kv_pos))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=1e-3)


def test_verify_attention_isolation():
    """A query must be COMPLETELY unaffected by other segments' K/V."""
    H, Kh, D, gamma = 4, 2, 16, 2
    lens = [24, 40]
    kv_seg, kv_pos, q_seg, q_pos = _packed_layout(lens, gamma, row_align=8)
    k = _rand(jax.random.PRNGKey(6), (len(kv_seg), Kh, D), jnp.float32)
    v = _rand(jax.random.PRNGKey(7), (len(kv_seg), Kh, D), jnp.float32)
    q = _rand(jax.random.PRNGKey(8), (len(q_seg), H, D), jnp.float32)
    out1 = verify_attention(q, k, v, jnp.asarray(q_seg), jnp.asarray(q_pos),
                            jnp.asarray(kv_seg), jnp.asarray(kv_pos),
                            bq=8, bk=8, interpret=True)
    # perturb segment-1 K/V wildly; segment-0 outputs must be identical
    k2 = k.at[np.where(kv_seg == 1)].mul(100.0)
    v2 = v.at[np.where(kv_seg == 1)].add(7.0)
    out2 = verify_attention(q, k2, v2, jnp.asarray(q_seg),
                            jnp.asarray(q_pos), jnp.asarray(kv_seg),
                            jnp.asarray(kv_pos), bq=8, bk=8, interpret=True)
    rows0 = np.where(q_seg == 0)[0]
    np.testing.assert_array_equal(np.asarray(out1)[rows0],
                                  np.asarray(out2)[rows0])


# ------------------------------------------- verify_attention, tree mask --

def _tree_layout(lens, branch_depths, row_align=8, dead=2):
    """Packed layout with a token tree per request: committed prefix
    (node -1), then per branch a root copy + chain (nodes off..off+k,
    one query per node with its ancestor bitmask), then ``dead`` CoW
    straddle-duplicate slots (node -2) whose values must never leak."""
    kv_seg, kv_pos, kv_node = [], [], []
    q_seg, q_pos, q_anc = [], [], []
    for i, (l, ks) in enumerate(zip(lens, branch_depths)):
        kv_seg += [i] * l
        kv_pos += list(range(l))
        kv_node += [-1] * l
        off = 0
        for k in ks:
            for d in range(k + 1):
                kv_seg.append(i)
                kv_pos.append(l + d)
                kv_node.append(off + d)
                q_seg.append(i)
                q_pos.append(l + d)
                q_anc.append(((1 << (d + 1)) - 1) << off)
            off += k + 1
        for _ in range(dead):
            kv_seg.append(i)
            kv_pos.append(max(0, l - 1))   # inside the causal window
            kv_node.append(-2)
        pad = (row_align - len(kv_seg) % row_align) % row_align
        kv_seg += [-1] * pad
        kv_pos += [-1] * pad
        kv_node += [-1] * pad
    return (np.array(kv_seg, np.int32), np.array(kv_pos, np.int32),
            np.array(kv_node, np.int32), np.array(q_seg, np.int32),
            np.array(q_pos, np.int32), np.array(q_anc, np.int32))


def test_tree_mask_equals_duplicated_prefix_semantics():
    """Ground truth for the tree mask itself: a shared-prefix token tree
    with node tags must produce exactly what you would get by flattening
    every branch into its own segment with a PRIVATE copy of the prefix
    (the mask-free linear layout tree speculation exists to avoid)."""
    H, Kh, D = 4, 2, 16
    lens = [13, 7]
    branch_depths = [[2, 1, 0], [3, 2]]
    kv_seg, kv_pos, kv_node, q_seg, q_pos, q_anc = _tree_layout(
        lens, branch_depths, row_align=1, dead=2)
    rng = np.random.default_rng(0)
    kt = rng.normal(size=(len(kv_seg), Kh, D)).astype(np.float32)
    vt = rng.normal(size=(len(kv_seg), Kh, D)).astype(np.float32)
    qt = rng.normal(size=(len(q_seg), H, D)).astype(np.float32)
    # poison the dead slots: they are masked, so they must not matter
    kt[kv_node == -2] = 1e3
    vt[kv_node == -2] = -1e3
    got = ref.verify_attention_ref(
        jnp.asarray(qt), jnp.asarray(kt), jnp.asarray(vt),
        jnp.asarray(q_seg), jnp.asarray(q_pos), jnp.asarray(kv_seg),
        jnp.asarray(kv_pos), jnp.asarray(q_anc), jnp.asarray(kv_node))
    # flat layout: one segment per (request, branch), prefix duplicated
    fk, fv, fseg, fpos = [], [], [], []
    fq, fqseg, fqpos = [], [], []
    qi = 0
    seg_id = 0
    for i, (l, ks) in enumerate(zip(lens, branch_depths)):
        pre = np.where((kv_seg == i) & (kv_node == -1))[0][:l]
        off = 0
        for k in ks:
            nodes = [np.where((kv_seg == i) & (kv_node == off + d))[0][0]
                     for d in range(k + 1)]
            for s in pre:
                fk.append(kt[s])
                fv.append(vt[s])
                fseg.append(seg_id)
                fpos.append(int(kv_pos[s]))
            for d, s in enumerate(nodes):
                fk.append(kt[s])
                fv.append(vt[s])
                fseg.append(seg_id)
                fpos.append(l + d)
                fq.append(qt[qi])
                fqseg.append(seg_id)
                fqpos.append(l + d)
                qi += 1
            off += k + 1
            seg_id += 1
    want = ref.verify_attention_ref(
        jnp.asarray(np.stack(fq)), jnp.asarray(np.stack(fk)),
        jnp.asarray(np.stack(fv)), jnp.asarray(np.array(fqseg, np.int32)),
        jnp.asarray(np.array(fqpos, np.int32)),
        jnp.asarray(np.array(fseg, np.int32)),
        jnp.asarray(np.array(fpos, np.int32)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-4)


@pytest.mark.parametrize("lens,branch_depths,H,Kh,D,bq,bk", [
    ([37, 61], [[2, 1], [3]], 8, 4, 32, 8, 32),
    ([5, 5, 9], [[1, 1, 1], [0, 0], [4]], 4, 4, 16, 16, 16),
    ([120], [[5, 4, 3]], 8, 2, 64, 8, 64),
    ([33, 1], [[2, 2], [1, 0]], 4, 1, 32, 8, 16),
])
def test_verify_attention_tree_matches_oracle(lens, branch_depths,
                                              H, Kh, D, bq, bk):
    kv_seg, kv_pos, kv_node, q_seg, q_pos, q_anc = _tree_layout(
        lens, branch_depths)
    q = _rand(jax.random.PRNGKey(0), (len(q_seg), H, D), jnp.float32)
    k = _rand(jax.random.PRNGKey(1), (len(kv_seg), Kh, D), jnp.float32)
    v = _rand(jax.random.PRNGKey(2), (len(kv_seg), Kh, D), jnp.float32)
    out = verify_attention(q, k, v, jnp.asarray(q_seg), jnp.asarray(q_pos),
                           jnp.asarray(kv_seg), jnp.asarray(kv_pos),
                           jnp.asarray(q_anc), jnp.asarray(kv_node),
                           bq=bq, bk=bk, interpret=True)
    want = ref.verify_attention_ref(q, k, v, jnp.asarray(q_seg),
                                    jnp.asarray(q_pos), jnp.asarray(kv_seg),
                                    jnp.asarray(kv_pos), jnp.asarray(q_anc),
                                    jnp.asarray(kv_node))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=1e-2)


@given(seed=st.integers(0, 1000), n=st.integers(1, 3))
@settings(max_examples=12, deadline=None)
def test_verify_attention_tree_property(seed, n):
    """Randomized topologies: ragged prefix lengths, ragged branch
    counts/depths (including empty-chain root-only branches)."""
    rng = np.random.default_rng(seed)
    lens = [int(x) for x in rng.integers(1, 60, n)]
    branch_depths = [[int(d) for d in
                      rng.integers(0, 5, int(rng.integers(1, 4)))]
                     for _ in range(n)]
    H, Kh, D = 4, 2, 16
    kv_seg, kv_pos, kv_node, q_seg, q_pos, q_anc = _tree_layout(
        lens, branch_depths)
    q = _rand(jax.random.PRNGKey(3), (len(q_seg), H, D), jnp.float32)
    k = _rand(jax.random.PRNGKey(4), (len(kv_seg), Kh, D), jnp.float32)
    v = _rand(jax.random.PRNGKey(5), (len(kv_seg), Kh, D), jnp.float32)
    out = verify_attention(q, k, v, jnp.asarray(q_seg), jnp.asarray(q_pos),
                           jnp.asarray(kv_seg), jnp.asarray(kv_pos),
                           jnp.asarray(q_anc), jnp.asarray(kv_node),
                           bq=8, bk=16, interpret=True)
    want = ref.verify_attention_ref(q, k, v, jnp.asarray(q_seg),
                                    jnp.asarray(q_pos), jnp.asarray(kv_seg),
                                    jnp.asarray(kv_pos), jnp.asarray(q_anc),
                                    jnp.asarray(kv_node))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=1e-3)


def test_verify_attention_degenerate_tree_mask_is_linear():
    """All-(-1) tree metadata must reduce to the mask-free call — the
    exact arrays, not merely close ones (the b=1 bit-identity contract
    rests on this)."""
    H, Kh, D, gamma = 4, 2, 16, 3
    lens = [24, 40]
    kv_seg, kv_pos, q_seg, q_pos = _packed_layout(lens, gamma, row_align=8)
    q = _rand(jax.random.PRNGKey(6), (len(q_seg), H, D), jnp.float32)
    k = _rand(jax.random.PRNGKey(7), (len(kv_seg), Kh, D), jnp.float32)
    v = _rand(jax.random.PRNGKey(8), (len(kv_seg), Kh, D), jnp.float32)
    plain = verify_attention(q, k, v, jnp.asarray(q_seg),
                             jnp.asarray(q_pos), jnp.asarray(kv_seg),
                             jnp.asarray(kv_pos), bq=8, bk=8, interpret=True)
    anc = jnp.full((len(q_seg),), -1, jnp.int32)
    node = jnp.full((len(kv_seg),), -1, jnp.int32)
    treed = verify_attention(q, k, v, jnp.asarray(q_seg),
                             jnp.asarray(q_pos), jnp.asarray(kv_seg),
                             jnp.asarray(kv_pos), anc, node,
                             bq=8, bk=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(treed))


# ------------------------------------------------------- flash_attention --

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,Kh,D,win,bq,bk", [
    (2, 64, 8, 4, 32, 0, 16, 16),
    (1, 96, 4, 4, 16, 24, 32, 32),
    (2, 40, 8, 2, 32, 0, 16, 16),
    (1, 128, 2, 1, 64, 32, 64, 64),
])
def test_flash_attention_matches_oracle(B, S, H, Kh, D, win, bq, bk, dtype):
    q = _rand(jax.random.PRNGKey(0), (B, S, H, D), dtype)
    k = _rand(jax.random.PRNGKey(1), (B, S, Kh, D), dtype)
    v = _rand(jax.random.PRNGKey(2), (B, S, Kh, D), dtype)
    out = flash_attention(q, k, v, window=win, bq=bq, bk=bk, interpret=True)
    want = ref.mha_ref(q, k, v, window=win)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=ATOL[dtype], rtol=1e-2)


# ------------------------------------------------------ decode_attention --

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,Kh,D,bk", [
    (2, 128, 8, 4, 32, 32),
    (4, 96, 4, 1, 16, 32),
    (1, 512, 8, 8, 64, 128),
])
def test_decode_attention_matches_oracle(B, S, H, Kh, D, bk, dtype):
    q = _rand(jax.random.PRNGKey(0), (B, H, D), dtype)
    k = _rand(jax.random.PRNGKey(1), (B, S, Kh, D), dtype)
    v = _rand(jax.random.PRNGKey(2), (B, S, Kh, D), dtype)
    lengths = jnp.asarray(
        np.random.default_rng(0).integers(1, S + 1, B), jnp.int32)
    out = decode_attention(q, k, v, lengths, bk=bk, interpret=True)
    want = ref.decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=ATOL[dtype], rtol=1e-2)


@given(B=st.integers(1, 4), nblk=st.integers(1, 6),
       lens_seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_decode_attention_property(B, nblk, lens_seed):
    # caches are allocated block-aligned (S a multiple of bk); lengths
    # inside stay ragged
    H, Kh, D, bk = 4, 2, 16, 32
    S = nblk * bk
    q = _rand(jax.random.PRNGKey(0), (B, H, D), jnp.float32)
    k = _rand(jax.random.PRNGKey(1), (B, S, Kh, D), jnp.float32)
    v = _rand(jax.random.PRNGKey(2), (B, S, Kh, D), jnp.float32)
    lengths = jnp.asarray(
        np.random.default_rng(lens_seed).integers(1, S + 1, B), jnp.int32)
    out = decode_attention(q, k, v, lengths, bk=bk, interpret=True)
    want = ref.decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=1e-3)


def test_decode_attention_rejects_unaligned_cache():
    """No silent full-cache pad copy per step: unaligned S is an error."""
    q = _rand(jax.random.PRNGKey(0), (1, 4, 16), jnp.float32)
    k = _rand(jax.random.PRNGKey(1), (1, 40, 2, 16), jnp.float32)
    v = _rand(jax.random.PRNGKey(2), (1, 40, 2, 16), jnp.float32)
    with pytest.raises(ValueError, match="multiple of bk"):
        decode_attention(q, k, v, jnp.asarray([10], jnp.int32), bk=32,
                         interpret=True)
