"""Pallas kernel validation: interpret-mode vs pure-jnp oracles across
shape/dtype sweeps + hypothesis property tests (assignment requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.verify_attention import verify_attention

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 3e-2}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ------------------------------------------------------ verify_attention --

def _packed_layout(lens, gamma, row_align=16):
    kv_seg, kv_pos = [], []
    for i, l in enumerate(lens):
        pad = (row_align - l % row_align) % row_align
        kv_seg += [i] * l + [-1] * pad
        kv_pos += list(range(l)) + [-1] * pad
    q_seg = np.repeat(np.arange(len(lens)), gamma + 1).astype(np.int32)
    q_pos = np.concatenate(
        [l + np.arange(gamma + 1) for l in lens]).astype(np.int32)
    return (np.array(kv_seg, np.int32), np.array(kv_pos, np.int32),
            q_seg, q_pos)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("lens,H,Kh,D,bq,bk", [
    ([37, 120, 61], 8, 4, 32, 8, 32),
    ([5, 5], 4, 4, 16, 16, 16),
    ([200], 8, 2, 64, 8, 64),
    ([33, 1, 97, 15], 4, 1, 32, 8, 16),
])
def test_verify_attention_matches_eq13_oracle(lens, H, Kh, D, bq, bk, dtype):
    gamma = 4
    kv_seg, kv_pos, q_seg, q_pos = _packed_layout(lens, gamma)
    Tq, Tkv = len(q_seg), len(kv_seg)
    q = _rand(jax.random.PRNGKey(0), (Tq, H, D), dtype)
    k = _rand(jax.random.PRNGKey(1), (Tkv, Kh, D), dtype)
    v = _rand(jax.random.PRNGKey(2), (Tkv, Kh, D), dtype)
    out = verify_attention(q, k, v, jnp.asarray(q_seg), jnp.asarray(q_pos),
                           jnp.asarray(kv_seg), jnp.asarray(kv_pos),
                           bq=bq, bk=bk, interpret=True)
    want = ref.verify_attention_ref(q, k, v, jnp.asarray(q_seg),
                                    jnp.asarray(q_pos), jnp.asarray(kv_seg),
                                    jnp.asarray(kv_pos))
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=ATOL[dtype], rtol=1e-2)


@given(lens=st.lists(st.integers(min_value=1, max_value=80), min_size=1,
                     max_size=5),
       gamma=st.integers(min_value=1, max_value=5))
@settings(max_examples=12, deadline=None)
def test_verify_attention_property(lens, gamma):
    H, Kh, D = 4, 2, 16
    kv_seg, kv_pos, q_seg, q_pos = _packed_layout(lens, gamma, row_align=8)
    q = _rand(jax.random.PRNGKey(3), (len(q_seg), H, D), jnp.float32)
    k = _rand(jax.random.PRNGKey(4), (len(kv_seg), Kh, D), jnp.float32)
    v = _rand(jax.random.PRNGKey(5), (len(kv_seg), Kh, D), jnp.float32)
    out = verify_attention(q, k, v, jnp.asarray(q_seg), jnp.asarray(q_pos),
                           jnp.asarray(kv_seg), jnp.asarray(kv_pos),
                           bq=8, bk=16, interpret=True)
    want = ref.verify_attention_ref(q, k, v, jnp.asarray(q_seg),
                                    jnp.asarray(q_pos), jnp.asarray(kv_seg),
                                    jnp.asarray(kv_pos))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=1e-3)


def test_verify_attention_isolation():
    """A query must be COMPLETELY unaffected by other segments' K/V."""
    H, Kh, D, gamma = 4, 2, 16, 2
    lens = [24, 40]
    kv_seg, kv_pos, q_seg, q_pos = _packed_layout(lens, gamma, row_align=8)
    k = _rand(jax.random.PRNGKey(6), (len(kv_seg), Kh, D), jnp.float32)
    v = _rand(jax.random.PRNGKey(7), (len(kv_seg), Kh, D), jnp.float32)
    q = _rand(jax.random.PRNGKey(8), (len(q_seg), H, D), jnp.float32)
    out1 = verify_attention(q, k, v, jnp.asarray(q_seg), jnp.asarray(q_pos),
                            jnp.asarray(kv_seg), jnp.asarray(kv_pos),
                            bq=8, bk=8, interpret=True)
    # perturb segment-1 K/V wildly; segment-0 outputs must be identical
    k2 = k.at[np.where(kv_seg == 1)].mul(100.0)
    v2 = v.at[np.where(kv_seg == 1)].add(7.0)
    out2 = verify_attention(q, k2, v2, jnp.asarray(q_seg),
                            jnp.asarray(q_pos), jnp.asarray(kv_seg),
                            jnp.asarray(kv_pos), bq=8, bk=8, interpret=True)
    rows0 = np.where(q_seg == 0)[0]
    np.testing.assert_array_equal(np.asarray(out1)[rows0],
                                  np.asarray(out2)[rows0])


# ------------------------------------------------------- flash_attention --

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,Kh,D,win,bq,bk", [
    (2, 64, 8, 4, 32, 0, 16, 16),
    (1, 96, 4, 4, 16, 24, 32, 32),
    (2, 40, 8, 2, 32, 0, 16, 16),
    (1, 128, 2, 1, 64, 32, 64, 64),
])
def test_flash_attention_matches_oracle(B, S, H, Kh, D, win, bq, bk, dtype):
    q = _rand(jax.random.PRNGKey(0), (B, S, H, D), dtype)
    k = _rand(jax.random.PRNGKey(1), (B, S, Kh, D), dtype)
    v = _rand(jax.random.PRNGKey(2), (B, S, Kh, D), dtype)
    out = flash_attention(q, k, v, window=win, bq=bq, bk=bk, interpret=True)
    want = ref.mha_ref(q, k, v, window=win)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=ATOL[dtype], rtol=1e-2)


# ------------------------------------------------------ decode_attention --

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,Kh,D,bk", [
    (2, 128, 8, 4, 32, 32),
    (4, 96, 4, 1, 16, 32),
    (1, 512, 8, 8, 64, 128),
])
def test_decode_attention_matches_oracle(B, S, H, Kh, D, bk, dtype):
    q = _rand(jax.random.PRNGKey(0), (B, H, D), dtype)
    k = _rand(jax.random.PRNGKey(1), (B, S, Kh, D), dtype)
    v = _rand(jax.random.PRNGKey(2), (B, S, Kh, D), dtype)
    lengths = jnp.asarray(
        np.random.default_rng(0).integers(1, S + 1, B), jnp.int32)
    out = decode_attention(q, k, v, lengths, bk=bk, interpret=True)
    want = ref.decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=ATOL[dtype], rtol=1e-2)


@given(B=st.integers(1, 4), nblk=st.integers(1, 6),
       lens_seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_decode_attention_property(B, nblk, lens_seed):
    # caches are allocated block-aligned (S a multiple of bk); lengths
    # inside stay ragged
    H, Kh, D, bk = 4, 2, 16, 32
    S = nblk * bk
    q = _rand(jax.random.PRNGKey(0), (B, H, D), jnp.float32)
    k = _rand(jax.random.PRNGKey(1), (B, S, Kh, D), jnp.float32)
    v = _rand(jax.random.PRNGKey(2), (B, S, Kh, D), jnp.float32)
    lengths = jnp.asarray(
        np.random.default_rng(lens_seed).integers(1, S + 1, B), jnp.int32)
    out = decode_attention(q, k, v, lengths, bk=bk, interpret=True)
    want = ref.decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=1e-3)


def test_decode_attention_rejects_unaligned_cache():
    """No silent full-cache pad copy per step: unaligned S is an error."""
    q = _rand(jax.random.PRNGKey(0), (1, 4, 16), jnp.float32)
    k = _rand(jax.random.PRNGKey(1), (1, 40, 2, 16), jnp.float32)
    v = _rand(jax.random.PRNGKey(2), (1, 40, 2, 16), jnp.float32)
    with pytest.raises(ValueError, match="multiple of bk"):
        decode_attention(q, k, v, jnp.asarray([10], jnp.int32), bk=32,
                         interpret=True)
