import os
import sys

# Tests run single-device on CPU (the 512-device override lives ONLY in
# launch/dryrun.py).  Keep x64 off; silence jax GPU probing noise.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
