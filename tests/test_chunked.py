"""Chunked prefill + token-budget step planner (ISSUE 3 acceptance).

Covers:
* planner policy — budget split between decode slots and prompt chunks,
  idle-slot progress rule, prefilling lifecycle transitions (no models);
* priority-aware scheduling — admission rank (priority, arrival, rid),
  preemption victims lowest-priority-first, default priority preserves
  FIFO behaviour exactly;
* chunked-vs-monolithic parity — same prompts, same seeds, bit-identical
  emitted tokens, for both paged and dense layouts (acceptance bar);
* mixed slots stay greedy-exact under preemption pressure;
* the chunk query shape maps onto the paged verify kernel (no dedicated
  chunk-prefill kernel) — kernel vs oracle on chunk-over-prefix queries;
* fast-switch precompute with bucketed (O(context)) widths falls back to
  a miss when the context outgrows the precomputed grid.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import spec_decode as sd
from repro.core.selector import LBSS, SelectorConfig
from repro.core.switching import SwitchManager
from repro.data.workloads import Request, make_workload
from repro.kernels import ref
from repro.kernels.paged_attention import paged_verify_attention
from repro.models import transformer as T
from repro.serving.engine import EngineConfig, SpinEngine
from repro.serving.scheduler import ContinuousScheduler, SchedulerConfig

VOCAB = 256


def _req(rid, arrival=0.0, prompt_len=8, max_new=8, priority=0,
         emitted=None):
    return Request(rid=rid, dataset="cip", difficulty=0.5,
                   prompt=np.zeros(prompt_len, np.int32), max_new=max_new,
                   arrival=arrival, priority=priority,
                   emitted=list(emitted or []))


# ------------------------------------------------------ planner (no jax) --

def test_chunk_grants_follow_admission_and_budget():
    s = ContinuousScheduler(SchedulerConfig(
        capacity=2, max_len=128, gamma=3, prefill_chunk=8, token_budget=24))
    s.submit([_req(0, prompt_len=20), _req(1, prompt_len=20)])
    dec = s.plan(0.0)
    assert [r.rid for r in dec.admit] == [0, 1]
    # nothing is decode-active yet: full budget goes to chunks, 8 each
    assert [(r.rid, n) for r, n in dec.prefill] == [(0, 8), (1, 8)]
    for r in dec.admit:
        s.mark_admitted(r, 0.0)
    assert set(s.prefilling) == {0, 1}
    for r, n in dec.prefill:
        r.prefill_pos += n
    # next slot: both still prefilling, budget 24 covers 8 + 8
    dec = s.plan(1.0)
    assert [(r.rid, n) for r, n in dec.prefill] == [(0, 8), (1, 8)]
    for r, n in dec.prefill:
        r.prefill_pos += n
    # final chunks are the 4-token remainders
    dec = s.plan(2.0)
    assert [(r.rid, n) for r, n in dec.prefill] == [(0, 4), (1, 4)]
    for r, n in dec.prefill:
        r.prefill_pos += n
        s.mark_prefill_done(r)
    assert not s.prefilling
    assert s.plan(3.0).empty


def test_decode_slots_outrank_prefill_in_the_token_budget():
    # budget 12, gamma 3: a decode-active request costs gamma+1 = 4
    # tokens off the top; only the remainder goes to prompt chunks
    s = ContinuousScheduler(SchedulerConfig(
        capacity=3, max_len=128, gamma=3, prefill_chunk=8, token_budget=12))
    a, b = _req(0, prompt_len=8), _req(1, arrival=0.1, prompt_len=8)
    c = _req(2, arrival=0.2, prompt_len=30)
    s.submit([a, b, c])

    def apply(dec, now):
        for r in dec.admit:
            s.mark_admitted(r, now)
        for r, n in dec.prefill:
            r.prefill_pos += n
            if r.prefill_pos >= s.prefill_target(r):
                s.mark_prefill_done(r)

    dec = s.plan(0.1)               # a + b admitted, c not arrived yet
    assert [r.rid for r in dec.admit] == [0, 1]
    # nothing decode-active: a gets a full 8-token chunk (done), b the
    # remaining 4 of the budget
    assert [(r.rid, n) for r, n in dec.prefill] == [(0, 8), (1, 4)]
    apply(dec, 0.1)
    assert 0 not in s.prefilling and 1 in s.prefilling
    dec = s.plan(0.15)              # a decode-active now: 12 - 4 = 8 left
    assert [(r.rid, n) for r, n in dec.prefill] == [(1, 4)]
    apply(dec, 0.15)
    dec = s.plan(0.25)              # c admitted; a + b decode-active
    grants = {r.rid: n for r, n in dec.prefill}
    assert grants == {2: 4}, grants   # 12 - 2*(3+1) = 4 tokens left
    apply(dec, 0.25)
    # idle-slot progress rule: even a zero-leftover budget grants the
    # top-ranked prefiller when nothing is decode-active
    s2 = ContinuousScheduler(SchedulerConfig(
        capacity=1, max_len=128, gamma=3, prefill_chunk=8, token_budget=2))
    s2.submit([_req(5, prompt_len=20)])
    dec2 = s2.plan(0.0)
    assert [(r.rid, n) for r, n in dec2.prefill] == [(5, 2)]
    s2.mark_admitted(dec2.admit[0], 0.0)
    dec2.admit[0].prefill_pos = 2
    dec3 = s2.plan(1.0)
    assert [(r.rid, n) for r, n in dec3.prefill] == [(5, 2)]


def test_preempted_prefilling_request_restarts_from_chunk_zero():
    s = ContinuousScheduler(SchedulerConfig(
        capacity=2, max_len=64, gamma=3, kv_budget=48, prefill_chunk=8))
    a = _req(0, arrival=0.0, prompt_len=10)
    b = _req(1, arrival=1.0, prompt_len=30)
    s.submit([a, b])
    dec = s.plan(1.0)
    for r in dec.admit:
        s.mark_admitted(r, 1.0)
    b.prefill_pos = 8               # b mid-prefill
    a.emitted = list(range(20))     # a outgrows the budget
    s.mark_prefill_done(a)
    dec = s.plan(2.0)
    assert [r.rid for r in dec.preempt] == [1]
    s.mark_preempted(b, 2.0)
    assert b.prefill_pos == 0       # partial KV discarded with the blocks
    assert 1 not in s.prefilling and [r.rid for r in s.waiting] == [1]


# ------------------------------------------------------------- priority --

def test_priority_outranks_arrival_for_admission():
    s = ContinuousScheduler(SchedulerConfig(capacity=2, max_len=64, gamma=3))
    s.submit([_req(0, arrival=0.0, priority=5),
              _req(1, arrival=1.0, priority=0),
              _req(2, arrival=2.0, priority=0)])
    dec = s.plan(2.0)
    assert [r.rid for r in dec.admit] == [1, 2]   # lower value = urgent
    for r in dec.admit:
        s.mark_admitted(r, 2.0)
    assert [r.rid for r in s.waiting] == [0]


def test_preemption_victims_lowest_priority_first_then_latest_arrival():
    s = ContinuousScheduler(SchedulerConfig(capacity=3, max_len=64, gamma=3,
                                            kv_budget=100))
    a = _req(0, arrival=0.0, priority=0, prompt_len=20)
    b = _req(1, arrival=1.0, priority=3, prompt_len=20)
    c = _req(2, arrival=2.0, priority=3, prompt_len=20)
    s.submit([a, b, c])
    dec = s.plan(2.0)
    for r in dec.admit:
        s.mark_admitted(r, 2.0)
    for r in (a, b, c):
        r.emitted = list(range(40))   # 3 * 63 cells > 100 budget
    dec = s.plan(3.0)
    # both class-3 requests go, latest arrival first; the class-0 request
    # keeps its row even though it arrived earliest
    assert [r.rid for r in dec.preempt] == [2, 1]
    assert a.rid not in {r.rid for r in dec.preempt}


def test_default_priority_preserves_fifo_exactly():
    def run(prio_field):
        s = ContinuousScheduler(SchedulerConfig(capacity=2, max_len=64,
                                                gamma=3, kv_budget=40))
        reqs = [_req(i, arrival=0.5 * i, **prio_field) for i in range(4)]
        s.submit(reqs)
        order = []
        for t in (0.0, 0.5, 1.0, 1.5, 2.0):
            dec = s.plan(t)
            for r in dec.admit:
                s.mark_admitted(r, t)
                order.append(r.rid)
            for rid in list(s.running):
                s.mark_finished(rid)
        return order

    assert run({}) == run({"priority": 0}) == [0, 1, 2, 3]


# --------------------------------------------------------- engine parity --

@pytest.fixture(scope="module")
def models():
    key = jax.random.PRNGKey(0)
    cfg_llm = registry.reduced_for("llama-7b", d_model=96, n_heads=4,
                                   n_kv_heads=4, vocab_size=VOCAB)
    llm = sd.Bundle(cfg_llm, T.init_params(cfg_llm, key))
    ssms = []
    for i, (d, L) in enumerate([(32, 1), (64, 2)]):
        c = registry.reduced_for("llama-68m", d_model=d, n_heads=4,
                                 n_kv_heads=4, vocab_size=VOCAB, n_layers=L)
        ssms.append(sd.Bundle(c, T.init_params(c, jax.random.PRNGKey(i + 1))))
    return llm, ssms


def _run_engine(llm, ssms, layout, prefill_chunk, *, token_budget=None,
                kv_budget=None, capacity=4, reqs=None, max_slots=400):
    sel = LBSS(SelectorConfig(n_ssms=len(ssms),
                              batch_limits=[capacity] * len(ssms),
                              alpha=4, beta=2, seed=1))
    ecfg = EngineConfig(gamma=3, max_len=128, capacity=capacity,
                        use_packed_verify=True, packed_bucket=128,
                        straggler_mitigation=False, kv_layout=layout,
                        block_size=16, kv_budget=kv_budget,
                        prefill_chunk=prefill_chunk,
                        token_budget=token_budget)
    eng = SpinEngine(llm, ssms, sel, ecfg)
    if reqs is None:
        reqs = make_workload("mix", 4, VOCAB, seed=7, scale=0.25,
                             arrival_rate=400.0)
    eng.add_requests(reqs)
    eng.run(max_slots=max_slots)
    assert all(r.done for r in eng.requests.values())
    return eng


@pytest.mark.parametrize("layout", ["paged", "dense"])
def test_chunked_prefill_bit_identical_to_monolithic(models, layout):
    """Acceptance: same prompts, same seeds -> bit-identical emitted
    tokens whether the prompt is ingested monolithically or in 8-token
    chunks, on both KV layouts."""
    llm, ssms = models
    mono = _run_engine(llm, ssms, layout, 0)
    chunked = _run_engine(llm, ssms, layout, 8, token_budget=48)
    assert chunked.chunked and not mono.chunked
    assert chunked.scheduler.prefill_grants > 0
    for rid in mono.requests:
        assert mono.requests[rid].emitted == chunked.requests[rid].emitted, \
            rid
    if layout == "paged":
        assert chunked.llm_pool.free_blocks == chunked.llm_pool.num_blocks


def greedy_reference(llm, prompt, n_new):
    P = len(prompt)
    toks = jnp.asarray(np.asarray(prompt, np.int32))[None]
    lg, cache = llm.prefill(toks, jnp.asarray([P], jnp.int32), P + n_new + 8)
    V = llm.cfg.vocab_size
    tok = jnp.argmax(lg[:, P - 1, :V], -1, keepdims=True).astype(jnp.int32)
    out = [int(tok[0, 0])]
    lengths = jnp.asarray([P], jnp.int32)
    for _ in range(n_new - 1):
        lg2, cache = llm.decode(cache, tok, lengths)
        tok = jnp.argmax(lg2[:, -1, :V], -1, keepdims=True).astype(jnp.int32)
        lengths = lengths + 1
        out.append(int(tok[0, 0]))
    return out


def test_mixed_slots_stay_greedy_exact_under_preemption(models):
    """A long prompt chunk-prefills while short requests decode and the
    KV budget preempts mid-stream: every request must still emit exactly
    the plain greedy continuation."""
    llm, ssms = models
    reqs = make_workload("cp", 4, VOCAB, seed=11, scale=0.35)
    rng = np.random.default_rng(3)
    reqs.append(Request(rid=len(reqs), dataset="long", difficulty=0.5,
                        prompt=rng.integers(0, VOCAB, 24).astype(np.int32),
                        max_new=8, arrival=0.01, emitted=[]))
    eng = _run_engine(llm, ssms, "paged", 8, token_budget=24, kv_budget=80,
                      capacity=3, reqs=reqs, max_slots=600)
    assert eng.scheduler.preemptions > 0, "budget never bound: tune test"
    assert eng.scheduler.prefill_grants > 0
    mixed = sum(1 for rec in eng.slot_log
                if rec.get("prefill_tokens") and rec.get("active"))
    assert mixed > 0, "no slot ran chunk-prefill and decode together"
    for r in eng.requests.values():
        want = greedy_reference(llm, r.prompt, r.max_new)
        assert r.emitted[:r.max_new] == want, r.rid


def test_chunked_falls_back_to_monolithic_for_recurrent_llm():
    cfg = registry.reduced_for("zamba2-1.2b", d_model=32, n_heads=4,
                               n_kv_heads=4, vocab_size=64, n_layers=2)
    llm = sd.Bundle(cfg, T.init_params(cfg, jax.random.PRNGKey(0)))
    sel = LBSS(SelectorConfig(n_ssms=1, batch_limits=[2], alpha=4, beta=2,
                              seed=1))
    eng = SpinEngine(llm, [llm], sel,
                     EngineConfig(gamma=2, max_len=64, capacity=2,
                                  prefill_chunk=8))
    assert not eng.chunked
    assert eng.scheduler.cfg.prefill_chunk == 0


# --------------------------------------------- kernel shape reuse (chunk) --

def test_chunk_queries_map_onto_paged_verify_kernel():
    """A prompt chunk is queries at positions pos..pos+n-1 over the row's
    blocks — the packed-verify kernel shape with the chunk as the query
    segment.  Kernel (interpret mode) vs oracle on that exact layout."""
    H, Kh, D, bs = 4, 2, 32, 16
    prefix, chunk = 40, 24
    total = prefix + chunk
    nb = -(-total // bs)
    rng = np.random.default_rng(0)
    perm = rng.permutation(nb + 2)          # fragmented block table
    blocks = perm[:nb]
    num_blocks = nb + 2
    pool_seg = np.full((num_blocks, bs), -1, np.int32)
    pool_pos = np.full((num_blocks, bs), -1, np.int32)
    for k, pb in enumerate(blocks):
        for s_ in range(bs):
            p = k * bs + s_
            if p < total:                   # chunk KV already written
                pool_seg[pb, s_] = 0
                pool_pos[pb, s_] = p
    q_pos = (prefix + np.arange(chunk)).astype(np.int32)
    q_seg = np.zeros(chunk, np.int32)
    key = jax.random.PRNGKey(1)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (chunk, H, D), jnp.float32)
    kp = jax.random.normal(k2, (num_blocks, bs, Kh, D), jnp.float32)
    vp = jax.random.normal(k3, (num_blocks, bs, Kh, D), jnp.float32)
    ids = np.concatenate([blocks, [0]]).astype(np.int32)
    owner = np.concatenate([np.zeros(nb), [-1]]).astype(np.int32)
    args = (q, kp, vp, jnp.asarray(pool_seg), jnp.asarray(pool_pos),
            jnp.asarray(q_seg), jnp.asarray(q_pos), jnp.asarray(ids),
            jnp.asarray(owner))
    out = paged_verify_attention(*args, bq=8, interpret=True)
    want = ref.paged_verify_ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=1e-2)


# --------------------------------------------- switch precompute widths --

def test_switch_precompute_bucketed_width_falls_back_on_outgrown_context():
    cfg = registry.reduced_for("llama-68m", d_model=32, n_heads=4,
                               n_kv_heads=4, vocab_size=64, n_layers=1)
    b = sd.Bundle(cfg, T.init_params(cfg, jax.random.PRNGKey(0)))
    sw = SwitchManager([b])
    tokens = np.arange(40) % 64
    # precompute at a bucketed width that covers 24 tokens only
    sw.precompute(7, 0, tokens, 16, 24)
    assert sw.pre[7].width == 24
    # context grew past the precomputed grid: must be a miss (a hit would
    # silently drop catch-up KV writes past the 24-slot cache)
    cache, recomputed = sw.switch(7, 0, tokens, 40, 48)
    assert sw.misses == 1 and sw.hits == 0
    assert recomputed == 40
    # within the width: normal hit with delta catch-up
    sw.precompute(8, 0, tokens, 16, 48)
    cache, recomputed = sw.switch(8, 0, tokens, 20, 48)
    assert sw.hits == 1
    assert recomputed == 4
