"""Request decomposition (paper §V-A): planner properties + packed-vs-padded
verification equivalence (Eq. 13 correctness) incl. hypothesis sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.configs import registry
from repro.core import decompose as D
from repro.models import transformer as T


# ------------------------------------------------------------- planner ----

@given(st.lists(st.integers(min_value=1, max_value=300), min_size=1,
                max_size=12))
@settings(max_examples=60, deadline=None)
def test_planner_covers_every_token_exactly_once(lengths):
    plan = D.plan_decomposition(lengths, align=16)
    # every (request, slot<len) pair appears exactly once among valid cells
    seen = set()
    for c in range(plan.total):
        if plan.valid[c]:
            key = (int(plan.gather_b[c]), int(plan.gather_s[c]))
            assert key not in seen
            seen.add(key)
    want = {(i, p) for i, l in enumerate(lengths) for p in range(l)}
    assert seen == want
    assert plan.total >= sum(lengths)
    assert plan.L % 16 == 0


@given(st.lists(st.integers(min_value=1, max_value=500), min_size=2,
                max_size=16))
@settings(max_examples=60, deadline=None)
def test_planner_never_worse_than_padded(lengths):
    plan = D.plan_decomposition(lengths, align=16)
    # packed cells never exceed the padded baseline rounded to alignment
    padded_aligned = len(lengths) * int(np.ceil(max(lengths) / 16) * 16)
    assert plan.total <= padded_aligned


def test_planner_saves_on_skewed_lengths():
    """Paper Fig. 9 scenario: one long request + short ones."""
    plan = D.plan_decomposition([700, 60, 40, 30], align=128)
    assert plan.saving > 0.5, plan


# ---------------------------------------------- packed == padded verify ---

@pytest.mark.parametrize("ctx_lens,gamma", [
    ([37, 9, 21, 5], 3),
    ([64, 64], 4),
    ([3, 50, 17], 1),
])
def test_packed_verification_matches_padded(ctx_lens, gamma):
    key = jax.random.PRNGKey(11)
    cfg = registry.reduced_for("llama-7b", d_model=96, n_heads=4,
                               n_kv_heads=2)
    params = T.init_params(cfg, key)
    B = len(ctx_lens)
    S_max = max(ctx_lens) + gamma + 4
    toks = jax.random.randint(key, (B, S_max), 1, cfg.vocab_size)
    lengths = jnp.asarray(ctx_lens, jnp.int32)
    _, cache = T.prefill(params, cfg, tokens=toks, lengths=lengths,
                         max_len=S_max)
    new_toks = jax.random.randint(jax.random.PRNGKey(12), (B, gamma + 1), 1,
                                  cfg.vocab_size)

    logits_pad, cache_pad = T.decode_step(params, cfg, cache,
                                          tokens=new_toks, lengths=lengths)

    plan = D.plan_decomposition(ctx_lens, align=8)
    q_rows, q_pos, q_seg = D.build_query_layout(ctx_lens, gamma)
    override = D.make_attn_override(plan.gather_b, plan.gather_s, plan.valid,
                                    q_rows)
    logits_packed, cache_packed = T.verify_step_packed(
        params, cfg, cache, tokens=new_toks.reshape(1, -1),
        positions=jnp.asarray(q_pos), segments=jnp.asarray(q_seg),
        attn_override=override)

    lp = logits_packed[0].reshape(B, gamma + 1, -1)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(logits_pad),
                               atol=1e-3, rtol=1e-2)
    for name, entry in cache_pad["scan"].items():
        for k in ("k", "v"):
            np.testing.assert_allclose(
                np.asarray(entry[k]),
                np.asarray(cache_packed["scan"][name][k]),
                atol=1e-4, rtol=1e-3)


def test_eq13_denominator_spans_fragments():
    """Direct Eq. (13) check: attention scores of a decomposed request are
    normalized over ALL its fragments, none of the other requests'."""
    from repro.models.layers import attention
    key = jax.random.PRNGKey(13)
    D_, H = 8, 2
    # one request of 10 tokens 'decomposed' across a packed axis with another
    # request of 6 tokens; query attends over the packed buffer.
    kv_len = 16
    k = jax.random.normal(key, (1, kv_len, H, D_))
    v = jax.random.normal(jax.random.PRNGKey(14), (1, kv_len, H, D_))
    q = jax.random.normal(jax.random.PRNGKey(15), (1, 1, H, D_))
    seg = jnp.asarray([[0] * 10 + [1] * 6])
    pos = jnp.asarray([list(range(10)) + list(range(6))])
    qpos = jnp.asarray([[10]])
    qseg = jnp.asarray([[0]])
    out = attention(q, k, v, q_positions=qpos, kv_positions=pos,
                    q_segments=qseg, kv_segments=seg)
    # oracle: softmax over exactly the request-0 tokens
    qf = q[0, 0].astype(jnp.float32)
    kf = k[0, :10].astype(jnp.float32)
    vf = v[0, :10].astype(jnp.float32)
    s = jnp.einsum("hd,shd->hs", qf, kf) / np.sqrt(D_)
    w = jax.nn.softmax(s, axis=-1)
    want = jnp.einsum("hs,shd->hd", w, vf)
    np.testing.assert_allclose(np.asarray(out[0, 0]), np.asarray(want),
                               atol=1e-5, rtol=1e-4)
