"""Goodput-aware speculation depth (core/gamma.py + engine integration).

Controller properties (monotonicity in the acceptance estimate, clamping,
load-aware capping), scheduler token-budget accounting with ragged
depths, and the engine-level contracts: ``fixed`` emits exactly the seed
outputs (== plain LLM greedy) on both KV layouts, and ``adaptive`` stays
lossless while changing only the speculation schedule.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypcompat import HAVE_HYPOTHESIS, given, settings, st
from repro.configs import registry
from repro.core import spec_decode as sd
from repro.core.decompose import build_query_layout
from repro.core.gamma import GammaConfig, GammaController, expected_tokens
from repro.core.pipeline import CostModel
from repro.core.selector import LBSS, SelectorConfig
from repro.data.workloads import Request, make_workload
from repro.models import transformer as T
from repro.serving.engine import EngineConfig, SpinEngine
from repro.serving.scheduler import ContinuousScheduler, SchedulerConfig

VOCAB = 256


def _cost(n_ssms=2, gamma=4):
    return CostModel(
        ssm_time_per_token=[1e-4 * (j + 1) for j in range(n_ssms)],
        ssm_fixed=[2e-4] * n_ssms,
        llm_fixed=1e-3,
        llm_time_per_token=5e-4,
        gamma=gamma,
    )


def _controller(policy="adaptive", gamma=4, gamma_max=8, selector=None):
    return GammaController(
        GammaConfig(policy=policy, gamma=gamma, gamma_max=gamma_max),
        _cost(gamma=gamma),
        selector,
    )


# ------------------------------------------------------------ controller --


def test_expected_tokens_closed_form():
    # a=0: always exactly the bonus token; a=1: everything + bonus
    assert expected_tokens(0.0, 5) == pytest.approx(1.0)
    assert expected_tokens(1.0, 5) == pytest.approx(6.0)
    # geometric series against a direct sum
    a, k = 0.6, 4
    direct = sum(a**i for i in range(k + 1))
    assert expected_tokens(a, k) == pytest.approx(direct)


def test_best_depth_clamped_and_monotone_on_grid():
    ctl = _controller(gamma_max=8)
    depths = [ctl.best_depth(a, 0) for a in np.linspace(0.0, 1.0, 101)]
    assert all(1 <= k <= 8 for k in depths)
    assert depths == sorted(depths), "depth must be monotone in acceptance"
    assert depths[0] == 1, "hopeless drafts deserve minimum depth"
    assert depths[-1] == 8, "perfect drafts deserve the full window"


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=60, deadline=None)
@given(
    a1=st.floats(min_value=0.0, max_value=1.0),
    a2=st.floats(min_value=0.0, max_value=1.0),
    gamma_max=st.integers(min_value=1, max_value=12),
)
def test_best_depth_monotone_and_clamped_property(a1, a2, gamma_max):
    ctl = _controller(gamma_max=gamma_max)
    k1, k2 = ctl.best_depth(a1, 0), ctl.best_depth(a2, 0)
    assert 1 <= k1 <= gamma_max and 1 <= k2 <= gamma_max
    lo, hi = (k1, k2) if a1 <= a2 else (k2, k1)
    assert lo <= hi, f"depth not monotone: a=({a1}, {a2}) -> k=({k1}, {k2})"


def test_adaptive_cold_start_grants_default_gamma():
    # no selector / no observations: --gamma is the cold-start depth
    ctl = _controller(gamma=3, gamma_max=8)
    assert ctl.grant([1, 2], {1: 0, 2: 1}) == {1: 3, 2: 3}
    # clamped to the cap when gamma > gamma_max
    ctl = _controller(gamma=6, gamma_max=4)
    assert ctl.grant([1], {1: 0}) == {1: 4}


def test_fixed_policy_grants_uniform_gamma_and_ignores_budget():
    ctl = _controller(policy="fixed", gamma=4, gamma_max=4)
    got = ctl.grant([7, 8, 9], {7: 0, 8: 1, 9: 0}, token_budget=3, reserved_tokens=16)
    assert got == {7: 4, 8: 4, 9: 4}
    assert ctl.capped == 0


def test_adaptive_budget_cap_trims_deepest_first_and_keeps_floor():
    sel = LBSS(SelectorConfig(n_ssms=1, batch_limits=[8]))
    for _ in range(4):
        sel.observe_accept(1, 0, 1.0)
        sel.observe_accept(2, 0, 1.0)
    ctl = _controller(gamma_max=8, selector=sel)
    free = ctl.grant([1, 2], {1: 0, 2: 0})
    assert free == {1: 8, 2: 8}
    # contended budget: 12 tokens minus 6 already granted to a prefill
    # chunk leaves 6 = exactly depth-1-plus-bonus for each request — and
    # grants are never trimmed below depth 1
    capped = ctl.grant([1, 2], {1: 0, 2: 0}, token_budget=12, reserved_tokens=6)
    assert sum(k + 1 for k in capped.values()) <= 6
    assert all(k >= 1 for k in capped.values())
    assert ctl.capped > 0


def test_controller_uses_selector_acceptance_estimates():
    sel = LBSS(SelectorConfig(n_ssms=2, batch_limits=[4, 4]))
    for _ in range(8):
        sel.observe_accept(1, 0, 1.0)
        sel.observe_accept(2, 1, 0.0)
    ctl = _controller(gamma_max=8, selector=sel)
    got = ctl.grant([1, 2], {1: 0, 2: 1})
    assert got[1] == 8 and got[2] == 1
    # estimates are shared within a group and survive retire()
    sel.retire(1)
    assert sel.accept_estimate(1, 0) == pytest.approx(1.0)


def test_ragged_query_layout_matches_uniform_and_counts_tokens():
    lens = [5, 9, 3]
    u_rows, u_pos, u_seg = build_query_layout(lens, 3)
    r_rows, r_pos, r_seg = build_query_layout(lens, [3, 3, 3])
    assert np.array_equal(u_rows, r_rows)
    assert np.array_equal(u_pos, r_pos)
    assert np.array_equal(u_seg, r_seg)
    rows, pos, seg = build_query_layout(lens, [1, 4, 2])
    assert rows.shape[0] == (1 + 1) + (4 + 1) + (2 + 1)
    assert list(rows) == [0, 0, 1, 1, 1, 1, 1, 2, 2, 2]
    assert list(pos[0]) == [5, 6, 9, 10, 11, 12, 13, 3, 4, 5]
    with pytest.raises(ValueError):
        build_query_layout(lens, [1, 2])


# ----------------------------------------------- scheduler token budget --


def _req(rid, arrival=0.0, prompt_len=40, max_new=8):
    return Request(
        rid=rid,
        dataset="cip",
        difficulty=0.5,
        prompt=np.zeros(prompt_len, np.int32),
        max_new=max_new,
        arrival=arrival,
        emitted=[],
    )


def test_token_budget_split_uses_granted_depths():
    """Ragged depths: shallow decode grants must free budget for prompt
    chunks, deep grants must consume it — at the uniform worst case the
    split degrades to the old n_decode * (gamma + 1)."""
    cfg = SchedulerConfig(
        capacity=4, max_len=128, gamma=4, prefill_chunk=16, token_budget=24
    )
    s = ContinuousScheduler(cfg)
    a, b = _req(0), _req(1)
    s.submit([a, b])
    for r in s.plan(0.0).admit:
        s.mark_admitted(r, 0.0)
    s.mark_prefill_done(a)
    s.mark_prefill_done(b)
    # a third request arrives and starts prefilling
    c = _req(2, arrival=1.0, prompt_len=60)
    s.submit([c])
    dec = s.plan(1.0)
    assert [r.rid for r in dec.admit] == [2]
    s.mark_admitted(c, 1.0)
    # no grants yet -> uniform worst case: 2 decoders cost 2 * (4+1) = 10
    # of the 24-token budget, leaving a 14-token chunk for c
    assert dec.prefill == [(c, 14)]
    # shallow grants (depth 1 each) cost 2 * 2 = 4, leaving 20 -> the
    # chunk cap (16) binds instead of the budget
    s.set_decode_depths({0: 1, 1: 1})
    dec = s.plan(2.0)
    assert dec.prefill == [(c, 16)]
    # deep grants eat the whole budget: decode 2 * (11+1) = 24 -> chunk
    # denied this slot (decode still advances)
    s.set_decode_depths({0: 11, 1: 11})
    dec = s.plan(3.0)
    assert dec.prefill == []
    assert s.decode_cost(0) == 12 and s.decode_cost(2) == cfg.gamma + 1


# ------------------------------------------------------- engine contract --


@pytest.fixture(scope="module")
def models():
    key = jax.random.PRNGKey(0)
    cfg_llm = registry.reduced_for(
        "llama-7b", d_model=96, n_heads=4, n_kv_heads=4, vocab_size=VOCAB
    )
    llm = sd.Bundle(cfg_llm, T.init_params(cfg_llm, key))
    ssms = []
    for i, (d, L) in enumerate([(32, 1), (64, 2)]):
        c = registry.reduced_for(
            "llama-68m",
            d_model=d,
            n_heads=4,
            n_kv_heads=4,
            vocab_size=VOCAB,
            n_layers=L,
        )
        ssms.append(sd.Bundle(c, T.init_params(c, jax.random.PRNGKey(i + 1))))
    return llm, ssms


def greedy_reference(llm, prompt, n_new):
    P = len(prompt)
    toks = jnp.asarray(np.asarray(prompt, np.int32))[None]
    lg, cache = llm.prefill(toks, jnp.asarray([P], jnp.int32), P + n_new + 8)
    V = llm.cfg.vocab_size
    tok = jnp.argmax(lg[:, P - 1, :V], -1, keepdims=True).astype(jnp.int32)
    out = [int(tok[0, 0])]
    lengths = jnp.asarray([P], jnp.int32)
    for _ in range(n_new - 1):
        lg2, cache = llm.decode(cache, tok, lengths)
        tok = jnp.argmax(lg2[:, -1, :V], -1, keepdims=True).astype(jnp.int32)
        lengths = lengths + 1
        out.append(int(tok[0, 0]))
    return out


def _run(llm, ssms, **kw):
    sel = LBSS(
        SelectorConfig(n_ssms=len(ssms), batch_limits=[5, 5], alpha=4, beta=2, seed=1)
    )
    defaults = dict(
        gamma=3, max_len=128, capacity=5, packed_bucket=128, straggler_mitigation=False
    )
    defaults.update(kw)
    eng = SpinEngine(llm, ssms, sel, EngineConfig(**defaults))
    reqs = make_workload("mix", 5, VOCAB, seed=3, scale=0.25)
    eng.add_requests(reqs)
    eng.run(max_slots=120)
    assert all(r.done for r in eng.requests.values())
    return eng


def _assert_greedy_exact(llm, eng):
    for r in eng.requests.values():
        n = r.max_new
        assert r.emitted[:n] == greedy_reference(llm, r.prompt, n), r.rid


@pytest.mark.parametrize("layout", ["paged", "dense"])
def test_fixed_policy_emits_seed_outputs_token_for_token(models, layout):
    """--gamma-policy fixed must reproduce the pre-controller engine
    exactly, which in turn equals plain LLM greedy decoding."""
    llm, ssms = models
    eng = _run(llm, ssms, gamma_policy="fixed", kv_layout=layout)
    assert eng.gamma_max == 3
    _assert_greedy_exact(llm, eng)
    st = eng.gamma_ctl.stats
    assert set(st["depth_hist"]) == {3}, "fixed must grant gamma uniformly"


@pytest.mark.parametrize("layout", ["paged", "dense"])
def test_adaptive_policy_is_lossless_both_layouts(models, layout):
    """Whatever depths the controller grants, greedy spec decoding must
    still emit exactly the LLM's own continuation."""
    llm, ssms = models
    eng = _run(llm, ssms, gamma_policy="adaptive", gamma_max=6, kv_layout=layout)
    _assert_greedy_exact(llm, eng)
    st = eng.gamma_ctl.stats
    assert all(1 <= k <= 6 for k in st["depth_hist"])
    assert st["grants"] > 0 and st["mean_depth"] >= 1.0


def test_adaptive_lossless_with_chunked_prefill_and_budget(models):
    llm, ssms = models
    eng = _run(
        llm,
        ssms,
        gamma_policy="adaptive",
        gamma_max=6,
        prefill_chunk=8,
        token_budget=30,
    )
    _assert_greedy_exact(llm, eng)
    assert eng.scheduler.stats["prefill_grants"] > 0
