"""Multi-replica router (serving/router.py): dispatch conservation,
single-replica bit-identity with the bare engine, policy balance, KV
spill, draining and deterministic tie-breaking — plus the replica
sub-mesh carving in launch/mesh.py."""

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.core import spec_decode as sd
from repro.core.selector import LBSS, SelectorConfig
from repro.data.workloads import make_workload
from repro.launch import mesh as M
from repro.models import transformer as T
from repro.serving.engine import EngineConfig, SpinEngine
from repro.serving.router import Router, RouterConfig

VOCAB = 256


@pytest.fixture(scope="module")
def models():
    key = jax.random.PRNGKey(0)
    cfg_llm = registry.reduced_for(
        "llama-7b", d_model=96, n_heads=4, n_kv_heads=4, vocab_size=VOCAB
    )
    llm = sd.Bundle(cfg_llm, T.init_params(cfg_llm, key))
    ssms = []
    for i, (d, L) in enumerate([(32, 1), (64, 2)]):
        c = registry.reduced_for(
            "llama-68m",
            d_model=d,
            n_heads=4,
            n_kv_heads=4,
            vocab_size=VOCAB,
            n_layers=L,
        )
        ssms.append(sd.Bundle(c, T.init_params(c, jax.random.PRNGKey(i + 1))))
    return llm, ssms


def make_engine(models, capacity=4, kv_budget=None, seed=0, **ecfg_kw):
    llm, ssms = models
    sel = LBSS(
        SelectorConfig(
            n_ssms=len(ssms),
            batch_limits=[capacity] * len(ssms),
            alpha=4,
            beta=2,
            seed=seed,
        )
    )
    ecfg = EngineConfig(
        gamma=3,
        max_len=128,
        capacity=capacity,
        packed_bucket=128,
        straggler_mitigation=False,
        kv_budget=kv_budget,
        seed=seed,
        **ecfg_kw,
    )
    return SpinEngine(llm, ssms, sel, ecfg)


def workload(n=6, rate=300.0, seed=11):
    return make_workload("mix", n, VOCAB, seed=seed, scale=0.25, arrival_rate=rate)


def sim_stats(stats: dict) -> dict:
    """Engine stats minus host wall-clock (recorded for reference only —
    every sim-clock metric must be bit-identical)."""
    return {k: v for k, v in stats.items() if k != "wall_time"}


# ------------------------------------------------------- N=1 bit-identity --


@pytest.mark.parametrize("policy", ["lot", "p2c", "slo"])
def test_single_replica_router_bit_identical(models, policy):
    """A 1-replica router must add nothing: same tokens, same sim clock,
    same scheduler counters as driving the bare engine directly."""
    bare = make_engine(models, capacity=3, kv_budget=96 * 3)
    reqs = workload()
    bare.add_requests(reqs)
    bare_stats = bare.run(max_slots=200)

    routed = make_engine(models, capacity=3, kv_budget=96 * 3)
    router = Router([routed], RouterConfig(policy=policy, seed=5))
    router.submit(workload())
    rstats = router.run(max_slots=200)

    for rid, r in bare.requests.items():
        assert routed.requests[rid].emitted == r.emitted, rid
    # the full engine stats dict — goodput, latency percentiles, TTFT,
    # switch and scheduler counters — must match field for field
    assert sim_stats(rstats["replica_stats"][0]) == sim_stats(bare_stats)
    assert rstats["accepted_tokens"] == bare_stats["accepted_tokens"]
    assert rstats["makespan_sim"] == bare_stats["sim_time"]
    assert rstats["dispatched"] == [len(reqs)]


def test_single_replica_bit_identical_chunked_adaptive(models):
    """Bit-identity must survive the chunked-prefill + adaptive-gamma
    engine features (the paths where admission timing is subtlest)."""
    kw = dict(
        capacity=3,
        prefill_chunk=8,
        token_budget=32,
        gamma_policy="adaptive",
        gamma_max=6,
    )
    bare = make_engine(models, **kw)
    bare.add_requests(workload(seed=23))
    bare_stats = bare.run(max_slots=300)

    routed = make_engine(models, **kw)
    router = Router([routed], RouterConfig(policy="lot"))
    router.submit(workload(seed=23))
    rstats = router.run(max_slots=300)

    for rid, r in bare.requests.items():
        assert routed.requests[rid].emitted == r.emitted, rid
    assert sim_stats(rstats["replica_stats"][0]) == sim_stats(bare_stats)


# ------------------------------------------------------------ conservation --


@pytest.mark.parametrize("policy", ["lot", "p2c", "slo"])
def test_dispatch_conservation_and_losslessness(models, policy):
    """Every request is served by exactly one replica, and sharding the
    stream never changes any request's tokens (speculative decoding is
    lossless per engine, so the dispatch decision must be too)."""
    reqs = workload(n=8, rate=500.0, seed=31)
    ref = make_engine(models, capacity=8)
    ref.add_requests(workload(n=8, rate=500.0, seed=31))
    ref.run(max_slots=200)

    engines = [make_engine(models, capacity=3, seed=i) for i in range(3)]
    router = Router(engines, RouterConfig(policy=policy, seed=7))
    router.submit(reqs)
    st = router.run(max_slots=200)

    owners = {}
    for i, eng in enumerate(engines):
        for rid, r in eng.requests.items():
            assert rid not in owners, f"request {rid} served twice"
            owners[rid] = i
            assert r.done
            want = ref.requests[rid].emitted[: ref.requests[rid].max_new]
            assert r.emitted[: r.max_new] == want
    assert set(owners) == {r.rid for r in reqs}
    assert sum(router.dispatch_count) == len(reqs)
    assert st["finished"] == len(reqs)
    assert st["undispatched"] == 0


# ----------------------------------------------------------------- balance --


def test_lot_balances_skewed_arrivals(models):
    """A burst of same-instant arrivals must spread across replicas under
    least-outstanding-tokens, not pile onto replica 0."""
    reqs = workload(n=9, rate=5000.0, seed=41)  # near-simultaneous burst
    engines = [make_engine(models, capacity=3, seed=i) for i in range(3)]
    router = Router(engines, RouterConfig(policy="lot"))
    router.submit(reqs)
    router.run(max_slots=200)
    counts = router.dispatch_count
    assert sum(counts) == 9
    assert min(counts) >= 2, counts
    assert max(counts) - min(counts) <= 2, counts


def test_p2c_spreads_load(models):
    """Two random probes on free KV must land work on more than one
    replica for a burst (statistical, but deterministic per seed)."""
    reqs = workload(n=9, rate=5000.0, seed=43)
    engines = [make_engine(models, capacity=3, seed=i) for i in range(3)]
    router = Router(engines, RouterConfig(policy="p2c", seed=3))
    router.submit(reqs)
    router.run(max_slots=200)
    counts = router.dispatch_count
    assert sum(counts) == 9
    assert sum(1 for c in counts if c > 0) >= 2, counts


# -------------------------------------------------------------- edge cases --


def test_replicas_drain_on_empty_queues(models):
    """One request, two replicas: the idle replica must not block
    termination or poison the aggregate stats."""
    engines = [make_engine(models, capacity=2, seed=i) for i in range(2)]
    router = Router(engines, RouterConfig())
    router.submit(workload(n=1, rate=100.0, seed=51))
    st = router.run(max_slots=100)
    assert st["finished"] == 1
    assert sorted(router.dispatch_count) == [0, 1]
    idle = router.dispatch_count.index(0)
    assert engines[idle].sim_time == 0.0
    assert st["aggregate_goodput_sim"] > 0.0


@pytest.mark.parametrize("policy", ["lot", "p2c", "slo"])
def test_kv_exhausted_replica_spills_no_deadlock(models, policy):
    """A replica whose KV budget is (nearly) exhausted must not absorb
    the stream: new work spills to the roomy replica and everything still
    finishes — per-replica schedulers guarantee progress, the router must
    not defeat them."""
    # replica 0: 2 blocks of 16 cells — one short request fills it.
    # replica 1: ample.
    tight = make_engine(models, capacity=2, kv_budget=32, seed=0)
    roomy = make_engine(models, capacity=4, kv_budget=4 * 128, seed=1)
    router = Router([tight, roomy], RouterConfig(policy=policy, seed=9))
    reqs = workload(n=6, rate=2000.0, seed=61)
    router.submit(reqs)
    st = router.run(max_slots=400)
    assert st["finished"] == len(reqs), router.dispatch_count
    for eng in (tight, roomy):
        for r in eng.requests.values():
            assert r.done
    if policy == "p2c":
        # KV-aware probing must favour the roomy replica for the burst
        # (lot is token-based and splits a same-instant burst evenly —
        # its guarantee here is progress, which the asserts above cover)
        assert router.dispatch_count[1] > router.dispatch_count[0]


def test_dispatch_avoids_budget_exhausted_replicas(models):
    """A replica that spent its run() step budget can never be stepped
    again in this run — dispatching to it would strand the request, so
    _choose must prefer replicas that can still serve (falling back to
    everyone only when nobody has budget)."""
    reqs = workload(n=1, rate=100.0, seed=81)
    for policy in ("lot", "p2c"):
        engines = [make_engine(models, capacity=2, seed=i) for i in range(2)]
        router = Router(engines, RouterConfig(policy=policy, seed=3))
        router._budget = [0, 5]  # replica 0 exhausted mid-run
        assert router._choose(reqs[0]) == 1
        router._budget = [0, 0]  # nobody left: conservation over progress
        assert router._choose(reqs[0]) in (0, 1)


def test_deterministic_dispatch_and_tie_breaking(models):
    """Same (policy, seed, workload) => identical dispatch map; equal
    replica state => lowest index wins."""
    for policy in ("lot", "p2c"):
        maps = []
        for _ in range(2):
            engines = [make_engine(models, capacity=2, seed=i) for i in range(3)]
            router = Router(engines, RouterConfig(policy=policy, seed=13))
            router.submit(workload(n=5, rate=1000.0, seed=71))
            router.run(max_slots=150)
            maps.append(dict(router.dispatched_to))
        assert maps[0] == maps[1], policy
    # lot on untouched equal replicas: first dispatch goes to replica 0
    engines = [make_engine(models, capacity=2, seed=i) for i in range(3)]
    router = Router(engines, RouterConfig(policy="lot"))
    router.submit(workload(n=1, rate=100.0, seed=73))
    router.run(max_slots=100)
    assert router.dispatched_to[0] == 0


def test_router_config_validation(models):
    with pytest.raises(ValueError):
        RouterConfig(policy="round-robin")
    with pytest.raises(ValueError):
        Router([], RouterConfig())
    eng = make_engine(models, capacity=2)
    with pytest.raises(ValueError):
        Router([eng], RouterConfig(), submeshes=[object(), object()], rules={})


# -------------------------------------------------------- replica sub-mesh --


def test_carve_replica_axis_pure_logic():
    """Device-array carving is pure array logic: each replica gets its
    slice, remaining axes keep their order, and every device appears in
    exactly one sub-array."""
    devs = np.arange(2 * 3 * 4).reshape(2, 3, 4)
    parts, names = M.carve_replica_axis(devs, ("replica", "data", "model"))
    assert names == ("data", "model")
    assert len(parts) == 2
    assert parts[0].shape == (3, 4)
    flat = np.sort(np.concatenate([p.ravel() for p in parts]))
    assert (flat == np.arange(24)).all()
    # replica axis not leading: moveaxis, not reshape
    devs = np.arange(3 * 2 * 4).reshape(3, 2, 4)
    parts, names = M.carve_replica_axis(devs, ("data", "replica", "model"))
    assert names == ("data", "model")
    assert len(parts) == 2 and parts[0].shape == (3, 4)
    want = {int(x) for x in devs[:, 0, :].ravel()}
    assert {int(x) for x in parts[0].ravel()} == want
    # no replica axis: the whole array is the single replica
    parts, names = M.carve_replica_axis(devs, ("pod", "data", "model"))
    assert len(parts) == 1 and names == ("pod", "data", "model")


def test_replica_submeshes_single_device():
    """On the 1-CPU test host: a replica-less mesh round-trips, and the
    replicas=1 constructor still builds a usable mesh."""
    mesh = M.make_local_mesh(1, 1)
    assert M.replica_submeshes(mesh) == [mesh]
    assert "replica" not in mesh.axis_names


def test_replica_sharding_trees_rejects_uncarved_mesh():
    from repro.distributed import sharding as shd

    class FakeMesh:
        def __init__(self, shape):
            self.shape = shape

    with pytest.raises(ValueError):
        shd.replica_sharding_trees(
            [FakeMesh({"replica": 2, "model": 2})], shd.serve_rules(), {}, {}
        )
