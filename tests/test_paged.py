"""Paged KV layout: kernel oracles, pool block accounting, engine parity.

Covers the ISSUE-2 acceptance surface:
* paged_decode / paged_verify Pallas kernels (interpret mode) vs jnp
  oracles on GQA, ragged lengths, single-token tail blocks, and
  fragmented (non-contiguous, shuffled) block tables;
* PagedCachePool property test — block accounting never leaks a block
  across random admit/evict/preempt/grow cycles;
* the paged engine emits bit-identical accepted tokens to the dense
  engine on a fixed trace (packed and padded verification).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.configs import registry
from repro.core import spec_decode as sd
from repro.core.selector import LBSS, SelectorConfig
from repro.data.workloads import make_workload
from repro.kernels import ref
from repro.kernels.paged_attention import (paged_decode_attention,
                                           paged_verify_attention)
from repro.models import transformer as T
from repro.serving.engine import EngineConfig, SpinEngine
from repro.serving.pool import PagedCachePool

VOCAB = 256


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _fragmented_tables(lens, bs, num_blocks, seed=0):
    """Allocate each row's blocks from a shuffled pool (non-contiguous,
    interleaved across rows — the worst-case fragmentation)."""
    rng = np.random.default_rng(seed)
    perm = list(rng.permutation(num_blocks))
    nb_max = max(max(1, -(-int(l) // bs)) for l in lens)
    bt = np.full((len(lens), nb_max), -1, np.int32)
    for b, l in enumerate(lens):
        for k in range(max(1, -(-int(l) // bs))):
            bt[b, k] = perm.pop()
    return bt


# ---------------------------------------------------------------- kernels --

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("lens,H,Kh,D,bs", [
    ([37, 120, 61], 8, 4, 32, 16),     # GQA, ragged
    ([17, 1, 33], 4, 1, 32, 16),       # MQA + single-token rows
    ([16, 32], 4, 4, 16, 16),          # exact block boundaries
    ([129], 8, 2, 64, 32),             # single-token tail block
])
def test_paged_decode_matches_oracle(lens, H, Kh, D, bs, dtype):
    nb_total = sum(max(1, -(-l // bs)) for l in lens) + 3
    bt = _fragmented_tables(lens, bs, nb_total, seed=2)
    B = len(lens)
    q = _rand(jax.random.PRNGKey(0), (B, H, D), dtype)
    kp = _rand(jax.random.PRNGKey(1), (nb_total, bs, Kh, D), dtype)
    vp = _rand(jax.random.PRNGKey(2), (nb_total, bs, Kh, D), dtype)
    lengths = jnp.asarray(lens, jnp.int32)
    out = paged_decode_attention(q, kp, vp, jnp.asarray(bt), lengths,
                                 interpret=True)
    want = ref.paged_decode_ref(q, kp, vp, jnp.asarray(bt), lengths)
    atol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=atol, rtol=1e-2)


@given(lens=st.lists(st.integers(min_value=1, max_value=90), min_size=1,
                     max_size=4),
       seed=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_paged_decode_property(lens, seed):
    H, Kh, D, bs = 4, 2, 16, 8
    nb_total = sum(max(1, -(-l // bs)) for l in lens) + 2
    bt = _fragmented_tables(lens, bs, nb_total, seed=seed)
    B = len(lens)
    q = _rand(jax.random.PRNGKey(3), (B, H, D))
    kp = _rand(jax.random.PRNGKey(4), (nb_total, bs, Kh, D))
    vp = _rand(jax.random.PRNGKey(5), (nb_total, bs, Kh, D))
    lengths = jnp.asarray(lens, jnp.int32)
    out = paged_decode_attention(q, kp, vp, jnp.asarray(bt), lengths,
                                 interpret=True)
    want = ref.paged_decode_ref(q, kp, vp, jnp.asarray(bt), lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=1e-3)


def _verify_setup(lens, bs, num_blocks, H, Kh, D, gamma, seed=0):
    bt = _fragmented_tables(lens, bs, num_blocks, seed=seed)
    pool_seg = np.full((num_blocks, bs), -1, np.int32)
    pool_pos = np.full((num_blocks, bs), -1, np.int32)
    ids, owner = [], []
    for b, l in enumerate(lens):
        for k in range(max(1, -(-int(l) // bs))):
            pb = int(bt[b, k])
            ids.append(pb)
            owner.append(b)
            for s in range(bs):
                p = k * bs + s
                if p < l:
                    pool_seg[pb, s] = 0
                    pool_pos[pb, s] = p
    ids += [0, 0]                       # bucketed-list padding entries
    owner += [-1, -1]
    q_seg = np.repeat(np.arange(len(lens)), gamma + 1).astype(np.int32)
    q_pos = np.concatenate(
        [l + np.arange(gamma + 1) for l in lens]).astype(np.int32)
    q = _rand(jax.random.PRNGKey(6), (len(q_seg), H, D))
    kp = _rand(jax.random.PRNGKey(7), (num_blocks, bs, Kh, D))
    vp = _rand(jax.random.PRNGKey(8), (num_blocks, bs, Kh, D))
    return (q, kp, vp, jnp.asarray(pool_seg), jnp.asarray(pool_pos),
            jnp.asarray(q_seg), jnp.asarray(q_pos),
            jnp.asarray(np.asarray(ids, np.int32)),
            jnp.asarray(np.asarray(owner, np.int32)))


@pytest.mark.parametrize("lens,H,Kh,D,bs,bq", [
    ([37, 120, 61], 8, 4, 32, 16, 8),
    ([5, 5], 4, 4, 16, 8, 16),
    ([33, 1, 97, 15], 4, 1, 32, 16, 8),
])
def test_paged_verify_matches_oracle(lens, H, Kh, D, bs, bq):
    gamma = 4
    nb = sum(max(1, -(-l // bs)) for l in lens) + 2
    args = _verify_setup(lens, bs, nb, H, Kh, D, gamma, seed=3)
    out = paged_verify_attention(*args, bq=bq, interpret=True)
    want = ref.paged_verify_ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=1e-2)


def test_paged_verify_isolation():
    """A request's queries are COMPLETELY unaffected by other requests'
    blocks, however the pool is fragmented."""
    lens, H, Kh, D, bs, gamma = [24, 40], 4, 2, 16, 8, 2
    nb = sum(-(-l // bs) for l in lens) + 2
    q, kp, vp, pseg, ppos, qs, qpos, ids, owner = _verify_setup(
        lens, bs, nb, H, Kh, D, gamma, seed=4)
    out1 = paged_verify_attention(q, kp, vp, pseg, ppos, qs, qpos, ids,
                                  owner, bq=8, interpret=True)
    other = np.asarray(ids)[np.asarray(owner) == 1]
    kp2 = kp.at[other].mul(100.0)
    vp2 = vp.at[other].add(7.0)
    out2 = paged_verify_attention(q, kp2, vp2, pseg, ppos, qs, qpos, ids,
                                  owner, bq=8, interpret=True)
    rows0 = np.where(np.asarray(qs) == 0)[0]
    np.testing.assert_array_equal(np.asarray(out1)[rows0],
                                  np.asarray(out2)[rows0])


def _tree_verify_setup(lens, branch_depths, bs, H, Kh, D, seed=0):
    """Paged tree layout mirroring the engine's CoW fork geometry: each
    request's committed prefix lives in shared blocks (node -1); each
    branch owns private blocks covering its speculation window, whose
    below-the-fork straddle cells are dead duplicates (node -2) and whose
    tree cells carry node tags."""
    rng = np.random.default_rng(seed)
    blocks = []                   # (owner_seg, node_row, seg_row, pos_row)
    q_seg, q_pos, q_anc = [], [], []
    for i, (l, ks) in enumerate(zip(lens, branch_depths)):
        for b0 in range(0, l, bs):
            node = np.full(bs, -1, np.int32)
            seg = np.full(bs, -1, np.int32)
            pos = np.full(bs, -1, np.int32)
            n = min(bs, l - b0)
            seg[:n] = 0
            pos[:n] = b0 + np.arange(n)
            blocks.append((i, node, seg, pos))
        off = 0
        for k in ks:
            lo = (l // bs) * bs           # branch copies start mid-block
            for b0 in range(lo, l + k + 1, bs):
                node = np.full(bs, -2, np.int32)
                seg = np.full(bs, -1, np.int32)
                pos = np.full(bs, -1, np.int32)
                for s in range(bs):
                    p = b0 + s
                    if p < l:             # dead straddle duplicate
                        seg[s] = 0
                        pos[s] = p
                    elif p <= l + k:      # tree node off + (p - l)
                        seg[s] = 0
                        pos[s] = p
                        node[s] = off + (p - l)
                blocks.append((i, node, seg, pos))
            for d in range(k + 1):
                q_seg.append(i)
                q_pos.append(l + d)
                q_anc.append(((1 << (d + 1)) - 1) << off)
            off += k + 1
    nb = len(blocks)
    perm = rng.permutation(nb)            # fragmented physical placement
    pool_seg = np.full((nb + 2, bs), -1, np.int32)
    pool_pos = np.full((nb + 2, bs), -1, np.int32)
    kp = np.asarray(rng.normal(size=(nb + 2, bs, Kh, D)), np.float32)
    vp = np.asarray(rng.normal(size=(nb + 2, bs, Kh, D)), np.float32)
    ids, owner, node_rows = [], [], []
    for m, (own, node, seg, pos) in enumerate(blocks):
        pb = int(perm[m])
        pool_seg[pb] = seg
        pool_pos[pb] = pos
        # poison dead duplicates: masked slots must not leak into outputs
        kp[pb, node == -2] = 1e3
        vp[pb, node == -2] = -1e3
        ids.append(pb)
        owner.append(own)
        node_rows.append(node)
    ids += [0, 0]                         # bucketed-list padding entries
    owner += [-1, -1]
    node_rows += [np.full(bs, -1, np.int32)] * 2
    q = _rand(jax.random.PRNGKey(9), (len(q_seg), H, D))
    return (q, jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(pool_seg),
            jnp.asarray(pool_pos), jnp.asarray(np.array(q_seg, np.int32)),
            jnp.asarray(np.array(q_pos, np.int32)),
            jnp.asarray(np.array(ids, np.int32)),
            jnp.asarray(np.array(owner, np.int32)),
            jnp.asarray(np.array(q_anc, np.int32)),
            jnp.asarray(np.stack(node_rows)))


@pytest.mark.parametrize("lens,branch_depths,bs", [
    ([37, 61], [[2, 1], [3]], 16),
    ([5, 9], [[1, 1, 1], [4]], 8),
    ([120], [[5, 4, 3]], 32),
    ([33, 1, 15], [[2, 2], [1, 0], [3]], 8),
])
def test_paged_verify_tree_matches_oracle(lens, branch_depths, bs):
    H, Kh, D = 4, 2, 32
    args = _tree_verify_setup(lens, branch_depths, bs, H, Kh, D, seed=5)
    out = paged_verify_attention(*args, bq=8, interpret=True)
    want = ref.paged_verify_ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=1e-2)


@given(seed=st.integers(0, 1000))
@settings(max_examples=8, deadline=None)
def test_paged_verify_tree_property(seed):
    """Randomized tree topologies over randomized block sizes and ragged
    prefix depths, fragmented placement included."""
    rng = np.random.default_rng(seed)
    bs = int(rng.choice([8, 16]))
    n = int(rng.integers(1, 4))
    lens = [int(x) for x in rng.integers(1, 70, n)]
    branch_depths = [[int(d) for d in
                      rng.integers(0, 5, int(rng.integers(1, 4)))]
                     for _ in range(n)]
    args = _tree_verify_setup(lens, branch_depths, bs, 4, 2, 16, seed=seed)
    out = paged_verify_attention(*args, bq=8, interpret=True)
    want = ref.paged_verify_ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=1e-3)


def test_paged_verify_degenerate_tree_mask_is_linear():
    """All-(-1) tree metadata must reproduce the mask-free paged call
    bit-for-bit (the b=1 bit-identity contract)."""
    lens, H, Kh, D, bs, gamma = [24, 40], 4, 2, 16, 8, 2
    nb = sum(-(-l // bs) for l in lens) + 2
    q, kp, vp, pseg, ppos, qs, qpos, ids, owner = _verify_setup(
        lens, bs, nb, H, Kh, D, gamma, seed=6)
    plain = paged_verify_attention(q, kp, vp, pseg, ppos, qs, qpos, ids,
                                   owner, bq=8, interpret=True)
    anc = jnp.full((qs.shape[0],), -1, jnp.int32)
    node = jnp.full((ids.shape[0], bs), -1, jnp.int32)
    treed = paged_verify_attention(q, kp, vp, pseg, ppos, qs, qpos, ids,
                                   owner, anc, node, bq=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(treed))


# ----------------------------------------------------- pool block ledger --

def _pool(capacity=4, max_len=64, bs=8, num_blocks=None):
    cfg = registry.reduced_for("llama-68m", d_model=32, n_heads=4,
                               n_kv_heads=4, vocab_size=64, n_layers=1)
    return PagedCachePool(cfg, capacity, max_len, bs, num_blocks=num_blocks)


def _one_cache(pool, length):
    S = pool.prefill_len(max(16, length))
    return T.init_cache(pool.cfg, 1, S)


def _ledger_ok(pool):
    table_blocks = [int(b) for row in range(pool.capacity)
                    for b in pool._table[row, :pool._nb[row]]]
    assert len(set(table_blocks)) == len(table_blocks), "double allocation"
    assert sorted(table_blocks + pool._free_blocks) == \
        list(range(pool.num_blocks)), "blocks leaked or duplicated"
    assert pool.free_blocks + pool.allocated_blocks == pool.num_blocks
    assert sorted(pool.row_of.values()) == sorted(
        set(pool.row_of.values())), "row double-booked"


@given(ops=st.lists(st.tuples(st.sampled_from(["admit", "evict", "grow"]),
                              st.integers(0, 7), st.integers(1, 60)),
                    min_size=1, max_size=40))
@settings(max_examples=15, deadline=None)
def test_pool_block_accounting_never_leaks(ops):
    pool = _pool()
    for op, rid, length in ops:
        if op == "admit" and not pool.has(rid):
            if pool.can_admit(length):
                pool.insert(rid, _one_cache(pool, length), length, 0)
        elif op == "evict" and pool.has(rid):
            pool.evict(rid)
        elif op == "grow" and pool.has(rid):
            need = min(int(pool.lengths[pool.row_of[rid]]) + length,
                       pool.max_len)
            if pool.blocks_needed(need) - pool._nb[pool.row_of[rid]] \
                    <= pool.free_blocks:
                pool.ensure(rid, need)
        _ledger_ok(pool)
    for rid in list(pool.row_of):
        pool.evict(rid)
        _ledger_ok(pool)
    assert pool.free_blocks == pool.num_blocks


def test_pool_admission_and_oversubscription_guards():
    pool = _pool(capacity=2, max_len=64, bs=8, num_blocks=8)
    assert pool.num_blocks == 8
    pool.insert(0, _one_cache(pool, 40), 40, 1)       # 5 blocks
    assert pool.free_blocks == 3
    assert not pool.can_admit(40)                     # would need 5 > 3
    assert pool.can_admit(20)                         # 3 blocks fit
    pool.insert(1, _one_cache(pool, 20), 20, 1)       # takes the last 3
    assert pool.free_blocks == 0
    with pytest.raises(RuntimeError, match="out of blocks"):
        pool.ensure(0, 48)                            # +1 block, none free
    # growth past max_len clamps to blocks_per_row (dense drops the same
    # overshoot writes), it is not an allocation error
    pool.evict(1)
    pool.ensure(0, pool.max_len + 10)
    assert pool.allocated_blocks == pool.blocks_per_row
    pool.evict(0)
    assert pool.free_blocks == 8


# ------------------------------------------------------- engine parity ----

@pytest.fixture(scope="module")
def models():
    key = jax.random.PRNGKey(0)
    cfg_llm = registry.reduced_for("llama-7b", d_model=96, n_heads=4,
                                   n_kv_heads=4, vocab_size=VOCAB)
    llm = sd.Bundle(cfg_llm, T.init_params(cfg_llm, key))
    ssms = []
    for i, (d, L) in enumerate([(32, 1), (64, 2)]):
        c = registry.reduced_for("llama-68m", d_model=d, n_heads=4,
                                 n_kv_heads=4, vocab_size=VOCAB, n_layers=L)
        ssms.append(sd.Bundle(c, T.init_params(c, jax.random.PRNGKey(i + 1))))
    return llm, ssms


def _run_engine(llm, ssms, layout, packed, kv_budget=None):
    sel = LBSS(SelectorConfig(n_ssms=len(ssms),
                              batch_limits=[4] * len(ssms),
                              alpha=4, beta=2, seed=1))
    ecfg = EngineConfig(gamma=3, max_len=128, capacity=4,
                        use_packed_verify=packed, packed_bucket=128,
                        straggler_mitigation=False, kv_layout=layout,
                        block_size=16, kv_budget=kv_budget)
    eng = SpinEngine(llm, ssms, sel, ecfg)
    reqs = make_workload("mix", 4, VOCAB, seed=7, scale=0.25,
                         arrival_rate=400.0)
    eng.add_requests(reqs)
    eng.run(max_slots=300)
    assert all(r.done for r in eng.requests.values())
    return eng


@pytest.mark.parametrize("packed", [True, False])
def test_paged_engine_bit_identical_to_dense(models, packed):
    """Same fixed arrival trace, same models: the paged engine must emit
    exactly the dense engine's accepted tokens (acceptance criterion)."""
    llm, ssms = models
    dense = _run_engine(llm, ssms, "dense", packed)
    paged = _run_engine(llm, ssms, "paged", packed)
    assert paged.paged and not dense.paged
    for rid in dense.requests:
        assert dense.requests[rid].emitted == paged.requests[rid].emitted, rid
    # all blocks returned once the stream drained
    assert paged.llm_pool.free_blocks == paged.llm_pool.num_blocks


def test_paged_engine_budget_is_physical(models):
    """Under a binding budget the pool's live allocation never exceeds the
    scheduler's block budget — the budget is enforced, not modeled."""
    llm, ssms = models
    sel = LBSS(SelectorConfig(n_ssms=len(ssms), batch_limits=[3, 3],
                              alpha=4, beta=2, seed=1))
    ecfg = EngineConfig(gamma=3, max_len=128, capacity=3,
                        use_packed_verify=True, packed_bucket=128,
                        straggler_mitigation=False, kv_budget=96,
                        block_size=16)
    eng = SpinEngine(llm, ssms, sel, ecfg)
    reqs = make_workload("mix", 5, VOCAB, seed=3, scale=0.25,
                         arrival_rate=500.0)
    eng.add_requests(reqs)
    budget_blocks = 96 // 16
    peak = 0
    for _ in range(400):
        rec = eng.step()
        peak = max(peak, eng.llm_pool.allocated_blocks)
        if rec.get("done") and not eng.scheduler.outstanding:
            break
    assert all(r.done for r in eng.requests.values())
    assert eng.scheduler.preemptions > 0
    assert peak <= budget_blocks, (peak, budget_blocks)


def test_paged_falls_back_to_dense_for_recurrent_models():
    from repro.serving.paged import paged_compatible
    cfg = registry.reduced_for("zamba2-1.2b", d_model=32, n_heads=4,
                               n_kv_heads=4, vocab_size=64, n_layers=2)
    assert not paged_compatible(cfg)   # engine auto-falls back to dense
    with pytest.raises(ValueError, match="attention-only"):
        T.init_paged_cache(cfg, 8, 16)
