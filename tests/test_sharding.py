"""Sharding-rule assignment + lowering machinery on a 1x1 mesh (the
512-device production meshes are exercised by launch/dryrun.py, which must
own its process — here we verify the same code paths cheaply)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.distributed import sharding as shd
from repro.models import transformer as T
from repro.optim import AdamW


class FakeMesh:
    """Minimal mesh stand-in exposing .shape for assign_spec tests."""
    def __init__(self, shape):
        self.shape = shape


def test_assign_spec_divisibility_fallback():
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = shd.serve_rules(False)
    # kv_heads=8 can't shard over model=16 -> cache_seq takes the model axis
    spec = shd.assign_spec(rules, ("cache_batch", "cache_seq", "kv_heads",
                                   "head_dim"), (128, 32768, 8, 128), mesh)
    assert spec == P("data", "model", None, None)
    # kv_heads=16 divides -> it gets the axis, seq stays unsharded
    spec = shd.assign_spec(rules, ("cache_batch", "cache_seq", "kv_heads",
                                   "head_dim"), (128, 32768, 16, 128), mesh)
    assert spec == P("data", None, "model", None)


def test_assign_spec_no_axis_reuse():
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = shd.train_rules(False)
    # both vocab and heads want "model": only one (higher priority) gets it
    spec = shd.assign_spec(rules, ("vocab", "heads"), (32768, 48), mesh)
    assert tuple(spec).count("model") == 1


def test_assign_spec_multipod_batch():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    rules = shd.train_rules(True)
    spec = shd.assign_spec(rules, ("batch", "seq"), (256, 4096), mesh)
    assert spec[0] == ("pod", "data")
    # batch=1 can't shard at all
    spec = shd.assign_spec(rules, ("batch", "seq"), (1, 4096), mesh)
    assert spec == P(None, None)


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mixtral-8x22b",
                                  "zamba2-1.2b", "xlstm-350m"])
def test_full_param_sharding_tree_covers_every_leaf(arch):
    """Production-mesh shardings must exist for every parameter leaf and
    respect divisibility (checked via assign_spec internals)."""
    cfg = registry.get(arch)
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = shd.train_rules(False)
    ab = T.abstract_params(cfg)
    ax = T.logical_axes(cfg)
    flat_ab = jax.tree.leaves(ab)
    def is_axes(a):
        return isinstance(a, tuple) and all(
            isinstance(e, (str, type(None))) for e in a)
    flat_ax = jax.tree.leaves(ax, is_leaf=is_axes)
    assert len(flat_ab) == len(flat_ax)
    for leaf, axes in zip(flat_ab, flat_ax):
        spec = shd.assign_spec(rules, axes, leaf.shape, mesh)
        for dim, part in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if part is None:
                continue
            size = np.prod([mesh.shape[a] for a in
                            ((part,) if isinstance(part, str) else part)])
            assert dim % size == 0, (arch, axes, leaf.shape, spec)


def test_lowering_on_tiny_mesh_end_to_end():
    """Lower + compile a reduced train step on the real 1-device mesh with
    rule-driven shardings + constrain() active — same code path as dryrun."""
    cfg = registry.reduced_for("qwen2-0.5b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = shd.train_rules(False)
    opt = AdamW(lr=1e-3)
    ab = T.abstract_params(cfg)
    ax = T.logical_axes(cfg)
    sh = shd.sharding_tree(mesh, rules, ax, ab)
    ab_opt = opt.abstract_state(ab)
    step = T.make_train_step(cfg, opt, T.Opts(remat="dots"))
    batch = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
             "labels": jax.ShapeDtypeStruct((4, 32), jnp.int32)}
    jitted = jax.jit(step, in_shardings=(sh, None, None))
    with mesh, shd.use_rules(mesh, rules):
        lowered = jitted.lower(ab, ab_opt, batch)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):      # older jax: one dict per device
        cost = cost[0]
    assert cost["flops"] > 0
    mem = compiled.memory_analysis()
    assert mem.temp_size_in_bytes >= 0


def test_collective_parser():
    from repro.launch.dryrun import collective_wire_bytes
    hlo = """
  %ar = f32[16,512]{1,0} all-reduce(f32[16,512]{1,0} %x), replica_groups={}
  %ag.1 = bf16[4,128]{1,0} all-gather(bf16[2,128]{1,0} %y), dimensions={0}
  %cp = f32[8]{0} collective-permute(f32[8]{0} %z)
"""
    out = collective_wire_bytes(hlo)
    assert out["all-reduce"] == 2 * 16 * 512 * 4
    assert out["all-gather"] == 4 * 128 * 2
    assert out["collective-permute"] == 8 * 4
    assert out["total"] == sum(v for k, v in out.items() if k != "total")
