"""Tree speculation differential suite (ISSUE 6).

The load-bearing guarantees, each pinned by a test:

* ``--spec-shape tree`` with branching 1 is the SAME algorithm as linear
  speculation — bit-identical emitted tokens AND an identical sim clock,
  across paged/dense layouts, adaptive gamma, and chunked prefill;
* branching > 1 stays lossless: every emitted stream equals the plain
  greedy decode of the target model (tree verify accepts the longest
  verified root-to-leaf path, ties to the main chain, bonus = LLM argmax);
* tree mode requires the paged CoW layout — dense falls back to linear
  with a warning and then behaves exactly like linear;
* a drained tree run returns every CoW block to the free list.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import spec_decode as sd
from repro.core.selector import LBSS, SelectorConfig
from repro.data.workloads import make_workload
from repro.models import transformer as T
from repro.serving.engine import EngineConfig, SpinEngine

VOCAB = 256


@pytest.fixture(scope="module")
def models():
    key = jax.random.PRNGKey(0)
    cfg_llm = registry.reduced_for("llama-7b", d_model=96, n_heads=4,
                                   n_kv_heads=4, vocab_size=VOCAB)
    llm = sd.Bundle(cfg_llm, T.init_params(cfg_llm, key))
    ssms = []
    for i, (d, L) in enumerate([(32, 1), (64, 2)]):
        c = registry.reduced_for("llama-68m", d_model=d, n_heads=4,
                                 n_kv_heads=4, vocab_size=VOCAB, n_layers=L)
        ssms.append(sd.Bundle(c, T.init_params(c, jax.random.PRNGKey(i + 1))))
    return llm, ssms


def greedy_reference(llm, prompt, n_new):
    """Plain greedy decode of the target model — the lossless contract."""
    P = len(prompt)
    toks = jnp.asarray(np.asarray(prompt, np.int32))[None]
    logits, cache = llm.prefill(toks, jnp.asarray([P], jnp.int32),
                                P + n_new + 8)
    V = llm.cfg.vocab_size
    tok = jnp.argmax(logits[:, P - 1, :V], -1, keepdims=True).astype(
        jnp.int32)
    out = [int(tok[0, 0])]
    lengths = jnp.asarray([P], jnp.int32)
    for _ in range(n_new - 1):
        logits, cache = llm.decode(cache, tok, lengths)
        tok = jnp.argmax(logits[:, -1, :V], -1, keepdims=True).astype(
            jnp.int32)
        lengths = lengths + 1
        out.append(int(tok[0, 0]))
    return out


def _run(llm, ssms, **kw):
    sel = LBSS(SelectorConfig(n_ssms=2, batch_limits=[5, 5], alpha=4,
                              beta=2, seed=1))
    defaults = dict(gamma=3, max_len=128, capacity=5, packed_bucket=128,
                    straggler_mitigation=False)
    defaults.update(kw)
    eng = SpinEngine(llm, ssms, sel, EngineConfig(**defaults))
    reqs = make_workload("mix", 5, VOCAB, seed=3, scale=0.25)
    eng.add_requests(reqs)
    eng.run(max_slots=160)
    assert all(r.done for r in eng.requests.values()), "stream must drain"
    return eng


def _same_trace(a, b):
    """Bit-identical output contract AND sim-clock bookkeeping."""
    for rid in a.requests:
        assert a.requests[rid].emitted == b.requests[rid].emitted, rid
    assert a.accepted_tokens == b.accepted_tokens
    assert a.sim_time == b.sim_time, (a.sim_time, b.sim_time)
    sa, sb = a.stats(), b.stats()
    for key in ("drafted", "slots", "goodput_sim", "p95_latency"):
        if key in sa:
            assert sa[key] == sb[key], key


# a branching factor of 1 must be THE SAME ALGORITHM as linear drafting,
# not merely lossless: same tokens, same accept counts, same sim clock
CONFIGS = {
    "paged-fixed": dict(),
    "paged-adaptive-chunked": dict(gamma_policy="adaptive", gamma_max=4,
                                   prefill_chunk=8, token_budget=30),
    "paged-kv-budget": dict(kv_budget=512, block_size=16),
    "dense-fallback": dict(kv_layout="dense"),
}


@pytest.mark.parametrize("config", sorted(CONFIGS))
def test_tree_branch1_bit_identical_to_linear(models, config):
    llm, ssms = models
    kw = CONFIGS[config]
    lin = _run(llm, ssms, **kw)
    with warnings.catch_warnings():
        # dense-fallback: the layout warning is the point of that config
        warnings.simplefilter("ignore")
        tree = _run(llm, ssms, spec_shape="tree", spec_branch=1, **kw)
    _same_trace(lin, tree)


def test_tree_branch2_lossless_and_drains_blocks(models):
    llm, ssms = models
    eng = _run(llm, ssms, spec_shape="tree", spec_branch=2)
    st = eng.stats()
    assert st["spec_shape"] == "tree" and st["spec_branches"] == 2
    assert st["tree_forks"] > 0
    for r in eng.requests.values():
        n = min(r.max_new, len(r.emitted))
        assert list(r.emitted[:n]) == greedy_reference(llm, r.prompt, n), \
            f"request {r.rid} diverged from plain greedy decode"
    # every CoW fork released its references: nothing leaked
    assert eng.llm_pool.free_blocks == eng.llm_pool.num_blocks


def test_tree_adaptive_gamma_lossless(models):
    llm, ssms = models
    eng = _run(llm, ssms, spec_shape="tree", spec_branch=2,
               gamma_policy="adaptive", gamma_max=4)
    assert eng.stats()["tree_forks"] > 0
    for r in eng.requests.values():
        n = min(r.max_new, len(r.emitted))
        assert list(r.emitted[:n]) == greedy_reference(llm, r.prompt, n), \
            f"request {r.rid} diverged from plain greedy decode"


def test_tree_on_dense_layout_warns_and_falls_back(models):
    llm, ssms = models
    sel = LBSS(SelectorConfig(n_ssms=2, batch_limits=[5, 5], alpha=4,
                              beta=2, seed=1))
    with pytest.warns(UserWarning, match="falling back to linear"):
        eng = SpinEngine(llm, ssms, sel, EngineConfig(
            gamma=3, max_len=128, capacity=5, packed_bucket=128,
            straggler_mitigation=False, kv_layout="dense",
            spec_shape="tree", spec_branch=2))
    assert not eng.tree
    assert eng.stats()["spec_shape"] == "linear"
    assert eng.stats()["spec_branches"] == 1


def test_tree_node_budget_guard(models):
    llm, ssms = models
    sel = LBSS(SelectorConfig(n_ssms=2, batch_limits=[5, 5], alpha=4,
                              beta=2, seed=1))
    with pytest.raises(ValueError, match="32"):
        SpinEngine(llm, ssms, sel, EngineConfig(
            gamma=30, max_len=128, capacity=5, packed_bucket=128,
            spec_shape="tree", spec_branch=4))


def test_serve_cli_rejects_oversized_tree():
    from repro.launch.serve import main
    with pytest.raises(SystemExit):
        main(["--spec-shape", "tree", "--gamma", "30", "--spec-branch", "4"])
    with pytest.raises(SystemExit):
        main(["--spec-shape", "tree", "--spec-branch", "0"])
