"""Quantized paged KV (ISSUE 8): round-trip error bounds for the
``kernels/quant.py`` helpers, quantized-kernel vs dense-oracle agreement
for all four paged attention kernels, CoW fork/cow_prepare/rename ledger
invariants with scale sidecars riding along, engine-level behavior of
``--kv-dtype`` (bf16 structural bit-identity, int8 fused==unfused,
dense-fallback warning), and the autotune tune-key kv-dtype component
with legacy/corrupt cache-key migration."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st
from test_fused import _decode_setup
from test_paged import _tree_verify_setup, _verify_setup
from test_pool_properties import _cow_ledger_ok

from repro.configs import registry
from repro.core import spec_decode as sd
from repro.core.selector import LBSS, SelectorConfig
from repro.data.workloads import make_workload
from repro.kernels import autotune, ops, quant, ref
from repro.models import transformer as T
from repro.serving.engine import EngineConfig, SpinEngine
from repro.serving.pool import PagedCachePool

VOCAB = 256


# ------------------------------------------------------ quantize helpers --

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), scale_pow=st.integers(-6, 6))
def test_int8_roundtrip_error_bound(seed, scale_pow):
    """Symmetric int8 round-trip error is at most half a quantization
    step per element: |dq - x| <= scale / 2 = amax / 254."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((5, 4, 16)).astype(np.float32) * 2.0 ** scale_pow
    q, sc = quant.quantize(jnp.asarray(x), jnp.int8)
    assert q.dtype == jnp.int8 and sc.dtype == jnp.float32
    assert sc.shape == x.shape[:-1]
    dq = np.asarray(quant.dequantize(q, sc))
    bound = np.asarray(sc)[..., None] * 0.5 + 1e-12
    assert (np.abs(dq - x) <= bound).all()


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_fp8_roundtrip_error_bound(seed):
    """e4m3 keeps 3 mantissa bits: relative error <= 2^-4 per element,
    plus one subnormal half-step (2^-10 scale) near zero."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((5, 4, 16)).astype(np.float32)
    q, sc = quant.quantize(jnp.asarray(x), jnp.float8_e4m3fn)
    assert q.dtype == jnp.float8_e4m3fn
    dq = np.asarray(quant.dequantize(q, sc))
    bound = np.abs(x) * 2.0 ** -4 + np.asarray(sc)[..., None] * 2.0 ** -10 \
        + 1e-12
    assert (np.abs(dq - x) <= bound).all()


@pytest.mark.parametrize("qdt", [jnp.int8, jnp.float8_e4m3fn])
def test_roundtrip_error_bound_example(qdt):
    """Example-based twin of the property tests above (runs in bare
    environments where hypothesis is unavailable)."""
    rng = np.random.default_rng(7)
    x = rng.standard_normal((6, 4, 32)).astype(np.float32)
    q, sc = quant.quantize(jnp.asarray(x), qdt)
    dq = np.asarray(quant.dequantize(q, sc))
    if qdt == jnp.int8:
        bound = np.asarray(sc)[..., None] * 0.5 + 1e-12
    else:
        bound = np.abs(x) * 2.0 ** -4 + np.asarray(sc)[..., None] * 2.0 ** -10
    assert (np.abs(dq - x) <= bound).all()


@pytest.mark.parametrize("qdt", [jnp.int8, jnp.float8_e4m3fn])
def test_all_zero_rows_quantize_exactly(qdt):
    q, sc = quant.quantize(jnp.zeros((3, 2, 8)), qdt)
    np.testing.assert_array_equal(np.asarray(sc), 0.0)
    np.testing.assert_array_equal(
        np.asarray(quant.dequantize(q, sc)), 0.0)


def test_kv_dtype_names_round_trip():
    assert quant.storage_dtype("bf16") is None
    assert quant.storage_dtype("int8") == jnp.int8
    assert quant.storage_dtype("fp8") == jnp.float8_e4m3fn
    assert quant.dtype_name(jnp.int8) == "int8"
    assert quant.dtype_name(jnp.float8_e4m3fn) == "fp8"
    assert quant.dtype_name(jnp.bfloat16) == "bf16"
    assert quant.dtype_name(jnp.float32) == "bf16"
    with pytest.raises(ValueError, match="kv_dtype"):
        quant.storage_dtype("int4")


# --------------------------------------------- kernels vs dense oracles --

def _quantize_pools(kp, vp, qdt):
    kq, ks = quant.quantize(kp, qdt)
    vq, vs = quant.quantize(vp, qdt)
    return kq, vq, ks, vs


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_paged_decode_quantized_matches_oracle(kv_dtype):
    qdt = quant.storage_dtype(kv_dtype)
    rng = np.random.default_rng(11)
    N, bs, Kh, D, H, B = 8, 16, 4, 16, 8, 3
    kp = jnp.asarray(rng.standard_normal((N, bs, Kh, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((N, bs, Kh, D)), jnp.float32)
    kq, vq, ks, vs = _quantize_pools(kp, vp, qdt)
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    bt = jnp.asarray([[0, 1, 2], [3, -1, -1], [4, 5, -1]], jnp.int32)
    lens = jnp.asarray([40, 9, 20], jnp.int32)
    out = ops.paged_decode_attention(q, kq, vq, bt, lens, ks, vs)
    want = ref.paged_decode_ref(q, kq, vq, bt, lens, ks, vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=1e-2)


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
@pytest.mark.parametrize("bq", [128, 8])
def test_paged_verify_quantized_matches_oracle(kv_dtype, bq):
    qdt = quant.storage_dtype(kv_dtype)
    lens, H, Kh, D, bs = [37, 61, 15], 4, 2, 16, 8
    nb = sum(-(-L // bs) for L in lens) + 2
    q, kp, vp, pseg, ppos, qs, qpos, ids, owner = _verify_setup(
        lens, bs, nb, H, Kh, D, 3, seed=21)
    kq, vq, ks, vs = _quantize_pools(kp, vp, qdt)
    out = ops.paged_verify_attention(q, kq, vq, pseg, ppos, qs, qpos,
                                     ids, owner, ks, vs, bq=bq)
    want = ref.paged_verify_ref(q, kq, vq, pseg, ppos, qs, qpos, ids,
                                owner, k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=1e-2)


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
@pytest.mark.parametrize("bk,depth", [(0, 1), (8, 2)])
def test_fused_decode_quantized_matches_oracle(kv_dtype, bk, depth):
    qdt = quant.storage_dtype(kv_dtype)
    q, kp, vp, pseg, ppos, qs, qpos, bt = _decode_setup(
        [37, 120, 61], 16, 4, 2, 16, 4, seed=5, idle_rows=1)
    kq, vq, ks, vs = _quantize_pools(kp, vp, qdt)
    out = ops.fused_paged_decode(q, kq, vq, pseg, ppos, qs, qpos, bt,
                                 ks, vs,
                                 config=autotune.FusedConfig(bk=bk,
                                                             depth=depth))
    want = ref.paged_seq_decode_ref(q, kq, vq, pseg, ppos, qs, qpos, bt,
                                    ks, vs)
    np.testing.assert_allclose(np.asarray(out)[:3], np.asarray(want)[:3],
                               atol=2e-5, rtol=1e-2)


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_fused_verify_quantized_tree_matches_oracle(kv_dtype):
    qdt = quant.storage_dtype(kv_dtype)
    args = _tree_verify_setup([37, 61], [[2, 1], [3]], 16, 4, 2, 16,
                              seed=9)
    q, kp, vp, pseg, ppos, qs, qpos, ids, owner, anc, node = args
    kq, vq, ks, vs = _quantize_pools(kp, vp, qdt)
    out = ops.fused_paged_verify(
        q, kq, vq, pseg, ppos, qs, qpos, ids, owner, anc, node, ks, vs,
        config=autotune.FusedConfig(bq=8, bk=0, depth=2))
    want = ref.paged_verify_ref(q, kq, vq, pseg, ppos, qs, qpos, ids,
                                owner, anc, node, ks, vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=1e-2)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_fused_decode_quantized_property(seed):
    rng = np.random.default_rng(seed)
    lens = [int(rng.integers(1, 80))
            for _ in range(int(rng.integers(1, 4)))]
    bs = int(rng.choice([8, 16]))
    Tn = int(rng.integers(1, 5))
    q, kp, vp, pseg, ppos, qs, qpos, bt = _decode_setup(
        lens, bs, 4, 2, 16, Tn, seed=seed)
    qdt = quant.storage_dtype(str(rng.choice(["int8", "fp8"])))
    kq, vq, ks, vs = _quantize_pools(kp, vp, qdt)
    cfg = autotune.FusedConfig(bk=int(rng.choice([0, bs // 2])),
                               depth=int(rng.integers(1, 3)))
    out = ops.fused_paged_decode(q, kq, vq, pseg, ppos, qs, qpos, bt,
                                 ks, vs, config=cfg)
    want = ref.paged_seq_decode_ref(q, kq, vq, pseg, ppos, qs, qpos, bt,
                                    ks, vs)
    live = len(lens)
    np.testing.assert_allclose(np.asarray(out)[:live],
                               np.asarray(want)[:live],
                               atol=2e-5, rtol=1e-2)


# -------------------------------------------------- pool scale sidecars --

def _pool(kv_dtype, capacity=4, max_len=64, bs=8, num_blocks=None):
    cfg = registry.reduced_for("llama-68m", d_model=32, n_heads=4,
                               n_kv_heads=4, vocab_size=64, n_layers=1)
    return PagedCachePool(cfg, capacity, max_len, bs,
                          num_blocks=num_blocks, kv_dtype=kv_dtype)


def _one_cache(pool, length, seed=0):
    S = pool.prefill_len(max(16, length))
    cache = T.init_cache(pool.cfg, 1, S)
    key = jax.random.PRNGKey(seed)
    out = []
    for i, leaf in enumerate(jax.tree.leaves(cache)):
        if leaf.ndim >= 4 and jnp.issubdtype(leaf.dtype, jnp.floating):
            leaf = jax.random.normal(jax.random.fold_in(key, i),
                                     leaf.shape, leaf.dtype)
        out.append(leaf)
    return jax.tree.unflatten(jax.tree.structure(cache), out)


def test_bf16_pool_tree_is_structurally_unquantized():
    """The by-construction bit-identity witness: ``kv_dtype='bf16'``
    produces the exact pre-quantization cache tree — same leaves, same
    shapes, same dtypes, no scale sidecars anywhere — so every PR-7 code
    path runs unchanged."""
    pool = _pool("bf16")
    plain = T.init_paged_cache(pool.cfg, pool.num_blocks, pool.block_size)
    assert jax.tree.structure(pool.cache) == jax.tree.structure(plain)
    for a, b in zip(jax.tree.leaves(pool.cache), jax.tree.leaves(plain)):
        assert a.shape == b.shape and a.dtype == b.dtype
    flat = jax.tree_util.tree_leaves_with_path(pool.cache)
    assert not any("scale" in jax.tree_util.keystr(p) for p, _ in flat)


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_quantized_pool_has_scale_sidecars(kv_dtype):
    pool = _pool(kv_dtype)
    qdt = quant.storage_dtype(kv_dtype)
    leaves = jax.tree_util.tree_leaves_with_path(pool.cache)
    kv = [(p, x) for p, x in leaves
          if jax.tree_util.keystr(p).endswith("['k']")
          or jax.tree_util.keystr(p).endswith("['v']")]
    sc = [(p, x) for p, x in leaves if "scale" in jax.tree_util.keystr(p)]
    assert kv and sc and len(sc) == len(kv)
    for _, x in kv:
        assert x.dtype == qdt
    for _, x in sc:
        assert x.dtype == jnp.float32
        assert x.shape[-3:] == (pool.num_blocks, pool.block_size,
                                pool.cfg.n_kv_heads) or \
            x.shape == (pool.num_blocks, pool.block_size,
                        pool.cfg.n_kv_heads)
    assert pool.bytes_per_block() < _pool("bf16").bytes_per_block()


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_insert_quantizes_on_write(kv_dtype):
    """Admission scatters quantized blocks + scales such that dequant
    recovers the prefilled K/V within the round-trip bound — and never
    stores a dequantized copy (pool K/V leaves stay int8/fp8)."""
    pool = _pool(kv_dtype, bs=8)
    L = 20
    one = _one_cache(pool, L, seed=3)
    pool.insert(0, one, L, 1)
    row = pool.row_of[0]
    nb = int(pool._nb[row])
    blocks = [int(b) for b in pool._table[row, :nb]]
    flat = {jax.tree_util.keystr(p): x
            for p, x in jax.tree_util.tree_leaves_with_path(pool.cache)}
    src_flat = {jax.tree_util.keystr(p): x
                for p, x in jax.tree_util.tree_leaves_with_path(one)}
    checked = 0
    for ks, leaf in flat.items():
        if not (ks.endswith("['k']") or ks.endswith("['v']")):
            continue
        assert leaf.dtype == quant.storage_dtype(kv_dtype)
        scale = flat[ks[:-2] + "_scale']"]
        # leading axis = scanned layer stack; then (N, bs, Kh, D)
        dq = np.asarray(quant.dequantize(leaf, scale))
        got = dq[:, blocks].reshape(
            dq.shape[0], nb * pool.block_size, *leaf.shape[3:])[:, :L]
        want = np.asarray(src_flat[ks], np.float32)[:, 0, :L]
        amax = np.abs(want).max()
        assert np.abs(got - want).max() <= amax * 0.07 + 1e-6
        checked += 1
    assert checked >= 2                       # at least one k and one v


def _scales_of(pool, blocks):
    flat = jax.tree_util.tree_leaves_with_path(pool.cache)
    return {ks: np.asarray(x)[..., blocks, :, :]
            for ks, x in ((jax.tree_util.keystr(p), x) for p, x in flat)
            if "scale" in ks}


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_cow_fork_carries_scale_sidecars(kv_dtype):
    """fork -> cow_prepare must whole-block-copy the scale sidecars with
    the K/V payload (a dequant through a stale scale silently corrupts
    the branch), under the same refcount ledger as the data blocks."""
    pool = _pool(kv_dtype, bs=8, max_len=64)
    L = 20                                        # straddles 3 blocks
    pool.insert(0, _one_cache(pool, L, seed=5), L, 1)
    row = pool.row_of[0]
    nb = int(pool._nb[row])
    src_blocks = [int(b) for b in pool._table[row, :nb]]
    before = _scales_of(pool, src_blocks)

    pool.fork(0, "b1")
    assert pool.ref_count(0, 0) == 2              # aliased, nothing moved
    _cow_ledger_ok(pool)
    copied = pool.cow_prepare("b1", 0, L)
    assert copied == nb
    _cow_ledger_ok(pool)
    brow = pool.row_of["b1"]
    new_blocks = [int(b) for b in pool._table[brow, :nb]]
    assert set(new_blocks).isdisjoint(src_blocks)
    after = _scales_of(pool, new_blocks)
    for ks in before:
        np.testing.assert_array_equal(before[ks], after[ks])

    # rename keeps the ledger untouched; evict returns blocks + sidecar
    # slots to the free list exactly once
    pool.evict(0)
    pool.rename("b1", 0)
    _cow_ledger_ok(pool)
    assert pool.allocated_blocks == nb
    pool.evict(0)
    _cow_ledger_ok(pool)
    assert pool.free_blocks == pool.num_blocks


@settings(max_examples=10, deadline=None)
@given(ops_list=st.lists(
    st.tuples(st.sampled_from(["admit", "evict", "fork", "cow", "rename"]),
              st.integers(0, 5), st.integers(1, 40)),
    min_size=1, max_size=25))
def test_quantized_pool_ledger_never_leaks(ops_list):
    """The PR-6 block-accounting property test, re-run on an int8 pool:
    scale sidecars ride the same alloc/copy/free paths and must never
    unbalance ``free + allocated == num_blocks``."""
    pool = _pool("int8")
    forks = set()
    for op, rid, length in ops_list:
        if op == "admit" and not pool.has(rid) and pool.can_admit(length):
            pool.insert(rid, _one_cache(pool, length), length, 0)
        elif op == "evict" and pool.has(rid):
            pool.evict(rid)
            forks.discard(rid)
        elif op == "fork" and pool.has(rid) and not pool.has(("f", rid)) \
                and pool.free_rows > 0:
            pool.fork(rid, ("f", rid))
            forks.add(("f", rid))
        elif op == "cow" and pool.has(("f", rid)) \
                and pool.free_blocks >= int(pool._nb[pool.row_of[("f", rid)]]):
            pool.cow_prepare(("f", rid), 0, length)
        elif op == "rename" and pool.has(("f", rid)) and pool.has(rid):
            pool.evict(rid)
            pool.rename(("f", rid), rid)
            forks.discard(("f", rid))
        _cow_ledger_ok(pool)
    for rid in list(pool.row_of):
        pool.evict(rid)
    assert pool.free_blocks == pool.num_blocks


# ----------------------------------------------------- engine behavior ----

@pytest.fixture(scope="module")
def models():
    key = jax.random.PRNGKey(0)
    cfg_llm = registry.reduced_for("llama-7b", d_model=96, n_heads=4,
                                   n_kv_heads=4, vocab_size=VOCAB)
    llm = sd.Bundle(cfg_llm, T.init_params(cfg_llm, key))
    ssms = []
    for i, (d, L) in enumerate([(32, 1), (64, 2)]):
        c = registry.reduced_for("llama-68m", d_model=d, n_heads=4,
                                 n_kv_heads=4, vocab_size=VOCAB, n_layers=L)
        ssms.append(sd.Bundle(c, T.init_params(c, jax.random.PRNGKey(i + 1))))
    return llm, ssms


def _run(llm, ssms, **kw):
    sel = LBSS(SelectorConfig(n_ssms=2, batch_limits=[4, 4], alpha=4,
                              beta=2, seed=1))
    defaults = dict(gamma=3, max_len=128, capacity=4, packed_bucket=128,
                    straggler_mitigation=False)
    defaults.update(kw)
    eng = SpinEngine(llm, ssms, sel, EngineConfig(**defaults))
    reqs = make_workload("mix", 4, VOCAB, seed=3, scale=0.2)
    eng.add_requests(reqs)
    eng.run(max_slots=120)
    assert all(r.done for r in eng.requests.values()), "stream must drain"
    return eng


def _same_trace(a, b):
    for rid in a.requests:
        assert a.requests[rid].emitted == b.requests[rid].emitted, rid
    assert a.accepted_tokens == b.accepted_tokens
    assert a.sim_time == b.sim_time, (a.sim_time, b.sim_time)
    sa, sb = a.stats(), b.stats()
    for key in ("drafted", "goodput_sim", "p95_latency"):
        assert sa[key] == sb[key], key


@pytest.mark.parametrize("shape", ["linear", "tree"])
def test_int8_engine_fused_bit_identical_to_unfused(models, shape):
    """Both dequant implementations — in-kernel (fused Pallas) and
    post-gather (XLA fallback) — must commit the same tokens on the same
    sim clock, linear and tree."""
    llm, ssms = models
    off = _run(llm, ssms, kv_dtype="int8", spec_shape=shape,
               fused_kernels="off")
    on = _run(llm, ssms, kv_dtype="int8", spec_shape=shape,
              fused_kernels="on")
    assert on.stats()["kv_dtype"] == "int8"
    _same_trace(off, on)


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_quantized_engine_drains_and_accepts(models, kv_dtype):
    """Quantized KV is a capacity knob, not a correctness knob: the
    stream drains, speculation still accepts at a healthy rate, and the
    total committed tokens match bf16 (greedy emission re-derives every
    token through the LLM, so output length is workload-determined)."""
    llm, ssms = models
    base = _run(llm, ssms)
    e = _run(llm, ssms, kv_dtype=kv_dtype)
    assert e.stats()["kv_dtype"] == kv_dtype
    assert e.accepted_tokens > 0
    # quantization noise may flip individual accept/reject outcomes but
    # must not collapse the acceptance rate
    assert abs(e.accepted_tokens - base.accepted_tokens) \
        <= 0.25 * base.accepted_tokens
    for rid, r in e.requests.items():
        assert len(r.emitted) == len(base.requests[rid].emitted)


def test_kv_dtype_dense_fallback_warns(models):
    llm, ssms = models
    sel = LBSS(SelectorConfig(n_ssms=2, batch_limits=[4, 4], alpha=4,
                              beta=2, seed=1))
    with pytest.warns(UserWarning, match="kv_dtype"):
        eng = SpinEngine(llm, ssms, sel, EngineConfig(
            gamma=3, max_len=128, capacity=4, kv_layout="dense",
            kv_dtype="int8"))
    assert eng.kv_dtype == "bf16"
    assert eng.stats()["kv_dtype"] == "bf16"


def test_engine_rejects_unknown_kv_dtype(models):
    llm, ssms = models
    sel = LBSS(SelectorConfig(n_ssms=2, batch_limits=[4, 4], alpha=4,
                              beta=2, seed=1))
    with pytest.raises(ValueError, match="kv_dtype"):
        SpinEngine(llm, ssms, sel, EngineConfig(
            gamma=3, max_len=128, capacity=4, kv_dtype="int4"))


# ------------------------------------------------ autotune key migration --

def test_tune_key_has_kv_dtype_component():
    k1 = autotune.tune_key("verify", H=4, Kh=4, D=16, gamma_max=8,
                           block_size=16)
    k2 = autotune.tune_key("verify", H=4, Kh=4, D=16, gamma_max=8,
                           block_size=16, kv_dtype="int8")
    assert "|kvbf16|" in k1 and "|kvint8|" in k2 and k1 != k2


def test_load_cache_migrates_legacy_and_drops_corrupt(tmp_path):
    """Pre-kv-dtype keys (the committed results/TUNE_cache.json format)
    migrate to ``kvbf16``; malformed keys are dropped; a current-format
    key wins over a legacy key migrating onto the same slot."""
    backend = jax.default_backend()
    legacy = f"decode|H4xKh4xD16|g8|bs16|linear|{backend}"
    modern = f"decode|H4xKh4xD16|g8|bs16|linear|kvbf16|{backend}"
    other = f"verify|H4xKh4xD16|g8|bs16|tree|{backend}"
    path = str(tmp_path / "tune.json")
    with open(path, "w") as f:
        json.dump({
            legacy: {"bq": 32, "bk": 0, "depth": 1},
            modern: {"bq": 128, "bk": 8, "depth": 2},
            other: {"bq": 64, "bk": 0, "depth": 1},
            "garbage key": {"bq": 1},
            "decode|oops|g8|bs16|linear|cpu": {"bq": 2},
            f"decode|H4xKh4xD16|g8|bs16|linear|kvint8|{backend}":
                {"bq": 16, "bk": 8, "depth": 1},
        }, f)
    cache = autotune.load_cache(path)
    # modern entry beat the legacy migration of the same geometry
    assert cache[modern] == {"bq": 128, "bk": 8, "depth": 2}
    assert cache[f"verify|H4xKh4xD16|g8|bs16|tree|kvbf16|{backend}"] \
        == {"bq": 64, "bk": 0, "depth": 1}
    assert not any("garbage" in k or "oops" in k for k in cache)
    # per-dtype entries stay distinct
    got_bf16 = autotune.get_config("decode", H=4, Kh=4, D=16, gamma_max=8,
                                   block_size=16, path=path)
    got_int8 = autotune.get_config("decode", H=4, Kh=4, D=16, gamma_max=8,
                                   block_size=16, kv_dtype="int8",
                                   path=path)
    assert got_bf16 == autotune.FusedConfig(bq=128, bk=8, depth=2)
    assert got_int8 == autotune.FusedConfig(bq=16, bk=8, depth=1)


def test_committed_tune_cache_loads_clean():
    """Every key in a populated results/TUNE_cache.json must survive the
    migration (none dropped as corrupt).  The cache is machine-local
    (gitignored) — skip when this checkout has never tuned."""
    try:
        with open(autotune.CACHE_PATH) as f:
            raw = json.load(f)
    except OSError:
        pytest.skip("no local tune cache")
    cache = autotune.load_cache()
    assert len(cache) == len(raw)
    assert all("|kv" in k for k in cache)


def test_roofline_candidates_widen_grid(tmp_path):
    """Roofline-derived tile points: a memory-dominant dry-run record
    adds deeper-prefetch configs; a missing file adds nothing."""
    assert autotune.roofline_candidates(
        "decode", 16, path=str(tmp_path / "absent.json")) == []
    path = str(tmp_path / "dryrun.json")
    with open(path, "w") as f:
        json.dump([
            {"status": "ok", "roofline": {"dominant": "memory",
                                          "t_compute_s": 1.0,
                                          "t_memory_s": 2.0,
                                          "t_collective_s": 0.1}},
            {"status": "ok", "roofline": {"dominant": "compute",
                                          "t_compute_s": 2.0,
                                          "t_memory_s": 1.0,
                                          "t_collective_s": 0.1}},
        ], f)
    extra = autotune.roofline_candidates("verify", 32, path=path)
    assert autotune.FusedConfig(bq=128, bk=8, depth=3) in extra
    assert autotune.FusedConfig(bq=256, bk=0, depth=1) in extra
    base = autotune.candidate_configs("verify", 32)
    widened = autotune.candidate_configs("verify", 32, roofline_path=path)
    assert set(base) < set(widened)
    # every widened candidate must actually run (guard against a derived
    # config the kernels reject)
    for cfg in extra:
        assert cfg.depth >= 1 and cfg.bq >= 1 and cfg.bk >= 0
