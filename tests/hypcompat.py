"""Optional-hypothesis shim.

`hypothesis` is a dev-only dependency; a bare environment (CI bootstrap,
minimal container) must still *collect* the property-test modules and run
their example-based tests.  Importing from here instead of hypothesis
directly gives the real decorators when hypothesis is installed and
skip-marking stand-ins when it is not:

    from hypcompat import HAVE_HYPOTHESIS, given, settings, st
"""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any strategy expression (st.lists(st.integers()), ...)
        so module-level @given(...) arguments still evaluate."""

        def __getattr__(self, name):
            return lambda *a, **k: self

        def __call__(self, *a, **k):
            return self

    st = _AnyStrategy()

    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):
        def deco(fn):
            return fn
        return deco
