"""Fused speculative-step kernels (ISSUE 7): interpret-mode validation of
``kernels/fused_verify`` / ``kernels/fused_decode`` against the
``kernels/ref.py`` oracles across tile configs and tree topologies,
autotune-cache behavior (cold-miss fallback, populate/consult roundtrip),
and engine-level bit-identity of ``--fused-kernels on`` vs ``off``."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st
from test_paged import _tree_verify_setup, _verify_setup

from repro.configs import registry
from repro.core import spec_decode as sd
from repro.core.selector import LBSS, SelectorConfig
from repro.data.workloads import make_workload
from repro.kernels import autotune, ref
from repro.kernels.fused_decode import fused_paged_decode
from repro.kernels.fused_verify import fused_paged_verify
from repro.models import transformer as T
from repro.serving.engine import EngineConfig, SpinEngine

VOCAB = 256


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


# ------------------------------------------------------ fused verify ------

@pytest.mark.parametrize("lens,H,Kh,D,bs", [
    ([37, 120, 61], 8, 4, 32, 16),
    ([5, 5], 4, 4, 16, 8),
    ([33, 1, 97, 15], 4, 1, 32, 16),
])
@pytest.mark.parametrize("bq,bk,depth", [
    (128, 0, 1), (8, 0, 2), (16, 8, 3),
])
def test_fused_verify_matches_oracle(lens, H, Kh, D, bs, bq, bk, depth):
    gamma = 4
    nb = sum(max(1, -(-L // bs)) for L in lens) + 2
    args = _verify_setup(lens, bs, nb, H, Kh, D, gamma, seed=3)
    out = fused_paged_verify(*args, bq=bq, bk=bk, depth=depth,
                             interpret=True)
    want = ref.paged_verify_ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=1e-2)


@pytest.mark.parametrize("lens,branch_depths,bs", [
    ([37, 61], [[2, 1], [3]], 16),
    ([5, 9], [[1, 1, 1], [4]], 8),
    ([33, 1, 15], [[2, 2], [1, 0], [3]], 8),
])
def test_fused_verify_tree_matches_oracle(lens, branch_depths, bs):
    args = _tree_verify_setup(lens, branch_depths, bs, 4, 2, 16, seed=5)
    out = fused_paged_verify(*args, bq=8, bk=0, depth=2, interpret=True)
    want = ref.paged_verify_ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=1e-2)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 3))
def test_fused_verify_tree_property(seed, n):
    """Random request mixes x branch topologies x tile configs: the fused
    inline mask path must track the dense oracle everywhere."""
    rng = np.random.default_rng(seed)
    lens = [int(rng.integers(1, 70)) for _ in range(n)]
    depths = [[int(d) for d in rng.integers(0, 5, rng.integers(1, 4))]
              for _ in range(n)]
    bs = int(rng.choice([8, 16]))
    args = _tree_verify_setup(lens, depths, bs, 4, 2, 16, seed=seed)
    bq = int(rng.choice([8, 32, 128]))
    bk = int(rng.choice([0, bs // 2]))
    depth = int(rng.integers(1, 4))
    out = fused_paged_verify(*args, bq=bq, bk=bk, depth=depth,
                             interpret=True)
    want = ref.paged_verify_ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=1e-2)


def test_fused_verify_padding_blocks_never_read():
    """Satellite regression: trailing bucketed-padding entries are clamped
    to the last live fragment (owner -1 keeps them masked), so growing the
    padding tail never changes the output."""
    lens, H, Kh, D, bs = [24, 40], 4, 2, 16, 8
    nb = sum(-(-L // bs) for L in lens) + 2
    q, kp, vp, pseg, ppos, qs, qpos, ids, owner = _verify_setup(
        lens, bs, nb, H, Kh, D, 2, seed=4)
    out1 = fused_paged_verify(q, kp, vp, pseg, ppos, qs, qpos, ids, owner,
                              bq=8, interpret=True)
    pad = 3 * ids.shape[0]                       # much longer padding tail
    ids2 = jnp.concatenate([ids, jnp.zeros(pad, jnp.int32)])
    owner2 = jnp.concatenate([owner, jnp.full(pad, -1, jnp.int32)])
    out2 = fused_paged_verify(q, kp, vp, pseg, ppos, qs, qpos, ids2,
                              owner2, bq=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_paged_verify_trailing_clamp_unchanged():
    """Satellite regression for ``paged_verify_attention``'s new trailing
    clamp (mirroring ``paged_decode_attention``): padding growth is
    output-invariant there too."""
    from repro.kernels.paged_attention import paged_verify_attention
    lens, H, Kh, D, bs = [19, 45, 7], 4, 2, 16, 8
    nb = sum(-(-L // bs) for L in lens) + 3
    q, kp, vp, pseg, ppos, qs, qpos, ids, owner = _verify_setup(
        lens, bs, nb, H, Kh, D, 3, seed=9)
    out1 = paged_verify_attention(q, kp, vp, pseg, ppos, qs, qpos, ids,
                                  owner, bq=8, interpret=True)
    want = ref.paged_verify_ref(q, kp, vp, pseg, ppos, qs, qpos, ids, owner)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(want),
                               atol=2e-5, rtol=1e-2)
    pad = ids.shape[0]
    ids2 = jnp.concatenate([ids, jnp.zeros(pad, jnp.int32)])
    owner2 = jnp.concatenate([owner, jnp.full(pad, -1, jnp.int32)])
    out2 = paged_verify_attention(q, kp, vp, pseg, ppos, qs, qpos, ids2,
                                  owner2, bq=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


# ------------------------------------------------------ fused decode ------

def _decode_setup(lens, bs, H, Kh, D, Tn, seed=0, idle_rows=0):
    """Rows with fragmented block tables; ``idle_rows`` extra rows own no
    blocks (seg -1 queries, outputs ignored)."""
    rng = np.random.default_rng(seed)
    B = len(lens) + idle_rows
    nbs = [max(1, -(-(L + Tn) // bs)) for L in lens] + [0] * idle_rows
    nb_max = max(nbs)
    N = sum(nbs) + 2
    perm = rng.permutation(N)
    bt = np.full((B, nb_max), -1, np.int32)
    pool_seg = np.full((N, bs), -1, np.int32)
    pool_pos = np.full((N, bs), -1, np.int32)
    m = 0
    for b, L in enumerate(lens):
        for k in range(nbs[b]):
            pb = int(perm[m]); m += 1
            bt[b, k] = pb
            for s in range(bs):
                p = k * bs + s
                if p < L:
                    pool_seg[pb, s] = 0
                    pool_pos[pb, s] = p
    kp = _rand(jax.random.PRNGKey(seed), (N, bs, Kh, D))
    vp = _rand(jax.random.PRNGKey(seed + 1), (N, bs, Kh, D))
    q = _rand(jax.random.PRNGKey(seed + 2), (B, Tn, H, D))
    q_seg = np.zeros((B, Tn), np.int32)
    q_seg[len(lens):] = -1
    q_pos = np.stack([L + np.arange(Tn) for L in lens]
                     + [np.full(Tn, -1)] * idle_rows).astype(np.int32)
    return (q, kp, vp, jnp.asarray(pool_seg), jnp.asarray(pool_pos),
            jnp.asarray(q_seg), jnp.asarray(q_pos), jnp.asarray(bt))


@pytest.mark.parametrize("lens,Tn,bs,bk,depth,idle", [
    ([37, 120, 61], 5, 16, 0, 1, 0),
    ([5, 5], 3, 8, 0, 2, 1),
    ([33, 1, 97, 15], 4, 16, 8, 3, 2),
])
def test_fused_decode_matches_oracle(lens, Tn, bs, bk, depth, idle):
    args = _decode_setup(lens, bs, 4, 2, 16, Tn, seed=7, idle_rows=idle)
    out = fused_paged_decode(*args, bk=bk, depth=depth, interpret=True)
    want = ref.paged_seq_decode_ref(*args)
    live = len(lens)
    np.testing.assert_allclose(np.asarray(out)[:live],
                               np.asarray(want)[:live],
                               atol=2e-5, rtol=1e-2)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_fused_decode_property(seed):
    rng = np.random.default_rng(seed)
    lens = [int(rng.integers(1, 80))
            for _ in range(int(rng.integers(1, 4)))]
    bs = int(rng.choice([8, 16]))
    Tn = int(rng.integers(1, 5))
    args = _decode_setup(lens, bs, 4, 2, 16, Tn, seed=seed,
                         idle_rows=int(rng.integers(0, 2)))
    out = fused_paged_decode(*args, bk=int(rng.choice([0, bs // 2])),
                             depth=int(rng.integers(1, 3)), interpret=True)
    want = ref.paged_seq_decode_ref(*args)
    live = len(lens)
    np.testing.assert_allclose(np.asarray(out)[:live],
                               np.asarray(want)[:live],
                               atol=2e-5, rtol=1e-2)


# ------------------------------------------------------ autotune cache ----

def test_autotune_cold_miss_falls_back_to_default(tmp_path):
    path = str(tmp_path / "tune.json")
    autotune.CACHE_STATS.update(hits=0, misses=0)
    cfg = autotune.get_config("verify", H=4, Kh=2, D=16, gamma_max=4,
                              block_size=8, path=path)
    assert cfg == autotune.DEFAULT_CONFIG
    assert autotune.CACHE_STATS["misses"] == 1
    assert autotune.CACHE_STATS["hits"] == 0


def test_autotune_populate_then_consult(tmp_path):
    path = str(tmp_path / "tune.json")
    won = autotune.autotune("decode", H=2, Kh=1, D=8, gamma_max=2,
                            block_size=8, path=path)
    key = autotune.tune_key("decode", H=2, Kh=1, D=8, gamma_max=2,
                            block_size=8)
    cache = autotune.load_cache(path)
    assert key in cache and cache[key]["us"] > 0
    autotune.CACHE_STATS.update(hits=0, misses=0)
    got = autotune.get_config("decode", H=2, Kh=1, D=8, gamma_max=2,
                              block_size=8, path=path)
    assert got == won
    assert autotune.CACHE_STATS["hits"] == 1
    # corrupt cache file degrades to empty (miss), never raises
    with open(path, "w") as f:
        f.write("{not json")
    assert autotune.get_config("decode", H=2, Kh=1, D=8, gamma_max=2,
                               block_size=8,
                               path=path) == autotune.DEFAULT_CONFIG


def test_fused_config_is_jit_cache_key():
    a = autotune.FusedConfig(bq=8, bk=0, depth=2)
    b = autotune.FusedConfig(bq=8, bk=0, depth=2)
    assert a == b and hash(a) == hash(b)
    assert a != autotune.FusedConfig(bq=8, bk=0, depth=1)


# ------------------------------------------------- engine bit-identity ----

@pytest.fixture(scope="module")
def models():
    key = jax.random.PRNGKey(0)
    cfg_llm = registry.reduced_for("llama-7b", d_model=96, n_heads=4,
                                   n_kv_heads=4, vocab_size=VOCAB)
    llm = sd.Bundle(cfg_llm, T.init_params(cfg_llm, key))
    ssms = []
    for i, (d, L) in enumerate([(32, 1), (64, 2)]):
        c = registry.reduced_for("llama-68m", d_model=d, n_heads=4,
                                 n_kv_heads=4, vocab_size=VOCAB, n_layers=L)
        ssms.append(sd.Bundle(c, T.init_params(c, jax.random.PRNGKey(i + 1))))
    return llm, ssms


def _run(llm, ssms, **kw):
    sel = LBSS(SelectorConfig(n_ssms=2, batch_limits=[4, 4], alpha=4,
                              beta=2, seed=1))
    defaults = dict(gamma=3, max_len=128, capacity=4, packed_bucket=128,
                    straggler_mitigation=False)
    defaults.update(kw)
    eng = SpinEngine(llm, ssms, sel, EngineConfig(**defaults))
    reqs = make_workload("mix", 4, VOCAB, seed=3, scale=0.2)
    eng.add_requests(reqs)
    eng.run(max_slots=120)
    assert all(r.done for r in eng.requests.values()), "stream must drain"
    return eng


def _same_trace(a, b):
    """Bit-identical output contract AND sim-clock bookkeeping."""
    for rid in a.requests:
        assert a.requests[rid].emitted == b.requests[rid].emitted, rid
    assert a.accepted_tokens == b.accepted_tokens
    assert a.sim_time == b.sim_time, (a.sim_time, b.sim_time)
    sa, sb = a.stats(), b.stats()
    for key in ("drafted", "slots", "goodput_sim", "p95_latency"):
        if key in sa:
            assert sa[key] == sb[key], key


@pytest.mark.parametrize("shape", ["linear", "tree"])
def test_fused_engine_bit_identical(models, shape):
    """``--fused-kernels on`` must emit the same tokens on the same sim
    clock as ``off`` (greedy accept decisions are argmax-stable under the
    kernels' fp reassociation), for linear AND tree speculation."""
    llm, ssms = models
    off = _run(llm, ssms, spec_shape=shape, fused_kernels="off")
    on = _run(llm, ssms, spec_shape=shape, fused_kernels="on")
    assert off.stats()["fused_kernels"] == "off"
    assert on.stats()["fused_kernels"] == "on"
    _same_trace(off, on)


def test_fused_on_dense_layout_warns_and_falls_back(models):
    llm, ssms = models
    sel = LBSS(SelectorConfig(n_ssms=2, batch_limits=[4, 4], alpha=4,
                              beta=2, seed=1))
    with pytest.warns(UserWarning, match="fused_kernels"):
        eng = SpinEngine(llm, ssms, sel, EngineConfig(
            gamma=3, max_len=128, capacity=4, kv_layout="dense",
            fused_kernels="on"))
    assert not eng.fused
    assert eng.fused_llm_verify is None


def test_engine_rejects_unknown_fused_kernels(models):
    llm, ssms = models
    sel = LBSS(SelectorConfig(n_ssms=2, batch_limits=[4, 4], alpha=4,
                              beta=2, seed=1))
    with pytest.raises(ValueError, match="fused_kernels"):
        SpinEngine(llm, ssms, sel, EngineConfig(
            gamma=3, max_len=128, capacity=4, fused_kernels="sometimes"))


def test_tree_node_budget_error_names_flags(models):
    """Satellite: the config-derived tree budget guard names the flags."""
    llm, ssms = models
    sel = LBSS(SelectorConfig(n_ssms=2, batch_limits=[4, 4], alpha=4,
                              beta=2, seed=1))
    with pytest.raises(ValueError) as ei:
        SpinEngine(llm, ssms, sel, EngineConfig(
            gamma=20, spec_shape="tree", spec_branch=16,
            max_len=128, capacity=4))
    msg = str(ei.value)
    assert "--gamma-max" in msg or "gamma_max" in msg
    assert "spec_branch" in msg or "--spec-branch" in msg
    from repro.core import decompose as D
    assert str(D.max_tree_nodes()) in msg
