"""Docs CI gate: broken-link and flag-drift checks.

Two failure modes docs rot through, both mechanical enough to gate:

1. **Broken relative links** — every ``[text](target)`` in README.md and
   docs/*.md whose target is a repo path must resolve to an existing
   file (anchors and external ``http(s)``/``mailto`` links are skipped).
2. **Flag drift** — every ``--flag`` that ``repro.launch.serve``'s
   argument parser accepts must be documented in ``docs/SERVING.md``
   (the operator guide promises full flag coverage).  A new serve flag
   without a SERVING.md entry fails CI.

Run from the repo root::

    PYTHONPATH=src python tools/check_docs.py

Exit status: 0 clean, 1 problems found (each printed on its own line).
"""

from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def doc_files():
    out = [os.path.join(REPO, "README.md")]
    docs = os.path.join(REPO, "docs")
    for name in sorted(os.listdir(docs)):
        if name.endswith(".md"):
            out.append(os.path.join(docs, name))
    return out


def check_links() -> list:
    problems = []
    for path in doc_files():
        with open(path) as f:
            text = f.read()
        for target in _LINK.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:  # pure in-page anchor
                continue
            resolved = os.path.normpath(os.path.join(os.path.dirname(path), rel))
            if not os.path.exists(resolved):
                problems.append(
                    f"{os.path.relpath(path, REPO)}: broken link -> {target}"
                )
    return problems


def check_flag_drift() -> list:
    from repro.launch.serve import build_parser

    with open(os.path.join(REPO, "docs", "SERVING.md")) as f:
        serving_md = f.read()
    problems = []
    for action in build_parser()._actions:
        for opt in action.option_strings:
            if not opt.startswith("--") or opt == "--help":
                continue
            # word-boundary match: `--gamma` must not be satisfied by the
            # documented `--gamma-max` (substring prefixes are the classic
            # silent hole in drift gates)
            if not re.search(re.escape(opt) + r"(?![\w-])", serving_md):
                problems.append(
                    f"docs/SERVING.md: serve.py flag {opt} is "
                    "undocumented (flag-drift gate)"
                )
    return problems


def main() -> int:
    problems = check_links() + check_flag_drift()
    for p in problems:
        print(p)
    if problems:
        print(f"{len(problems)} docs problem(s)")
        return 1
    print("docs clean: links resolve, every serve.py flag documented")
    return 0


if __name__ == "__main__":
    sys.exit(main())
